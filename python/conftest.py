"""Anchors pytest's rootdir so `compile.*` imports resolve from python/."""

"""L2 contract tests: variant geometry, jit wrapper, pallas-vs-ref graph
equivalence at the model level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


class TestVariants:
    def test_default_variants_are_consistent(self):
        assert len(model.DEFAULT_VARIANTS) >= 3
        names = [v.name for v in model.DEFAULT_VARIANTS]
        assert len(set(names)) == len(names), "duplicate variant names"
        for v in model.DEFAULT_VARIANTS:
            assert v.s % v.block_s == 0, v.name
            assert v.m > 0
            assert "teda_" in v.name

    def test_variant_name_encodes_geometry(self):
        v = model.Variant(s=16, n=3, t=8, m=2.5)
        assert v.name == "teda_s16_n3_t8_m2p5"


class TestModelFn:
    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_shapes_and_dtypes(self, use_pallas):
        v = model.Variant(s=8, n=2, t=4, m=3.0)
        fn = model.jitted(v, use_pallas=use_pallas)
        args = [jnp.zeros(a.shape, a.dtype) for a in model.example_args(v)]
        out = fn(*args)
        assert len(out) == 6
        ecc, zeta, outlier, mu2, var2, k2 = out
        assert ecc.shape == (8, 4)
        assert zeta.shape == (8, 4)
        assert outlier.shape == (8, 4)
        assert mu2.shape == (8, 2)
        assert var2.shape == (8,)
        assert k2.shape == (8,)
        for o in out:
            assert o.dtype == jnp.float32

    def test_pallas_and_ref_models_agree(self):
        v = model.Variant(s=8, n=2, t=16, m=3.0)
        rng = np.random.default_rng(0)
        mu = jnp.asarray(rng.standard_normal((8, 2)), jnp.float32) * 0.1
        var = jnp.asarray(rng.random(8) + 0.5, jnp.float32)
        k = jnp.full((8,), 10.0, jnp.float32)
        x = jnp.asarray(rng.standard_normal((8, 16, 2)), jnp.float32)
        a = model.jitted(v, use_pallas=True)(mu, var, k, x)
        b = model.jitted(v, use_pallas=False)(mu, var, k, x)
        for ta, tb, name in zip(a, b, ["ecc", "zeta", "out", "mu", "var", "k"]):
            np.testing.assert_allclose(
                np.asarray(ta), np.asarray(tb), rtol=1e-5, atol=1e-6,
                err_msg=name,
            )

    def test_threshold_matches_chebyshev(self):
        # outlier fires iff zeta > (m^2+1)/(2k) — reconstruct from outputs.
        v = model.Variant(s=8, n=2, t=8, m=3.0)
        rng = np.random.default_rng(1)
        mu = jnp.zeros((8, 2), jnp.float32)
        var = jnp.full((8,), 0.01, jnp.float32)
        k = jnp.full((8,), 100.0, jnp.float32)
        x = jnp.asarray(rng.standard_normal((8, 8, 2)) * 2, jnp.float32)
        ecc, zeta, outlier, *_ = model.jitted(v)(mu, var, k, x)
        ks = np.arange(101, 109, dtype=np.float64)
        thr = ref.chebyshev_threshold(3.0, ks)[None, :]
        z = np.asarray(zeta, np.float64)
        got = np.asarray(outlier) > 0.5
        want = z > thr
        # fp tolerance right at the boundary
        edge = np.abs(z - thr) < 1e-6
        assert (got == want)[~edge].all()

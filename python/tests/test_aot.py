"""AOT pipeline tests: HLO-text emission and manifest contract."""

import json
import os

import pytest

from compile import aot, model


class TestHloText:
    def test_lowered_variant_produces_hlo_text(self):
        v = model.Variant(s=8, n=2, t=4, m=3.0)
        text = aot.to_hlo_text(model.lower_variant(v))
        # The xla-crate parser needs classic HLO text.
        assert text.startswith("HloModule"), text[:60]
        assert "f32[8,4,2]" in text  # x input shape
        assert "ROOT" in text

    def test_ref_variant_also_lowers(self):
        v = model.Variant(s=8, n=2, t=4, m=3.0)
        text = aot.to_hlo_text(model.lower_variant(v, use_pallas=False))
        assert text.startswith("HloModule")


class TestManifest:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        assert aot.main(["--out-dir", str(out)]) == 0
        return out

    def test_manifest_lists_all_variants(self, built):
        with open(built / "manifest.json") as f:
            manifest = json.load(f)
        assert manifest["format"] == 1
        assert manifest["interchange"] == "hlo-text"
        assert len(manifest["variants"]) == len(model.DEFAULT_VARIANTS)
        for entry in manifest["variants"]:
            assert os.path.exists(built / entry["file"])
            assert entry["kernel"] == "pallas"
            # io specs in execution order
            assert [i["name"] for i in entry["inputs"]] == [
                "mu", "var", "k", "x",
            ]
            assert [o["name"] for o in entry["outputs"]] == [
                "ecc", "zeta", "outlier", "mu_out", "var_out", "k_out",
            ]

    def test_manifest_shapes_match_geometry(self, built):
        with open(built / "manifest.json") as f:
            manifest = json.load(f)
        for entry in manifest["variants"]:
            s, n, t = entry["s"], entry["n"], entry["t"]
            by_name = {i["name"]: i for i in entry["inputs"]}
            assert by_name["mu"]["shape"] == [s, n]
            assert by_name["x"]["shape"] == [s, t, n]
            out_by_name = {o["name"]: o for o in entry["outputs"]}
            assert out_by_name["ecc"]["shape"] == [s, t]
            assert out_by_name["k_out"]["shape"] == [s]

    def test_sha256_matches_file(self, built):
        import hashlib

        with open(built / "manifest.json") as f:
            manifest = json.load(f)
        entry = manifest["variants"][0]
        with open(built / entry["file"]) as f:
            digest = hashlib.sha256(f.read().encode()).hexdigest()
        assert digest == entry["sha256"]

"""L1 correctness: Pallas kernel vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute layer: hypothesis
sweeps the kernel geometry (S, N, T, block_s, m) and input regimes, and
every output (ecc, zeta, outlier, state') must match the reference scan.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.teda_kernel import teda_chunk, vmem_words_per_cell

jax.config.update("jax_platform_name", "cpu")


def run_both(mu, var, k, x, m, block_s):
    ecc, zeta, outlier, mu2, var2, k2 = teda_chunk(
        mu, var, k, x, m=m, block_s=block_s
    )
    st2, ecc_r, zeta_r, out_r = ref.teda_chunk_ref(
        ref.TedaState(mu=mu, var=var, k=k), x, m
    )
    return (ecc, zeta, outlier, mu2, var2, k2), (
        ecc_r,
        zeta_r,
        out_r,
        st2.mu,
        st2.var,
        st2.k,
    )


def assert_match(got, want, atol=1e-5, rtol=1e-5):
    names = ["ecc", "zeta", "outlier", "mu", "var", "k"]
    for name, g, w in zip(names, got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=atol, rtol=rtol, err_msg=name
        )


def fresh_case(seed, s, n, t, scale=1.0):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((s, t, n)) * scale).astype(np.float32)
    mu = np.zeros((s, n), np.float32)
    var = np.zeros((s,), np.float32)
    k = np.zeros((s,), np.float32)
    return jnp.asarray(mu), jnp.asarray(var), jnp.asarray(k), jnp.asarray(x)


def warmed_case(seed, s, n, t, k0=100.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((s, t, n)).astype(np.float32)
    mu = rng.standard_normal((s, n)).astype(np.float32) * 0.1
    var = (rng.random((s,)) + 0.5).astype(np.float32)
    k = np.full((s,), k0, np.float32)
    return jnp.asarray(mu), jnp.asarray(var), jnp.asarray(k), jnp.asarray(x)


class TestKernelVsRef:
    def test_fresh_state_small(self):
        case = fresh_case(0, s=8, n=2, t=16)
        got, want = run_both(*case, m=3.0, block_s=8)
        assert_match(got, want)

    def test_warmed_state(self):
        case = warmed_case(1, s=16, n=4, t=8)
        got, want = run_both(*case, m=3.0, block_s=8)
        assert_match(got, want)

    def test_multi_grid_cells(self):
        # S split across 4 grid cells must equal the reference exactly.
        case = warmed_case(2, s=32, n=2, t=4)
        got, want = run_both(*case, m=3.0, block_s=8)
        assert_match(got, want)

    def test_block_s_equals_s(self):
        case = warmed_case(3, s=8, n=3, t=5)
        got, want = run_both(*case, m=3.0, block_s=8)
        assert_match(got, want)

    @settings(max_examples=25, deadline=None)
    @given(
        s_blocks=st.integers(1, 4),
        n=st.integers(1, 6),
        t=st.integers(1, 12),
        m=st.floats(0.5, 6.0),
        k0=st.sampled_from([0.0, 1.0, 2.0, 50.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, s_blocks, n, t, m, k0, seed):
        block_s = 4
        s = s_blocks * block_s
        if k0 == 0.0:
            mu, var, k, x = fresh_case(seed, s, n, t)
        else:
            mu, var, k, x = warmed_case(seed, s, n, t, k0=k0)
        got, want = run_both(mu, var, k, x, m=float(m), block_s=block_s)
        assert_match(got, want)

    def test_constant_input_never_outlier(self):
        # sigma^2 stays 0 -> guard path -> never an outlier.
        s, n, t = 8, 2, 32
        x = jnp.ones((s, t, n), jnp.float32) * 3.25
        mu = jnp.zeros((s, n), jnp.float32)
        var = jnp.zeros((s,), jnp.float32)
        k = jnp.zeros((s,), jnp.float32)
        _, _, outlier, _, var2, _ = teda_chunk(mu, var, k, x, m=3.0)
        assert float(jnp.sum(outlier)) == 0.0
        np.testing.assert_allclose(np.asarray(var2), 0.0, atol=1e-6)

    def test_spike_detected(self):
        # Steady stream then a gross spike at t=20: Eq. 6 must fire there.
        s, n, t = 8, 2, 32
        rng = np.random.default_rng(5)
        x = rng.standard_normal((s, t, n)).astype(np.float32) * 0.1
        x[:, 20, :] = 50.0
        mu = jnp.zeros((s, n), jnp.float32)
        var = jnp.zeros((s,), jnp.float32)
        k = jnp.full((s,), 200.0, jnp.float32)
        # warm the state as if 200 N(0, 0.1) samples came before
        mu_w = jnp.asarray(rng.standard_normal((s, n)).astype(np.float32) * 0.01)
        var_w = jnp.full((s,), 0.01, jnp.float32)
        _, _, outlier, *_ = teda_chunk(mu_w, var_w, k, jnp.asarray(x), m=3.0)
        out = np.asarray(outlier)
        assert (out[:, 20] == 1.0).all()
        # and the quiet prefix stays quiet
        assert out[:, :20].sum() == 0.0

    def test_chunk_split_equals_one_shot(self):
        # Running [T] in one chunk == two chunks of T/2 with carried state.
        mu, var, k, x = warmed_case(7, s=8, n=2, t=16)
        full = teda_chunk(mu, var, k, x, m=3.0)
        a = teda_chunk(mu, var, k, x[:, :8], m=3.0)
        b = teda_chunk(a[3], a[4], a[5], x[:, 8:], m=3.0)
        np.testing.assert_allclose(
            np.asarray(full[1]),
            np.concatenate([np.asarray(a[1]), np.asarray(b[1])], axis=1),
            rtol=1e-5,
            atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(full[3]), np.asarray(b[3]), rtol=1e-5, atol=1e-6
        )

    def test_bad_block_size_rejected(self):
        mu, var, k, x = fresh_case(0, s=10, n=2, t=4)
        with pytest.raises(ValueError, match="block_s"):
            teda_chunk(mu, var, k, x, m=3.0, block_s=8)

    def test_bad_state_shape_rejected(self):
        mu, var, k, x = fresh_case(0, s=8, n=2, t=4)
        with pytest.raises(ValueError, match="state shapes"):
            teda_chunk(mu[:, :1], var, k, x, m=3.0, block_s=8)


class TestVmemModel:
    def test_vmem_words_formula(self):
        # 8 streams, 16 steps, 2 features: x 256 + state 2*16+2*8 + out 384.
        assert vmem_words_per_cell(8, 16, 2) == 256 + 48 + 384

    def test_vmem_fits_16mb_for_shipped_variants(self):
        from compile.model import DEFAULT_VARIANTS

        for v in DEFAULT_VARIANTS:
            words = vmem_words_per_cell(v.block_s, v.t, v.n)
            assert words * 4 < 16 * 2**20, v.name

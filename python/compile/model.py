"""Layer-2 JAX model: the batched-stream TEDA compute graph.

Wraps the Layer-1 Pallas kernel into the jit-able function the Rust
coordinator calls through PJRT:

    (mu[S,N], var[S], k[S], x[S,T,N])
        -> (ecc[S,T], zeta[S,T], outlier[S,T], mu'[S,N], var'[S], k'[S])

`m` (the Chebyshev multiplier) is baked into the artifact as a constant —
exactly as the paper stores it as a constant inside the OUTLIER module
(§4.1). One artifact is emitted per (S, N, T, m) variant; the coordinator
picks the variant that fits its current batch (see
rust/src/runtime/manifest.rs).

Python in this package runs at *build time only* (``make artifacts``);
nothing here is on the Rust request path.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.teda_kernel import teda_chunk


@dataclasses.dataclass(frozen=True)
class Variant:
    """One AOT-compiled (S, N, T, m) instantiation."""

    s: int  # streams per batch (multiple of block_s)
    n: int  # features per sample
    t: int  # time steps per chunk
    m: float  # Chebyshev multiplier (paper uses 3.0)
    block_s: int = 8

    @property
    def name(self) -> str:
        mtag = str(self.m).replace(".", "p")
        return f"teda_s{self.s}_n{self.n}_t{self.t}_m{mtag}"


# The variants shipped in artifacts/: sized for the coordinator's batcher
# (small = low latency, large = high throughput) on the DAMADICS workload
# (N=2 features) plus an N=4 shape for the generic service path.
DEFAULT_VARIANTS = (
    Variant(s=8, n=2, t=16, m=3.0),
    Variant(s=32, n=2, t=32, m=3.0),
    Variant(s=64, n=4, t=32, m=3.0),
)


def make_fn(variant: Variant, use_pallas: bool = True):
    """Build the jit-able chunk function for `variant`.

    With use_pallas=False the pure-jnp reference graph is built instead
    (used by tests and by the `--ref` ablation artifact).
    """

    def fn(mu, var, k, x):
        if use_pallas:
            ecc, zeta, outlier, mu2, var2, k2 = teda_chunk(
                mu, var, k, x, m=variant.m, block_s=variant.block_s
            )
        else:
            state2, ecc, zeta, outlier = ref.teda_chunk_ref(
                ref.TedaState(mu=mu, var=var, k=k), x, variant.m
            )
            mu2, var2, k2 = state2.mu, state2.var, state2.k
        # Single flat tuple result; rust unwraps with to_tuple().
        return (ecc, zeta, outlier, mu2, var2, k2)

    return fn


def example_args(variant: Variant, dtype=jnp.float32):
    """ShapeDtypeStructs for lowering `variant`."""
    return (
        jax.ShapeDtypeStruct((variant.s, variant.n), dtype),  # mu
        jax.ShapeDtypeStruct((variant.s,), dtype),  # var
        jax.ShapeDtypeStruct((variant.s,), dtype),  # k
        jax.ShapeDtypeStruct((variant.s, variant.t, variant.n), dtype),  # x
    )


@functools.lru_cache(maxsize=None)
def jitted(variant: Variant, use_pallas: bool = True):
    """Jitted chunk function (cached per variant)."""
    return jax.jit(make_fn(variant, use_pallas=use_pallas))


def lower_variant(variant: Variant, use_pallas: bool = True):
    """Lower `variant` to a jax Lowered object (AOT entry point)."""
    return jax.jit(make_fn(variant, use_pallas=use_pallas)).lower(
        *example_args(variant)
    )

"""AOT compiler: lower every model variant to HLO *text* + manifest.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The HLO text parser
on the Rust side (HloModuleProto::from_text_file) reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

The manifest (artifacts/manifest.json) is the contract with
rust/src/runtime/manifest.rs: for each variant it records the file name,
the (S, N, T, m, block_s) geometry, and the input/output tensor specs in
execution order.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def variant_entry(v: model.Variant, filename: str, hlo_text: str) -> dict:
    """Manifest record for one compiled variant."""
    f32 = "f32"
    return {
        "name": v.name,
        "file": filename,
        "s": v.s,
        "n": v.n,
        "t": v.t,
        "m": v.m,
        "block_s": v.block_s,
        "sha256": hashlib.sha256(hlo_text.encode()).hexdigest(),
        "inputs": [
            {"name": "mu", "dtype": f32, "shape": [v.s, v.n]},
            {"name": "var", "dtype": f32, "shape": [v.s]},
            {"name": "k", "dtype": f32, "shape": [v.s]},
            {"name": "x", "dtype": f32, "shape": [v.s, v.t, v.n]},
        ],
        "outputs": [
            {"name": "ecc", "dtype": f32, "shape": [v.s, v.t]},
            {"name": "zeta", "dtype": f32, "shape": [v.s, v.t]},
            {"name": "outlier", "dtype": f32, "shape": [v.s, v.t]},
            {"name": "mu_out", "dtype": f32, "shape": [v.s, v.n]},
            {"name": "var_out", "dtype": f32, "shape": [v.s]},
            {"name": "k_out", "dtype": f32, "shape": [v.s]},
        ],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--ref",
        action="store_true",
        help="also emit pure-jnp reference artifacts (ablation)",
    )
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for v in model.DEFAULT_VARIANTS:
        for use_pallas in ([True, False] if args.ref else [True]):
            name = v.name if use_pallas else v.name + "_ref"
            filename = f"{name}.hlo.txt"
            print(f"lowering {name} ...", flush=True)
            lowered = model.lower_variant(v, use_pallas=use_pallas)
            text = to_hlo_text(lowered)
            path = os.path.join(args.out_dir, filename)
            with open(path, "w") as f:
                f.write(text)
            entry = variant_entry(v, filename, text)
            entry["name"] = name
            entry["kernel"] = "pallas" if use_pallas else "jnp_ref"
            entries.append(entry)
            print(f"  wrote {path} ({len(text)} chars)")

    manifest = {
        "format": 1,
        "interchange": "hlo-text",
        "jax_version": jax.__version__,
        "variants": entries,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(entries)} variants)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Pure-jnp TEDA oracle — the correctness reference for the Pallas kernel.

Implements the paper's Algorithm 1 (Eqs. 1-6) over a batch of S independent
streams, scanning T samples per stream. Written with plain `jax.numpy` +
`lax.scan` only; no Pallas. Every backend (the Pallas kernel, the Rust
software engine, the RTL simulator) must agree with this function.

State layout (all float32 unless stated otherwise):
  mu  : [S, N]  running mean per stream
  var : [S]     running scalar variance (Eq. 3)
  k   : [S]     samples absorbed so far (carried as f32 for arithmetic)

Chunk layout:
  x   : [S, T, N]

Outputs per sample:
  ecc     : [S, T]  eccentricity xi_k          (Eq. 1)
  zeta    : [S, T]  normalized eccentricity    (Eq. 5)
  outlier : [S, T]  1.0 where Eq. 6 fires else 0.0
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TedaState(NamedTuple):
    """Carried TEDA state for S parallel streams."""

    mu: jax.Array  # [S, N]
    var: jax.Array  # [S]
    k: jax.Array  # [S]


def init_state(s: int, n: int, dtype=jnp.float32) -> TedaState:
    """Fresh (k=0) state for S streams of N features."""
    return TedaState(
        mu=jnp.zeros((s, n), dtype),
        var=jnp.zeros((s,), dtype),
        k=jnp.zeros((s,), dtype),
    )


def teda_step(state: TedaState, x_t: jax.Array, m: float):
    """One TEDA update for all S streams (Algorithm 1 lines 3-15).

    x_t: [S, N] — the k-th sample of every stream.
    Returns (state', (ecc, zeta, outlier)) with [S]-shaped outputs.

    The operation order matches the RTL datapath (and rust teda::state):
    MEAN -> VARIANCE (distance to the *new* mean) -> ECCENTRICITY -> OUTLIER.
    """
    one = jnp.asarray(1.0, x_t.dtype)
    k = state.k + one  # [S]
    inv_k = one / k
    ratio = (k - one) * inv_k
    first = (k == one)[:, None]  # [S, 1]

    # MEAN module (Eq. 2) with the k=1 bypass mux (MMUXn).
    mu = jnp.where(first, x_t, ratio[:, None] * state.mu + inv_k[:, None] * x_t)

    # VARIANCE module (Eq. 3): distance to the new mean, k=1 bypass (VMUX1).
    d = x_t - mu  # [S, N]
    d2 = jnp.sum(d * d, axis=-1)  # [S]
    var = jnp.where(first[:, 0], jnp.zeros_like(state.var), ratio * state.var + inv_k * d2)

    # ECCENTRICITY module (Eq. 1) with the sigma^2 > 0 guard.
    ecc = jnp.where(var > 0, inv_k + d2 / (var * k), inv_k)

    # OUTLIER module (Eqs. 5-6).
    zeta = ecc * jnp.asarray(0.5, x_t.dtype)
    thr = jnp.asarray((m * m + 1.0) * 0.5, x_t.dtype) * inv_k
    outlier = (zeta > thr).astype(x_t.dtype)

    return TedaState(mu=mu, var=var, k=k), (ecc, zeta, outlier)


def teda_chunk_ref(state: TedaState, x: jax.Array, m: float):
    """Scan a [S, T, N] chunk through `teda_step`.

    Returns (state', ecc[S,T], zeta[S,T], outlier[S,T]).
    """
    xt = jnp.swapaxes(x, 0, 1)  # [T, S, N] for scan over time

    def body(st, x_t):
        st2, outs = teda_step(st, x_t, m)
        return st2, outs

    state2, (ecc, zeta, outlier) = jax.lax.scan(body, state, xt)
    # scan stacks along T first: [T, S] -> [S, T]
    return state2, ecc.T, zeta.T, outlier.T


def chebyshev_threshold(m: float, k):
    """Eq. 6 threshold (m^2+1)/(2k); for m=3 this is the 5/k curve."""
    return (m * m + 1.0) / (2.0 * k)

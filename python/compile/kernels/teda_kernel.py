"""Layer-1 Pallas kernel: batched-stream TEDA chunk update.

The paper's hardware parallelism is a *temporal pipeline* (MEAN ->
VARIANCE -> {ECCENTRICITY, OUTLIER}, one sample in flight per stage). On a
TPU-shaped machine that insight maps to (DESIGN.md §Hardware-Adaptation):

  * pipeline registers (MREGn/VREG1)  ->  VMEM-resident carry of
    (mu, var, k) across an in-kernel `fori_loop` over the T samples of a
    chunk — the recurrence is sequential in T exactly as in hardware;
  * "multiple TEDA modules in parallel" (paper §5.2.1)  ->  the stream
    axis S: the grid tiles S into blocks of `block_s` streams, each grid
    cell is an independent replica of the RTL block;
  * the divides 1/k, (k-1)/k (EDIV1/ODIV1)  ->  computed once per time
    step for the whole block (scalar broadcast), not per element.

The kernel is lowered with ``interpret=True`` — mandatory for CPU-PJRT
execution (real TPU lowering emits a Mosaic custom-call the CPU plugin
cannot run). Correctness is pinned to ``ref.py`` by
``python/tests/test_kernel.py``.

VMEM footprint per grid cell (f32): x block BS*T*N + state 2*BS*N + 2*BS
+ outputs 3*BS*T words; see EXPERIMENTS.md §Perf for the numbers per
shipped variant.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _teda_kernel(
    x_ref,  # [BS, T, N] input chunk block
    mu_ref,  # [BS, N] state in
    var_ref,  # [BS] state in
    k_ref,  # [BS] state in
    ecc_ref,  # [BS, T] out
    zeta_ref,  # [BS, T] out
    outlier_ref,  # [BS, T] out
    mu_out_ref,  # [BS, N] state out
    var_out_ref,  # [BS] state out
    k_out_ref,  # [BS] state out
    *,
    m: float,
    t_steps: int,
):
    """One grid cell = `block_s` independent TEDA modules over T steps."""
    dtype = x_ref.dtype
    one = jnp.asarray(1.0, dtype)
    half = jnp.asarray(0.5, dtype)
    thr_num = jnp.asarray((m * m + 1.0) * 0.5, dtype)

    def body(t, carry):
        mu, var, k = carry  # [BS,N], [BS], [BS]
        x_t = x_ref[:, t, :]  # dynamic index on the T axis
        k1 = k + one
        inv_k = one / k1
        ratio = (k1 - one) * inv_k
        first = (k1 == one)[:, None]

        # MEAN (Eq. 2) + k=1 bypass (MMUXn).
        mu1 = jnp.where(first, x_t, ratio[:, None] * mu + inv_k[:, None] * x_t)
        # VARIANCE (Eq. 3): distance to the new mean, k=1 bypass (VMUX1).
        d = x_t - mu1
        d2 = jnp.sum(d * d, axis=-1)
        var1 = jnp.where(first[:, 0], jnp.zeros_like(var), ratio * var + inv_k * d2)
        # ECCENTRICITY (Eq. 1) with the sigma^2 > 0 guard.
        ecc = jnp.where(var1 > 0, inv_k + d2 / (var1 * k1), inv_k)
        # OUTLIER (Eqs. 5-6).
        zeta = ecc * half
        outlier = (zeta > thr_num * inv_k).astype(dtype)

        ecc_ref[:, t] = ecc
        zeta_ref[:, t] = zeta
        outlier_ref[:, t] = outlier
        return mu1, var1, k1

    mu0 = mu_ref[...]
    var0 = var_ref[...]
    k0 = k_ref[...]
    mu_f, var_f, k_f = jax.lax.fori_loop(0, t_steps, body, (mu0, var0, k0))
    mu_out_ref[...] = mu_f
    var_out_ref[...] = var_f
    k_out_ref[...] = k_f


def teda_chunk(
    mu: jax.Array,  # [S, N]
    var: jax.Array,  # [S]
    k: jax.Array,  # [S]
    x: jax.Array,  # [S, T, N]
    *,
    m: float,
    block_s: int = 8,
    interpret: bool = True,
):
    """Run a [S, T, N] chunk through the Pallas TEDA kernel.

    Returns (ecc[S,T], zeta[S,T], outlier[S,T], mu'[S,N], var'[S], k'[S]).

    S must be a multiple of `block_s` (the coordinator's dynamic batcher
    pads the stream axis; see rust/src/coordinator/batcher.rs).
    """
    s, t_steps, n = x.shape
    if s % block_s != 0:
        raise ValueError(f"S={s} not a multiple of block_s={block_s}")
    if mu.shape != (s, n) or var.shape != (s,) or k.shape != (s,):
        raise ValueError(
            f"state shapes {mu.shape}/{var.shape}/{k.shape} inconsistent "
            f"with x {x.shape}"
        )
    grid = (s // block_s,)
    dtype = x.dtype

    kernel = functools.partial(_teda_kernel, m=float(m), t_steps=t_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, t_steps, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, t_steps), lambda i: (i, 0)),
            pl.BlockSpec((block_s, t_steps), lambda i: (i, 0)),
            pl.BlockSpec((block_s, t_steps), lambda i: (i, 0)),
            pl.BlockSpec((block_s, n), lambda i: (i, 0)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
            pl.BlockSpec((block_s,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, t_steps), dtype),
            jax.ShapeDtypeStruct((s, t_steps), dtype),
            jax.ShapeDtypeStruct((s, t_steps), dtype),
            jax.ShapeDtypeStruct((s, n), dtype),
            jax.ShapeDtypeStruct((s,), dtype),
            jax.ShapeDtypeStruct((s,), dtype),
        ],
        interpret=interpret,
    )(x, mu, var, k)


def vmem_words_per_cell(block_s: int, t_steps: int, n: int) -> int:
    """f32 words resident in VMEM for one grid cell (perf model input)."""
    x_blk = block_s * t_steps * n
    state = 2 * (block_s * n) + 2 * block_s  # mu in+out, var/k in+out
    outs = 3 * block_s * t_steps
    return x_blk + state + outs

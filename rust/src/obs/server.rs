//! Tiny scrape endpoint: one std-`TcpListener` thread serving the
//! metrics plane over HTTP/1.1, no dependencies.
//!
//! Routes:
//! - `/metrics` — Prometheus text exposition (version 0.0.4)
//! - `/`        — the human-readable `ServiceMetrics::render()` text
//! - `/trace`   — the flight recorder's merged event tail
//!
//! The listener runs nonblocking with a stop flag checked between
//! accepts, so [`MetricsServer::stop`] (and `Drop`) shut it down
//! promptly without needing a self-connect or a poll syscall. One
//! request per connection, `Connection: close` — scrapers reconnect
//! per scrape anyway, and it keeps the loop allocation-free of any
//! connection table.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::metrics::{EnsembleMetrics, ServiceMetrics};
use crate::obs::prometheus::{render_prometheus, CONTENT_TYPE};
use crate::obs::recorder::recorder;

/// How much of the merged recorder tail `/trace` serves.
const TRACE_TAIL: usize = 256;

/// Accept-loop nap when no connection is pending.
const ACCEPT_NAP: Duration = Duration::from_millis(5);

/// A running metrics endpoint. Stop it explicitly with
/// [`MetricsServer::stop`]; dropping it stops it too.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `"127.0.0.1:9464"`; port `0` picks a free
    /// one — handy for tests) and start serving the given metrics.
    pub fn start(
        addr: &str,
        service: Arc<ServiceMetrics>,
        ensemble: Option<Arc<EnsembleMetrics>>,
    ) -> crate::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| crate::Error::io(format!("bind {addr}"), e))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| crate::Error::io("set_nonblocking", e))?;
        let local = listener
            .local_addr()
            .map_err(|e| crate::Error::io("local_addr", e))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_in = stop.clone();
        let handle = std::thread::Builder::new()
            .name("teda-metrics".into())
            .spawn(move || {
                while !stop_in.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((conn, _)) => {
                            // A misbehaving client must not wedge the
                            // scrape plane: errors just drop the conn.
                            let _ = serve_one(conn, &service, &ensemble);
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(ACCEPT_NAP);
                        }
                        Err(_) => std::thread::sleep(ACCEPT_NAP),
                    }
                }
            })
            .map_err(|e| crate::Error::io("spawn teda-metrics", e))?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port `0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the server thread. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_one(
    mut conn: TcpStream,
    service: &ServiceMetrics,
    ensemble: &Option<Arc<EnsembleMetrics>>,
) -> std::io::Result<()> {
    conn.set_read_timeout(Some(Duration::from_millis(500)))?;
    conn.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Read until the request line is complete (we ignore headers; a
    // scrape has no body). 1 KiB is plenty for `GET <path> HTTP/1.1`.
    let mut buf = [0u8; 1024];
    let mut used = 0usize;
    let path = loop {
        let n = conn.read(&mut buf[used..])?;
        used += n;
        let head = &buf[..used];
        if let Some(eol) = head.iter().position(|&b| b == b'\n') {
            let line = String::from_utf8_lossy(&head[..eol]);
            let mut parts = line.split_whitespace();
            let method = parts.next().unwrap_or("");
            let path = parts.next().unwrap_or("/").to_string();
            if method != "GET" {
                return respond(&mut conn, 405, "text/plain", "method not allowed\n");
            }
            break path;
        }
        if n == 0 || used == buf.len() {
            return respond(&mut conn, 400, "text/plain", "bad request\n");
        }
    };
    match path.split('?').next().unwrap_or("/") {
        "/metrics" => {
            let body = render_prometheus(service, ensemble.as_deref());
            respond(&mut conn, 200, CONTENT_TYPE, &body)
        }
        "/" => {
            let mut body = service.render();
            if let Some(em) = ensemble {
                body.push('\n');
                body.push_str(&em.render());
            }
            respond(&mut conn, 200, "text/plain; charset=utf-8", &body)
        }
        "/trace" => {
            let body = recorder().render_tail(TRACE_TAIL);
            respond(&mut conn, 200, "text/plain; charset=utf-8", &body)
        }
        _ => respond(&mut conn, 404, "text/plain", "not found\n"),
    }
}

fn respond(
    conn: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    conn.write_all(head.as_bytes())?;
    conn.write_all(body.as_bytes())?;
    conn.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> (u16, String, String) {
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(
            format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes(),
        )
        .unwrap();
        let mut raw = String::new();
        conn.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").unwrap();
        let status: u16 =
            head.split_whitespace().nth(1).unwrap().parse().unwrap();
        let ctype = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Type: "))
            .unwrap_or("")
            .to_string();
        (status, ctype, body.to_string())
    }

    #[test]
    fn serves_metrics_text_and_trace() {
        let m = ServiceMetrics::new();
        m.samples_in.add(99);
        let mut srv = MetricsServer::start("127.0.0.1:0", m.clone(), None)
            .unwrap();
        let addr = srv.local_addr();

        let (status, ctype, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        assert_eq!(ctype, CONTENT_TYPE);
        assert!(body.contains("teda_samples_in 99"));
        assert!(body.contains("# TYPE teda_samples_in counter"));

        let (status, _, body) = get(addr, "/");
        assert_eq!(status, 200);
        assert!(body.contains("samples_in          99"));

        let (status, _, body) = get(addr, "/trace");
        assert_eq!(status, 200);
        assert!(body.contains("flight recorder: last"));

        let (status, _, _) = get(addr, "/nope");
        assert_eq!(status, 404);

        srv.stop();
        srv.stop(); // idempotent
        assert!(
            TcpStream::connect(addr).is_err()
                || TcpStream::connect(addr)
                    .map(|mut c| {
                        // Listener is gone; at best the connect queue
                        // drains with no responder.
                        c.set_read_timeout(Some(Duration::from_millis(200)))
                            .unwrap();
                        c.write_all(b"GET /metrics HTTP/1.1\r\n\r\n").ok();
                        let mut s = String::new();
                        c.read_to_string(&mut s).is_err() || s.is_empty()
                    })
                    .unwrap_or(true),
            "server still answering after stop"
        );
    }

    #[test]
    fn ensemble_appears_when_attached() {
        let m = ServiceMetrics::new();
        let em = EnsembleMetrics::new(vec!["teda(m=3)".into()]);
        em.fused_verdicts.add(4);
        let srv =
            MetricsServer::start("127.0.0.1:0", m, Some(em)).unwrap();
        let (status, _, body) = get(srv.local_addr(), "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("teda_ensemble_fused_verdicts 4"));
        let (_, _, human) = get(srv.local_addr(), "/");
        assert!(human.contains("fused_verdicts    4"));
    }

    #[test]
    fn rejects_non_get() {
        let m = ServiceMetrics::new();
        let srv = MetricsServer::start("127.0.0.1:0", m, None).unwrap();
        let mut conn = TcpStream::connect(srv.local_addr()).unwrap();
        conn.write_all(b"POST /metrics HTTP/1.1\r\n\r\n").unwrap();
        let mut raw = String::new();
        conn.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 405"));
    }
}

//! Flight recorder: lock-free, fixed-capacity per-thread ring journals
//! of typed coordinator events.
//!
//! Counters say *how often* something happened; the recorder says *what
//! happened just now, in what order* — the last N routing decisions,
//! ring-full stalls, seals, adopts, restores and epoch swaps that led
//! up to the moment you are staring at. It is the postmortem surface: a
//! panicking worker dumps its tail automatically, `teda-fpga trace`
//! dumps on demand, and the metrics server serves it at `/trace`.
//!
//! ## Design
//!
//! - **One journal per thread.** [`record`] writes to a thread-local
//!   [`Journal`] (registered globally on the thread's first event), so
//!   the hot path takes no locks and shares no cache lines between
//!   threads. Readers merge the per-thread tails by timestamp.
//! - **Seqlock slots.** Each slot is published with a sequence-stamp
//!   protocol (invalidate → payload → stamp) so a reader that races a
//!   wrapping writer detects the torn slot and skips it instead of
//!   reporting garbage. Writers never wait for readers.
//! - **Bounded, overwrite-oldest.** A journal holds the last
//!   `capacity` events per thread; older events are overwritten. A
//!   dump is a snapshot of the recent past, never a complete log.
//! - **Cheap when off.** The global [`FlightRecorder::set_enabled`]
//!   gate is one relaxed atomic load per [`record`] call.
//!
//! ## Event field semantics
//!
//! `stream`/`shard`/`worker` are reused per kind (a fixed-width record,
//! not a schema):
//!
//! | kind                   | stream            | shard          | worker |
//! |------------------------|-------------------|----------------|--------|
//! | `Submit`               | samples in burst  | —              | target |
//! | `Route`                | stream id         | shard          | target |
//! | `RingPush` / `CtlPush` | samples delivered | —              | target |
//! | `RingFull`             | samples blocked   | —              | target |
//! | `Dequeue`              | samples in job    | —              | self   |
//! | `Stray`                | stream id         | shard          | self   |
//! | `Seal` / `Adopt`       | streams in bundle | shards moved   | self   |
//! | `Snapshot` / `Restore` | stream id         | —              | self   |
//! | `Evict`                | stream id         | —              | self   |
//! | `EpochSwap`            | new epoch         | —              | —      |
//! | `Park`                 | —                 | —              | —      |
//! | `WorkerPanic`          | —                 | —              | self   |
//! | `PeerConnect`          | peer node id      | —              | —      |
//! | `Heartbeat`            | peer node id      | —              | —      |
//! | `BundleShip`           | bundle bytes      | shards moved   | —      |
//! | `Failover`             | dead node id      | shards adopted | —      |
//! | `MemberJoin`           | joined node id    | —              | —      |
//! | `NodeRebalance`        | recipient node id | shards shed    | —      |
//! | `IngestPark`           | samples parked    | buffer depth   | —      |
//! | `StrayDrop`            | strays dropped    | —              | —      |
//!
//! "—" columns carry `0` (or [`NO_WORKER`] for the worker field).

use std::sync::atomic::{
    fence, AtomicBool, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-thread journal capacity (events; rounded to a power of
/// two).
pub const DEFAULT_CAPACITY: usize = 4096;

/// Sentinel for "no worker id applies" (the worker field is packed
/// into 24 bits, so worker ids must stay below this).
pub const NO_WORKER: u32 = 0x00FF_FFFF;

/// Typed coordinator events (see the module table for field use).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// A worker-burst handed to the batched submit core.
    Submit = 0,
    /// A non-fast-path routing decision (retry or epoch miss).
    Route,
    /// A data job published on a worker's SPSC ring (batched path).
    RingPush,
    /// A push that found the ring full and entered the counted spin.
    RingFull,
    /// A data job diverted to the bounded control channel.
    CtlPush,
    /// A worker dequeued a data job.
    Dequeue,
    /// A sample reached a worker no longer owning its shard.
    Stray,
    /// Migration: old worker sealed a shard set.
    Seal,
    /// Migration: new worker adopted a shard set.
    Adopt,
    /// A per-stream checkpoint was published.
    Snapshot,
    /// A stream's state was restored from a checkpoint.
    Restore,
    /// An idle stream was evicted.
    Evict,
    /// A new shard-table epoch was installed (sender restamp).
    EpochSwap,
    /// A worker parked on its doorbell (both queues empty).
    Park,
    /// A worker thread died by panic.
    WorkerPanic,
    /// A transport connection to a cluster peer was established.
    PeerConnect,
    /// A cluster heartbeat was exchanged with a peer.
    Heartbeat,
    /// A sealed bundle crossed the transport to/from a peer.
    BundleShip,
    /// A dead peer's shards were recovered from the shared store.
    Failover,
    /// A member was installed into the roster at runtime.
    MemberJoin,
    /// Cross-node load rebalance: shards shed to a colder peer.
    NodeRebalance,
    /// A burst was parked in the failover-window ingest buffer.
    IngestPark,
    /// Parked strays were dropped at the bounded park list's cap.
    StrayDrop,
}

const KINDS: [EventKind; 23] = [
    EventKind::Submit,
    EventKind::Route,
    EventKind::RingPush,
    EventKind::RingFull,
    EventKind::CtlPush,
    EventKind::Dequeue,
    EventKind::Stray,
    EventKind::Seal,
    EventKind::Adopt,
    EventKind::Snapshot,
    EventKind::Restore,
    EventKind::Evict,
    EventKind::EpochSwap,
    EventKind::Park,
    EventKind::WorkerPanic,
    EventKind::PeerConnect,
    EventKind::Heartbeat,
    EventKind::BundleShip,
    EventKind::Failover,
    EventKind::MemberJoin,
    EventKind::NodeRebalance,
    EventKind::IngestPark,
    EventKind::StrayDrop,
];

impl EventKind {
    /// Stable display name (also the `/trace` wire spelling).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Route => "route",
            EventKind::RingPush => "ring_push",
            EventKind::RingFull => "ring_full",
            EventKind::CtlPush => "ctl_push",
            EventKind::Dequeue => "dequeue",
            EventKind::Stray => "stray",
            EventKind::Seal => "seal",
            EventKind::Adopt => "adopt",
            EventKind::Snapshot => "snapshot",
            EventKind::Restore => "restore",
            EventKind::Evict => "evict",
            EventKind::EpochSwap => "epoch_swap",
            EventKind::Park => "park",
            EventKind::WorkerPanic => "worker_panic",
            EventKind::PeerConnect => "peer_connect",
            EventKind::Heartbeat => "heartbeat",
            EventKind::BundleShip => "bundle_ship",
            EventKind::Failover => "failover",
            EventKind::MemberJoin => "member_join",
            EventKind::NodeRebalance => "node_rebalance",
            EventKind::IngestPark => "ingest_park",
            EventKind::StrayDrop => "stray_drop",
        }
    }

    fn from_u8(b: u8) -> Option<EventKind> {
        KINDS.get(b as usize).copied()
    }
}

/// One recorded event, decoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Per-thread monotonic sequence number (1-based).
    pub seq: u64,
    /// Nanoseconds since the process-wide recorder epoch.
    pub ts_ns: u64,
    pub kind: EventKind,
    pub stream: u64,
    pub shard: u32,
    /// Worker index, or [`NO_WORKER`].
    pub worker: u32,
}

/// An event tagged with the journal (thread) it came from.
#[derive(Debug, Clone)]
pub struct TaggedEvent {
    pub thread: String,
    pub event: Event,
}

/// kind (8 bits) | shard (32 bits) | worker (24 bits).
fn pack_meta(kind: EventKind, shard: u32, worker: u32) -> u64 {
    (kind as u64)
        | ((shard as u64) << 8)
        | (((worker.min(NO_WORKER)) as u64) << 40)
}

/// Nanoseconds since the first call (the process recorder epoch).
/// Monotonic; shared by every journal so merged dumps sort correctly.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// One slot: a seqlock over a 3-word payload. `seq == 0` means "being
/// written"; otherwise `seq` is the 1-based event number whose payload
/// the slot holds.
struct Slot {
    seq: AtomicU64,
    ts: AtomicU64,
    stream: AtomicU64,
    meta: AtomicU64,
}

/// A single thread's fixed-capacity event ring.
///
/// Writer contract: [`Journal::push`] must only ever be called from
/// ONE thread (the global recorder enforces this by handing each
/// thread its own journal). Readers ([`Journal::tail`]) may run from
/// any thread, concurrently with the writer, and skip torn slots.
pub struct Journal {
    label: String,
    mask: u64,
    /// Events ever pushed (1-based; event n lives in slot (n-1) & mask
    /// until overwritten by event n + capacity).
    head: AtomicU64,
    slots: Vec<Slot>,
}

impl Journal {
    /// A journal holding the last `capacity` events (rounded up to a
    /// power of two, minimum 8).
    pub fn new(label: impl Into<String>, capacity: usize) -> Journal {
        let cap = capacity.max(8).next_power_of_two();
        Journal {
            label: label.into(),
            mask: (cap - 1) as u64,
            head: AtomicU64::new(0),
            slots: (0..cap)
                .map(|_| Slot {
                    seq: AtomicU64::new(0),
                    ts: AtomicU64::new(0),
                    stream: AtomicU64::new(0),
                    meta: AtomicU64::new(0),
                })
                .collect(),
        }
    }

    /// Journal label (the owning thread's name).
    pub fn label(&self) -> &str {
        &self.label
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events ever pushed (not capped by capacity).
    pub fn pushed(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Record one event. Writer side — single thread only.
    #[inline]
    pub fn push(&self, kind: EventKind, stream: u64, shard: u32, worker: u32) {
        let n = self.head.load(Ordering::Relaxed) + 1;
        let slot = &self.slots[((n - 1) & self.mask) as usize];
        // Seqlock write: invalidate, then payload, then stamp. The
        // Release fence keeps the invalidation visible before any
        // payload store; the Release stamp pairs with the reader's
        // Acquire load so a stamped slot implies a complete payload.
        slot.seq.store(0, Ordering::Relaxed);
        fence(Ordering::Release);
        slot.ts.store(now_ns(), Ordering::Relaxed);
        slot.stream.store(stream, Ordering::Relaxed);
        slot.meta.store(pack_meta(kind, shard, worker), Ordering::Relaxed);
        slot.seq.store(n, Ordering::Release);
        self.head.store(n, Ordering::Release);
    }

    /// The newest `n` events still resident, oldest first. Slots being
    /// overwritten by a concurrent writer are skipped (the seqlock
    /// recheck), so a tail under live load may come back shorter.
    pub fn tail(&self, n: usize) -> Vec<Event> {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let want = (n as u64).min(cap).min(head);
        let mut out = Vec::with_capacity(want as usize);
        for seq in (head - want + 1)..=head {
            let slot = &self.slots[((seq - 1) & self.mask) as usize];
            // Seqlock read: stamp, payload, fence, stamp again. Any
            // mismatch means the writer lapped us mid-read.
            if slot.seq.load(Ordering::Acquire) != seq {
                continue;
            }
            let ts_ns = slot.ts.load(Ordering::Relaxed);
            let stream = slot.stream.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) != seq {
                continue;
            }
            let Some(kind) = EventKind::from_u8((meta & 0xFF) as u8) else {
                continue;
            };
            out.push(Event {
                seq,
                ts_ns,
                kind,
                stream,
                shard: ((meta >> 8) & 0xFFFF_FFFF) as u32,
                worker: ((meta >> 40) & NO_WORKER as u64) as u32,
            });
        }
        out
    }
}

/// The process-wide recorder: the enable gate, the capacity for
/// journals yet to be created, and the registry of every thread's
/// journal (journals outlive their threads so postmortems still see a
/// dead worker's last events).
pub struct FlightRecorder {
    enabled: AtomicBool,
    capacity: AtomicUsize,
    journals: Mutex<Vec<Arc<Journal>>>,
}

/// The global recorder (created on first touch, enabled by default).
pub fn recorder() -> &'static FlightRecorder {
    static RECORDER: OnceLock<FlightRecorder> = OnceLock::new();
    RECORDER.get_or_init(|| FlightRecorder {
        enabled: AtomicBool::new(true),
        capacity: AtomicUsize::new(DEFAULT_CAPACITY),
        journals: Mutex::new(Vec::new()),
    })
}

thread_local! {
    static JOURNAL: std::cell::OnceCell<Arc<Journal>> =
        const { std::cell::OnceCell::new() };
}

/// Record one event into the calling thread's journal. The single
/// always-paid cost is one relaxed load of the enable gate; the first
/// event per thread also registers its journal globally.
#[inline]
pub fn record(kind: EventKind, stream: u64, shard: u32, worker: u32) {
    let r = recorder();
    if !r.enabled.load(Ordering::Relaxed) {
        return;
    }
    JOURNAL.with(|cell| {
        cell.get_or_init(|| r.register_current_thread())
            .push(kind, stream, shard, worker);
    });
}

impl FlightRecorder {
    /// Toggle recording (relaxed-checked on every [`record`] call).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Per-thread capacity for journals created *after* this call
    /// (existing journals keep theirs — they are fixed-size by design).
    pub fn set_capacity(&self, capacity: usize) {
        self.capacity.store(capacity.max(8), Ordering::Relaxed);
    }

    /// Apply the `[obs]` config knobs in one call.
    pub fn configure(&self, enabled: bool, capacity: usize) {
        self.set_capacity(capacity);
        self.set_enabled(enabled);
    }

    fn register_current_thread(&self) -> Arc<Journal> {
        let cur = std::thread::current();
        let label = cur
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("{:?}", cur.id()));
        let journal = Arc::new(Journal::new(
            label,
            self.capacity.load(Ordering::Relaxed),
        ));
        self.journals.lock().unwrap().push(journal.clone());
        journal
    }

    /// Registered journals (snapshot; includes dead threads').
    pub fn journals(&self) -> Vec<Arc<Journal>> {
        self.journals.lock().unwrap().clone()
    }

    /// Merge the newest `per_thread` events of every journal into one
    /// timeline, oldest first (timestamps share [`now_ns`]'s epoch).
    pub fn dump(&self, per_thread: usize) -> Vec<TaggedEvent> {
        let mut out: Vec<TaggedEvent> = Vec::new();
        for journal in self.journals() {
            for event in journal.tail(per_thread) {
                out.push(TaggedEvent {
                    thread: journal.label().to_string(),
                    event,
                });
            }
        }
        out.sort_by(|a, b| {
            a.event
                .ts_ns
                .cmp(&b.event.ts_ns)
                .then_with(|| a.thread.cmp(&b.thread))
                .then(a.event.seq.cmp(&b.event.seq))
        });
        out
    }

    /// Human-readable dump of the last `n` events across all threads
    /// (the panic-handler / `teda-fpga trace` / `/trace` format).
    pub fn render_tail(&self, n: usize) -> String {
        let merged = self.dump(n);
        let tail = &merged[merged.len().saturating_sub(n)..];
        let mut out = String::with_capacity(tail.len() * 64 + 64);
        out.push_str(&format!(
            "flight recorder: last {} of {} merged event(s)\n",
            tail.len(),
            merged.len()
        ));
        for t in tail {
            let e = &t.event;
            let worker = if e.worker == NO_WORKER {
                "-".to_string()
            } else {
                e.worker.to_string()
            };
            out.push_str(&format!(
                "[{:>14.6}s] {:<16} {:<12} stream={:<8} shard={:<5} worker={}\n",
                e.ts_ns as f64 / 1e9,
                t.thread,
                e.kind.name(),
                e.stream,
                e.shard,
                worker
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_through_pack() {
        for (i, kind) in KINDS.iter().enumerate() {
            assert_eq!(*kind as u8 as usize, i);
            assert_eq!(EventKind::from_u8(i as u8), Some(*kind));
        }
        assert_eq!(EventKind::from_u8(KINDS.len() as u8), None);
    }

    #[test]
    fn journal_records_and_tails_in_order() {
        let j = Journal::new("t", 64);
        for i in 0..10u64 {
            j.push(EventKind::Dequeue, i, i as u32, 3);
        }
        let tail = j.tail(64);
        assert_eq!(tail.len(), 10);
        for (i, e) in tail.iter().enumerate() {
            assert_eq!(e.seq, i as u64 + 1);
            assert_eq!(e.stream, i as u64);
            assert_eq!(e.shard, i as u32);
            assert_eq!(e.worker, 3);
            assert_eq!(e.kind, EventKind::Dequeue);
        }
        // Timestamps are monotone non-decreasing within one thread.
        for w in tail.windows(2) {
            assert!(w[0].ts_ns <= w[1].ts_ns);
        }
    }

    #[test]
    fn journal_wraparound_keeps_exactly_the_newest_capacity_events() {
        // Capacity rounds 10 → 16; push 3 full laps plus a remainder.
        let j = Journal::new("wrap", 10);
        assert_eq!(j.capacity(), 16);
        let total = 16 * 3 + 5;
        for i in 0..total as u64 {
            j.push(EventKind::Submit, i, 0, 0);
        }
        assert_eq!(j.pushed(), total as u64);
        let tail = j.tail(1000);
        assert_eq!(tail.len(), 16, "only the newest capacity survive");
        // The survivors are exactly the last 16, in push order.
        for (i, e) in tail.iter().enumerate() {
            let expect = (total - 16 + i) as u64;
            assert_eq!(e.seq, expect + 1);
            assert_eq!(e.stream, expect);
        }
        // A shorter tail cuts from the old end.
        let short = j.tail(4);
        assert_eq!(short.len(), 4);
        assert_eq!(short[0].stream, (total - 4) as u64);
    }

    #[test]
    fn tail_under_concurrent_writes_never_tears() {
        // A tiny ring wrapped at full speed while a reader polls: every
        // event the reader accepts must be self-consistent (we encode
        // seq-derived values in every payload field).
        let j = Arc::new(Journal::new("race", 8));
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let j = j.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    j.push(EventKind::Route, i * 3, (i % 1000) as u32, 7);
                    i += 1;
                }
                i
            })
        };
        let mut seen = 0usize;
        for _ in 0..2000 {
            for e in j.tail(8) {
                seen += 1;
                let i = e.seq - 1;
                assert_eq!(e.stream, i * 3, "torn slot surfaced");
                assert_eq!(e.shard, (i % 1000) as u32);
                assert_eq!(e.worker, 7);
            }
        }
        stop.store(true, Ordering::Relaxed);
        let pushed = writer.join().unwrap();
        assert!(pushed > 0);
        assert!(seen > 0, "reader never observed a stable slot");
    }

    #[test]
    fn global_recorder_merges_concurrent_threads() {
        recorder().set_enabled(true);
        let threads: Vec<_> = (0..4)
            .map(|t| {
                std::thread::Builder::new()
                    .name(format!("obs-rec-test-{t}"))
                    .spawn(move || {
                        for i in 0..100u64 {
                            record(
                                EventKind::Snapshot,
                                t * 1_000_000 + i,
                                t as u32,
                                NO_WORKER,
                            );
                        }
                    })
                    .unwrap()
            })
            .collect();
        for h in threads {
            h.join().unwrap();
        }
        // The dump is global (other tests' events may interleave):
        // filter down to ours by thread name.
        let dump = recorder().dump(4096);
        for t in 0..4u64 {
            let name = format!("obs-rec-test-{t}");
            let mine: Vec<_> = dump
                .iter()
                .filter(|e| e.thread == name)
                .map(|e| &e.event)
                .collect();
            assert_eq!(mine.len(), 100, "thread {name}");
            for (i, e) in mine.iter().enumerate() {
                assert_eq!(e.stream, t * 1_000_000 + i as u64);
                assert_eq!(e.shard, t as u32);
                assert_eq!(e.kind, EventKind::Snapshot);
            }
        }
        // Merged ordering is by timestamp.
        for w in dump.windows(2) {
            assert!(w[0].event.ts_ns <= w[1].event.ts_ns);
        }
    }

    #[test]
    fn disabled_gate_short_circuits_before_any_journal() {
        // A local instance (not the global — toggling that would race
        // other tests' event assertions in this process). record()'s
        // hot path is: gate load, then journal init/push — with the
        // gate closed nothing is registered, nothing is written.
        let r = FlightRecorder {
            enabled: AtomicBool::new(false),
            capacity: AtomicUsize::new(64),
            journals: Mutex::new(Vec::new()),
        };
        assert!(!r.is_enabled());
        if r.is_enabled() {
            r.register_current_thread().push(EventKind::Evict, 1, 2, 3);
        }
        assert!(r.journals().is_empty(), "gate must precede registration");
        r.set_enabled(true);
        if r.is_enabled() {
            r.register_current_thread().push(EventKind::Evict, 1, 2, 3);
        }
        let journals = r.journals();
        assert_eq!(journals.len(), 1);
        assert_eq!(journals[0].pushed(), 1);
        // Capacity knob applies to journals created after the change.
        r.set_capacity(128);
        let j2 = r.register_current_thread();
        assert_eq!(j2.capacity(), 128);
        assert_eq!(journals[0].capacity(), 64, "existing journals keep theirs");
    }

    #[test]
    fn render_tail_formats_worker_sentinel() {
        let r = recorder();
        r.set_enabled(true);
        std::thread::Builder::new()
            .name("obs-render-test".into())
            .spawn(|| {
                record(EventKind::EpochSwap, 42, 0, NO_WORKER);
                record(EventKind::Seal, 5, 2, 1);
            })
            .unwrap()
            .join()
            .unwrap();
        let text = r.render_tail(10_000);
        assert!(text.contains("epoch_swap"));
        assert!(text.contains("worker=-"), "NO_WORKER renders as '-'");
        assert!(text.contains("flight recorder: last"));
    }
}

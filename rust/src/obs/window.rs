//! Windowed metric views: delta snapshots over the registry.
//!
//! Lifetime counters answer "how much, ever"; control loops need "how
//! much, lately". [`MetricsWindow`] snapshots every registry row and,
//! on each tick, returns the counter deltas, per-second rates, and
//! windowed histogram distributions for just the elapsed interval.
//! [`ShardWindow`] is the per-shard analogue the rebalancer consumes:
//! sample-count deltas plus windowed per-shard p99, replacing the raw
//! count vector the coordinator used to diff by hand.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::metrics::{
    HistogramSnapshot, MetricValue, ServiceMetrics, ShardMetrics,
};

/// One counter's view of the last window.
#[derive(Debug, Clone)]
pub struct WindowRow {
    pub name: &'static str,
    /// Increment over the window.
    pub delta: u64,
    /// Increment per second of window wall time.
    pub rate_per_s: f64,
}

/// Everything one [`MetricsWindow::tick`] observed.
#[derive(Debug, Clone)]
pub struct WindowReport {
    /// Window wall time in seconds (never 0; clamped to ≥ 1µs).
    pub elapsed_s: f64,
    /// Counter deltas/rates, registry order.
    pub counters: Vec<WindowRow>,
    /// Gauges are instantaneous: current value, registry order.
    pub gauges: Vec<(&'static str, u64)>,
    /// Histogram distributions of just this window, registry order.
    pub histograms: Vec<(&'static str, HistogramSnapshot)>,
}

impl WindowReport {
    /// Windowed counter increment (0 for unknown names).
    pub fn delta(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|r| r.name == name)
            .map_or(0, |r| r.delta)
    }

    /// Windowed counter rate per second (0 for unknown names).
    pub fn rate(&self, name: &str) -> f64 {
        self.counters
            .iter()
            .find(|r| r.name == name)
            .map_or(0.0, |r| r.rate_per_s)
    }

    /// Current gauge value (0 for unknown names).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges
            .iter()
            .find(|(n, _)| *n == name)
            .map_or(0, |(_, v)| *v)
    }

    /// Windowed histogram (None for unknown names).
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, h)| h)
    }

    /// Windowed p99 in ns (0 for unknown or empty windows).
    pub fn p99(&self, name: &str) -> u64 {
        self.histogram(name).map_or(0, |h| h.quantile(0.99))
    }

    /// Compact one-window summary for `serve` progress lines.
    pub fn render(&self) -> String {
        format!(
            "window {:.1}s: in={:.0}/s out={:.0}/s backpressure={} \
             latency_p99={}ns queue_p99={}ns engine_p99={}ns",
            self.elapsed_s,
            self.rate("samples_in"),
            self.rate("verdicts_out"),
            self.delta("backpressure_events"),
            self.p99("latency"),
            self.p99("queue_wait"),
            self.p99("engine_time"),
        )
    }
}

/// Rolling delta tracker over the whole [`ServiceMetrics`] registry.
/// Feed it the same metrics handle each tick; it remembers the last
/// snapshot and hands back the interval view (sink 3 of the registry).
#[derive(Debug)]
pub struct MetricsWindow {
    counters: BTreeMap<&'static str, u64>,
    histograms: BTreeMap<&'static str, HistogramSnapshot>,
    taken: Instant,
}

impl MetricsWindow {
    /// Baseline "now": the first tick measures from this call.
    pub fn new(metrics: &ServiceMetrics) -> MetricsWindow {
        let mut w = MetricsWindow {
            counters: BTreeMap::new(),
            histograms: BTreeMap::new(),
            taken: Instant::now(),
        };
        w.rebaseline(metrics);
        w
    }

    fn rebaseline(&mut self, metrics: &ServiceMetrics) {
        for row in metrics.registry() {
            match row.value {
                MetricValue::Counter(v) => {
                    self.counters.insert(row.name, v);
                }
                MetricValue::Gauge(_) => {}
                MetricValue::Histogram(h) => {
                    self.histograms.insert(row.name, h.snapshot());
                }
            }
        }
        self.taken = Instant::now();
    }

    /// Close the current window: report deltas/rates since the last
    /// tick (or construction) and start the next window.
    pub fn tick(&mut self, metrics: &ServiceMetrics) -> WindowReport {
        let elapsed_s = self.taken.elapsed().as_secs_f64().max(1e-6);
        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut histograms = Vec::new();
        for row in metrics.registry() {
            match row.value {
                MetricValue::Counter(v) => {
                    let prev = self.counters.get(row.name).copied().unwrap_or(0);
                    let delta = v.saturating_sub(prev);
                    counters.push(WindowRow {
                        name: row.name,
                        delta,
                        rate_per_s: delta as f64 / elapsed_s,
                    });
                }
                MetricValue::Gauge(v) => gauges.push((row.name, v)),
                MetricValue::Histogram(h) => {
                    let snap = h.snapshot();
                    let prev = self.histograms.remove(row.name).unwrap_or_default();
                    histograms.push((row.name, snap.delta(&prev)));
                }
            }
        }
        self.rebaseline(metrics);
        WindowReport { elapsed_s, counters, gauges, histograms }
    }
}

/// One shard's activity over a window.
#[derive(Debug, Clone, Copy)]
pub struct ShardDelta {
    pub shard: u32,
    /// Samples processed in the window.
    pub samples: u64,
    /// Windowed end-to-end p99 of this shard's verdicts (0 if idle).
    pub p99_ns: u64,
}

/// Per-shard delta tracker for the rebalancer: what each virtual shard
/// did since the last look, by volume *and* by windowed tail latency.
#[derive(Debug)]
pub struct ShardWindow {
    counts: Vec<u64>,
    latency: Vec<HistogramSnapshot>,
}

impl ShardWindow {
    /// Zero baseline: the first delta reports lifetime totals (the
    /// behaviour the rebalancer's very first interval always had).
    pub fn new(virtual_shards: usize) -> ShardWindow {
        ShardWindow {
            counts: vec![0; virtual_shards],
            latency: vec![HistogramSnapshot::default(); virtual_shards],
        }
    }

    /// Forget the current window: the next delta measures from here.
    /// Called after a migration so the post-move interval isn't
    /// polluted by pre-move load attribution.
    pub fn rebaseline(&mut self, shards: &ShardMetrics) {
        self.counts = shards.sample_counts();
        self.latency = shards.latency_snapshots();
    }

    /// Per-shard activity since the last call (or construction), then
    /// rebaseline — each window is consumed exactly once.
    pub fn delta(&mut self, shards: &ShardMetrics) -> Vec<ShardDelta> {
        let counts = shards.sample_counts();
        let snaps = shards.latency_snapshots();
        let empty = HistogramSnapshot::default();
        let out = counts
            .iter()
            .enumerate()
            .map(|(i, &c)| ShardDelta {
                shard: i as u32,
                samples: c
                    .saturating_sub(self.counts.get(i).copied().unwrap_or(0)),
                p99_ns: snaps[i]
                    .delta(self.latency.get(i).unwrap_or(&empty))
                    .quantile(0.99),
            })
            .collect();
        self.counts = counts;
        self.latency = snaps;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_reports_deltas_not_lifetimes() {
        let m = ServiceMetrics::default();
        m.samples_in.add(1_000);
        m.latency.record(500);
        let mut w = MetricsWindow::new(&m);
        // Everything before construction is baseline, not window.
        m.samples_in.add(10);
        m.verdicts_out.add(7);
        m.latency.record(2_000_000);
        let r = w.tick(&m);
        assert_eq!(r.delta("samples_in"), 10);
        assert_eq!(r.delta("verdicts_out"), 7);
        assert!(r.rate("samples_in") > 0.0);
        let lat = r.histogram("latency").unwrap();
        assert_eq!(lat.count, 1, "only the in-window recording");
        assert!(r.p99("latency") > 1_000_000);
        // Next window starts clean.
        let r2 = w.tick(&m);
        assert_eq!(r2.delta("samples_in"), 0);
        assert_eq!(r2.histogram("latency").unwrap().count, 0);
        assert_eq!(r2.p99("latency"), 0);
    }

    #[test]
    fn window_covers_every_registry_row() {
        // Sink 3 (windows) must show every registry row.
        let m = ServiceMetrics::default();
        let mut w = MetricsWindow::new(&m);
        let r = w.tick(&m);
        for row in m.registry() {
            let present = match row.value {
                MetricValue::Counter(_) => {
                    r.counters.iter().any(|c| c.name == row.name)
                }
                MetricValue::Gauge(_) => {
                    r.gauges.iter().any(|(n, _)| *n == row.name)
                }
                MetricValue::Histogram(_) => r.histogram(row.name).is_some(),
            };
            assert!(present, "window missing {}", row.name);
        }
    }

    #[test]
    fn window_gauges_are_instantaneous() {
        let m = ServiceMetrics::default();
        m.workers_active.set(4);
        let mut w = MetricsWindow::new(&m);
        m.workers_active.set(6);
        let r = w.tick(&m);
        assert_eq!(r.gauge("workers_active"), 6, "current value, not delta");
        assert_eq!(r.gauge("epoch"), 0);
    }

    #[test]
    fn window_render_mentions_rates() {
        let m = ServiceMetrics::default();
        let mut w = MetricsWindow::new(&m);
        m.samples_in.add(100);
        let line = w.tick(&m).render();
        assert!(line.contains("in="));
        assert!(line.contains("latency_p99="));
    }

    #[test]
    fn shard_window_isolates_intervals_and_ranks_by_recent_load() {
        let sm = ShardMetrics::new(4);
        sm.shard(0).samples.add(1_000); // historic hotspot
        sm.shard(0).latency.record(100);
        let mut w = ShardWindow::new(4);
        // First delta sees lifetime totals (zero baseline)...
        let first = w.delta(&sm);
        assert_eq!(first[0].samples, 1_000);
        // ...then only shard 2 is active in the new window.
        sm.shard(2).samples.add(50);
        sm.shard(2).latency.record(5_000_000);
        let second = w.delta(&sm);
        assert_eq!(second[0].samples, 0, "historic load aged out");
        assert_eq!(second[2].samples, 50);
        assert!(second[2].p99_ns > 1_000_000, "windowed p99");
        assert_eq!(second[0].p99_ns, 0, "idle shard has no window p99");
    }

    #[test]
    fn shard_window_rebaseline_discards_the_open_window() {
        let sm = ShardMetrics::new(2);
        let mut w = ShardWindow::new(2);
        sm.shard(1).samples.add(500);
        w.rebaseline(&sm); // e.g. a migration just rebalanced
        let d = w.delta(&sm);
        assert_eq!(d[1].samples, 0, "pre-rebaseline load not attributed");
    }
}

//! Observability plane: flight recorder, stage-level tracing support,
//! and metric sinks.
//!
//! The paper's evaluation is a static table of throughput/occupation
//! numbers; a long-running service needs the live equivalent. This
//! module is that substrate, in three pillars, all dependency-free:
//!
//! 1. **Flight recorder** ([`recorder`]) — per-thread lock-free ring
//!    journals of typed coordinator events (routing, ring pushes and
//!    stalls, seals/adopts, checkpoints, evictions, epoch swaps,
//!    panics), merged on demand into one nanosecond-stamped timeline.
//!    The counters say a migration happened; the recorder shows the
//!    seal → adopt → stray-replay order it happened in.
//! 2. **Stage-level tracing** — the coordinator threads a submit
//!    timestamp through every `Job` and splits the old end-to-end
//!    latency into queue-wait / engine / emit histograms (plus
//!    fuse/vote time for ensembles). The histograms themselves live in
//!    [`crate::metrics`]; this module gives them windowed views.
//! 3. **Metric sinks** — the `ServiceMetrics` registry feeds three
//!    sinks: the human text (`render()`), the Prometheus exposition
//!    endpoint ([`server::MetricsServer`] serving
//!    [`prometheus::render_prometheus`]), and rolling delta windows
//!    ([`window::MetricsWindow`], [`window::ShardWindow`]) that give
//!    control loops rates-per-interval and windowed p99 instead of
//!    lifetime totals.
//!
//! ## Hot-path discipline
//!
//! The recorder stays off the lock-free per-sample submit path by
//! construction: steady-state single submits record *nothing*, the
//! batched path records one event per worker burst, and only anomalies
//! (ring-full stalls, routing retries) record unconditionally. The
//! `benches/obs.rs` + bench-gate pair holds this to "< 20% regression
//! with the recorder enabled".

pub mod prometheus;
pub mod recorder;
pub mod server;
pub mod window;

pub use prometheus::{escape_label, render_prometheus, CONTENT_TYPE};
pub use recorder::{
    record, recorder, Event, EventKind, FlightRecorder, Journal,
    TaggedEvent, NO_WORKER,
};
pub use server::MetricsServer;
pub use window::{
    MetricsWindow, ShardDelta, ShardWindow, WindowReport, WindowRow,
};

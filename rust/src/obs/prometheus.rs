//! Prometheus text-exposition rendering (format version 0.0.4),
//! dependency-free.
//!
//! Every [`ServiceMetrics`] registry row becomes one metric family:
//! counters and gauges verbatim, histograms as summaries (p50/p95/p99
//! quantiles plus `_sum`/`_count` — the log₂ buckets are an internal
//! layout, quantiles are the portable surface). Ensemble metrics, when
//! attached, add fused totals and one `member="<label>"`-labelled
//! series per member, with label values escaped per the exposition
//! spec.

use crate::metrics::{EnsembleMetrics, Histogram, MetricValue, ServiceMetrics};

/// Content type a conforming scraper expects from `/metrics`.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Prefix applied to every exported family name.
pub const PREFIX: &str = "teda_";

const QUANTILES: [(f64, &str); 3] =
    [(0.50, "0.5"), (0.95, "0.95"), (0.99, "0.99")];

/// Escape a label *value*: `\` → `\\`, `"` → `\"`, newline → `\n`.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape HELP text: `\` → `\\`, newline → `\n` (quotes stay literal).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn family(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {PREFIX}{name} {}\n", escape_help(help)));
    out.push_str(&format!("# TYPE {PREFIX}{name} {kind}\n"));
}

fn summary(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    for (q, qs) in QUANTILES {
        let sep = if labels.is_empty() { "" } else { "," };
        out.push_str(&format!(
            "{PREFIX}{name}{{{labels}{sep}quantile=\"{qs}\"}} {}\n",
            h.quantile(q)
        ));
    }
    let braced = if labels.is_empty() {
        String::new()
    } else {
        format!("{{{labels}}}")
    };
    out.push_str(&format!("{PREFIX}{name}_sum{braced} {}\n", h.sum()));
    out.push_str(&format!("{PREFIX}{name}_count{braced} {}\n", h.count()));
}

/// Render the full exposition body: one family per service registry
/// row, plus the ensemble families when an ensemble is attached.
pub fn render_prometheus(
    service: &ServiceMetrics,
    ensemble: Option<&EnsembleMetrics>,
) -> String {
    let mut out = String::with_capacity(8 * 1024);
    for row in service.registry() {
        match row.value {
            MetricValue::Counter(v) => {
                family(&mut out, row.name, row.help, "counter");
                out.push_str(&format!("{PREFIX}{} {v}\n", row.name));
            }
            MetricValue::Gauge(v) => {
                family(&mut out, row.name, row.help, "gauge");
                out.push_str(&format!("{PREFIX}{} {v}\n", row.name));
            }
            MetricValue::Histogram(h) => {
                family(&mut out, row.name, row.help, "summary");
                summary(&mut out, row.name, "", h);
            }
        }
    }
    if let Some(em) = ensemble {
        for (name, help, v) in [
            (
                "ensemble_fused_verdicts",
                "Fused verdicts emitted.",
                em.fused_verdicts.get(),
            ),
            (
                "ensemble_fused_outliers",
                "Fused verdicts that flagged an outlier.",
                em.fused_outliers.get(),
            ),
            (
                "ensemble_quorum_evictions",
                "Samples evicted because their quorum never completed.",
                em.quorum_evictions.get(),
            ),
        ] {
            family(&mut out, name, help, "counter");
            out.push_str(&format!("{PREFIX}{name} {v}\n"));
        }
        family(
            &mut out,
            "ensemble_fuse_time",
            "Time to fuse one quorum of votes into a verdict.",
            "summary",
        );
        summary(&mut out, "ensemble_fuse_time", "", &em.fuse_time);

        for (name, help) in [
            ("ensemble_member_votes", "Votes this member produced."),
            (
                "ensemble_member_outliers",
                "Votes that flagged an outlier.",
            ),
            (
                "ensemble_member_disagreements",
                "Votes that disagreed with the fused verdict.",
            ),
            (
                "ensemble_member_busy_ns",
                "Wall-clock ns spent inside this member.",
            ),
        ] {
            family(&mut out, name, help, "counter");
            for m in &em.members {
                let v = match name {
                    "ensemble_member_votes" => m.votes.get(),
                    "ensemble_member_outliers" => m.outliers.get(),
                    "ensemble_member_disagreements" => m.disagreements.get(),
                    _ => m.busy_ns.get(),
                };
                out.push_str(&format!(
                    "{PREFIX}{name}{{member=\"{}\"}} {v}\n",
                    escape_label(&m.label)
                ));
            }
        }
        family(
            &mut out,
            "ensemble_member_vote_time",
            "Per-call ingest latency of this member.",
            "summary",
        );
        for m in &em.members {
            summary(
                &mut out,
                "ensemble_member_vote_time",
                &format!("member=\"{}\"", escape_label(&m.label)),
                &m.vote_time,
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Value of a sample line `want <v>` (exact series-name match).
    fn value_of(body: &str, want: &str) -> Option<f64> {
        body.lines().find_map(|l| {
            let (name, v) = l.rsplit_once(' ')?;
            (name == want).then(|| v.parse().ok())?
        })
    }

    #[test]
    fn every_registry_row_is_exposed_with_help_and_type() {
        // Sink 2 (Prometheus) must show every registry row.
        let m = ServiceMetrics::default();
        let body = render_prometheus(&m, None);
        for row in m.registry() {
            let name = format!("{PREFIX}{}", row.name);
            assert!(
                body.contains(&format!("# HELP {name} ")),
                "missing HELP for {name}"
            );
            assert!(
                body.contains(&format!("# TYPE {name} ")),
                "missing TYPE for {name}"
            );
            let kind = match row.value {
                MetricValue::Counter(_) => "counter",
                MetricValue::Gauge(_) => "gauge",
                MetricValue::Histogram(_) => "summary",
            };
            assert!(
                body.contains(&format!("# TYPE {name} {kind}\n")),
                "{name} typed {kind}"
            );
        }
    }

    #[test]
    fn exposition_format_conforms() {
        let m = ServiceMetrics::default();
        m.samples_in.add(42);
        m.epoch.set(7);
        m.latency.record(1_000);
        m.latency.record(3_000);
        let body = render_prometheus(&m, None);

        assert_eq!(value_of(&body, "teda_samples_in"), Some(42.0));
        assert_eq!(value_of(&body, "teda_epoch"), Some(7.0));
        assert_eq!(value_of(&body, "teda_latency_count"), Some(2.0));
        assert_eq!(value_of(&body, "teda_latency_sum"), Some(4_000.0));
        assert!(body.contains("teda_latency{quantile=\"0.5\"}"));
        assert!(body.contains("teda_latency{quantile=\"0.99\"}"));

        // Structural conformance: every non-comment line is
        // `<name>[{labels}] <number>`, names carry the prefix, HELP
        // precedes TYPE precedes samples within each family.
        for line in body.lines() {
            if line.starts_with('#') {
                assert!(
                    line.starts_with("# HELP teda_")
                        || line.starts_with("# TYPE teda_"),
                    "bad comment line: {line}"
                );
                continue;
            }
            let (series, v) = line.rsplit_once(' ').expect("sample line");
            assert!(series.starts_with(PREFIX), "unprefixed: {line}");
            assert!(v.parse::<f64>().is_ok(), "non-numeric value: {line}");
        }
        let help_at = body.find("# HELP teda_samples_in").unwrap();
        let type_at = body.find("# TYPE teda_samples_in").unwrap();
        let sample_at = body.find("\nteda_samples_in 42").unwrap();
        assert!(help_at < type_at && type_at < sample_at);
    }

    #[test]
    fn counters_scrape_monotonically() {
        let m = ServiceMetrics::default();
        m.samples_in.add(5);
        let first = value_of(&render_prometheus(&m, None), "teda_samples_in")
            .unwrap();
        m.samples_in.add(3);
        let second = value_of(&render_prometheus(&m, None), "teda_samples_in")
            .unwrap();
        m.samples_in.inc();
        let third = value_of(&render_prometheus(&m, None), "teda_samples_in")
            .unwrap();
        assert!(first <= second && second <= third);
        assert_eq!(second, 8.0);
        assert_eq!(third, 9.0);
    }

    #[test]
    fn member_labels_are_escaped() {
        let em = EnsembleMetrics::new(vec![
            "weird\"label\\with\nnewline".to_string(),
        ]);
        em.members[0].votes.add(3);
        let m = ServiceMetrics::default();
        let body = render_prometheus(&m, Some(&em));
        assert!(
            body.contains(
                "teda_ensemble_member_votes{member=\"weird\\\"label\\\\with\\nnewline\"} 3"
            ),
            "escaped member label missing:\n{body}"
        );
        assert!(!body.contains("with\nnewline\""), "raw newline leaked");
        // Labelled summaries put the quantile after the member label.
        assert!(body.contains(
            "teda_ensemble_member_vote_time{member=\"weird\\\"label\\\\with\\nnewline\",quantile=\"0.5\"}"
        ));
    }

    #[test]
    fn escape_label_covers_the_spec_triplet() {
        assert_eq!(escape_label(r"a\b"), r"a\\b");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
        assert_eq!(escape_label("plain"), "plain");
    }
}

//! Core TEDA algorithm — Typicality and Eccentricity Data Analytics.
//!
//! Implements Algorithm 1 of the paper via the recursive statistics of
//! Eqs. 1–6:
//!
//! - mean (Eq. 2):        `μ_k = (k-1)/k · μ_{k-1} + 1/k · x_k`
//! - variance (Eq. 3):    `σ²_k = (k-1)/k · σ²_{k-1} + 1/k · ‖x_k − μ_k‖²`
//! - eccentricity (Eq. 1): `ξ_k = 1/k + ‖μ_k − x_k‖² / (k · σ²_k)`
//! - typicality (Eq. 4):  `τ_k = 1 − ξ_k`
//! - normalized ecc (Eq. 5): `ζ_k = ξ_k / 2`
//! - outlier test (Eq. 6, Chebyshev): `ζ_k > (m² + 1) / (2k)`
//!
//! Two entry points:
//! - [`TedaState`] / [`TedaStep`]: the raw recurrence, generic over f32/f64
//!   ([`Real`]), exactly mirroring what the RTL pipeline computes — this is
//!   the bit-level oracle for `rtl`'s pipeline.
//! - [`TedaDetector`]: the user-facing streaming detector (f64, owns its
//!   state, exposes verdicts).

mod detector;
pub mod fixed;
mod state;

pub use detector::{DetectorSnapshot, TedaDetector, Verdict};
pub use fixed::{FixedStep, Q16_16, TedaFixed};
pub use state::{TedaState, TedaStep};

/// Scalar trait for TEDA arithmetic: `f32` (bit-matches the RTL float
/// cores) or `f64` (software reference precision).
///
/// Self-contained stand-in for `num_traits::Float` (crates.io is
/// unavailable in this build environment, DESIGN.md §3): only the
/// operations the recurrence actually needs.
pub trait Real:
    Copy
    + PartialOrd
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::fmt::Debug
    + std::fmt::Display
    + Default
    + Send
    + Sync
    + 'static
{
    /// Additive identity.
    fn zero() -> Self;
    /// Multiplicative identity.
    fn one() -> Self;
    /// Lossless-enough conversion from a sample index.
    fn from_k(k: u64) -> Self;
}

impl Real for f32 {
    #[inline]
    fn zero() -> Self {
        0.0
    }

    #[inline]
    fn one() -> Self {
        1.0
    }

    #[inline]
    fn from_k(k: u64) -> Self {
        k as f32
    }
}

impl Real for f64 {
    #[inline]
    fn zero() -> Self {
        0.0
    }

    #[inline]
    fn one() -> Self {
        1.0
    }

    #[inline]
    fn from_k(k: u64) -> Self {
        k as f64
    }
}

/// The Chebyshev comparison threshold of Eq. 6: `(m² + 1) / (2k)`.
///
/// For `m = 3` this is the `5/k` curve drawn in Figs. 6–7.
#[inline]
pub fn chebyshev_threshold<T: Real>(m: T, k: u64) -> T {
    let two = T::one() + T::one();
    (m * m + T::one()) / (two * T::from_k(k))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chebyshev_m3_is_5_over_k() {
        // The paper plots the m=3 threshold as 5/k (Figs. 6-7 captions).
        for k in 1..2000u64 {
            let t = chebyshev_threshold(3.0f64, k);
            assert!((t - 5.0 / k as f64).abs() < 1e-12, "k={k}");
        }
    }

    #[test]
    fn chebyshev_decreases_with_k() {
        let mut prev = f64::INFINITY;
        for k in 1..100 {
            let t = chebyshev_threshold(3.0f64, k);
            assert!(t < prev);
            prev = t;
        }
    }
}

//! The raw TEDA recurrence: state carry + one-sample step.
//!
//! This module is the *semantic contract* shared by every backend:
//! the software detector, the RTL pipeline simulator, and the Pallas
//! kernel (`python/compile/kernels/teda_kernel.py`) all compute exactly
//! this function. The operation ORDER matches the paper's datapaths
//! (Figs. 2–4) so that an f32 instantiation is bit-comparable with the
//! RTL simulator's float cores.

use super::{chebyshev_threshold, Real};

/// Carried state of one TEDA stream: `(μ_k, σ²_k, k)`.
#[derive(Debug, Clone, PartialEq)]
pub struct TedaState<T: Real> {
    /// Running per-feature mean `μ_k` (length N).
    pub mean: Vec<T>,
    /// Running scalar variance `σ²_k` of Eq. 3.
    pub var: T,
    /// Number of samples absorbed so far (the paper's `k`; 0 = fresh).
    pub k: u64,
}

/// Everything Algorithm 1 produces for one sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TedaStep<T: Real> {
    /// Eccentricity `ξ_k` (Eq. 1).
    pub eccentricity: T,
    /// Typicality `τ_k = 1 − ξ_k` (Eq. 4).
    pub typicality: T,
    /// Normalized eccentricity `ζ_k = ξ_k / 2` (Eq. 5).
    pub zeta: T,
    /// Chebyshev threshold `(m²+1)/(2k)` this sample was compared to.
    pub threshold: T,
    /// `ζ_k > threshold` (Eq. 6). Always `false` for `k = 1`.
    pub outlier: bool,
    /// Squared distance `‖x_k − μ_k‖²` (the VARIANCE module's by-product).
    pub sq_dist: T,
}

impl<T: Real> TedaState<T> {
    /// Fresh state for `n_features`-dimensional samples (`k = 0`).
    pub fn new(n_features: usize) -> Self {
        TedaState { mean: vec![T::zero(); n_features], var: T::zero(), k: 0 }
    }

    /// Number of features N.
    #[inline]
    pub fn n_features(&self) -> usize {
        self.mean.len()
    }

    /// Reset to the fresh (`k = 0`) state without reallocating.
    pub fn reset(&mut self) {
        for m in &mut self.mean {
            *m = T::zero();
        }
        self.var = T::zero();
        self.k = 0;
    }

    /// Absorb one sample `x_k` and classify it (Algorithm 1 lines 3–15).
    ///
    /// Operation order mirrors the RTL datapath:
    /// 1. MEAN module (Fig. 2):  `μ_k = μ_{k-1}·(k-1)/k + x_k·(1/k)`,
    ///    with the k=1 bypass mux (`μ_1 = x_1`).
    /// 2. VARIANCE module (Fig. 3): `d² = Σ (x − μ)·(x − μ)`,
    ///    `σ²_k = σ²_{k-1}·(k-1)/k + d²·(1/k)`, k=1 bypass (`σ²_1 = 0`).
    /// 3. ECCENTRICITY module (Fig. 4): `ξ = 1/k + d² / (σ²·k)`.
    /// 4. OUTLIER module (Fig. 5): `ζ = ξ/2`, compare with Eq. 6.
    ///
    /// # Panics
    /// Panics if `x.len() != self.n_features()`.
    pub fn step(&mut self, x: &[T], m: T) -> TedaStep<T> {
        assert_eq!(
            x.len(),
            self.mean.len(),
            "sample dimension {} != state dimension {}",
            x.len(),
            self.mean.len()
        );
        self.k += 1;
        let k = self.k;
        let kf = T::from_k(k);
        let inv_k = T::one() / kf;
        let ratio = (kf - T::one()) / kf; // (k-1)/k

        if k == 1 {
            // Algorithm 1 lines 3-5: μ_1 ← x_1, σ²_1 ← 0.
            self.mean.copy_from_slice(x);
            self.var = T::zero();
            // ξ_1 = 1/k + 0: with σ² = 0 the paper's Eq. 1 guard
            // ([σ²] > 0) makes the distance term vanish (x₁ == μ₁).
            let ecc = T::one();
            return TedaStep {
                eccentricity: ecc,
                typicality: T::one() - ecc,
                zeta: ecc / (T::one() + T::one()),
                threshold: chebyshev_threshold(m, k),
                outlier: false,
                sq_dist: T::zero(),
            };
        }

        // MEAN module (Eq. 2), elementwise: MMULT1 (μ·(k-1)/k),
        // MMULT2 (x·1/k), MSUM.
        for (mu, &xi) in self.mean.iter_mut().zip(x.iter()) {
            *mu = *mu * ratio + xi * inv_k;
        }

        // VARIANCE module (Eq. 3): VSUBn, VMULT1_n, VSUM1 → d²;
        // then VMULT2 (d²·1/k) + VMULT3 (σ²·(k-1)/k) → VSUM2.
        let mut sq_dist = T::zero();
        for (mu, &xi) in self.mean.iter().zip(x.iter()) {
            let d = xi - *mu;
            sq_dist = sq_dist + d * d;
        }
        self.var = self.var * ratio + sq_dist * inv_k;

        // ECCENTRICITY module (Eq. 1): EMULT1 (σ²·k), EDIV1, ESUM1.
        // Guard [σ²]_k > 0 (identical samples so far): eccentricity
        // degenerates to the uniform 1/k.
        let ecc = if self.var > T::zero() {
            inv_k + sq_dist / (self.var * kf)
        } else {
            inv_k
        };

        // OUTLIER module (Eqs. 5-6): ODIV1 (ξ/2), OCOMP1.
        let two = T::one() + T::one();
        let zeta = ecc / two;
        let threshold = chebyshev_threshold(m, k);
        TedaStep {
            eccentricity: ecc,
            typicality: T::one() - ecc,
            zeta,
            threshold,
            outlier: zeta > threshold,
            sq_dist,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Batch (non-recursive) mean/variance oracle used by the tests.
    fn batch_stats(samples: &[Vec<f64>]) -> (Vec<f64>, f64) {
        let n = samples[0].len();
        let k = samples.len() as f64;
        let mut mean = vec![0.0; n];
        for s in samples {
            for (m, v) in mean.iter_mut().zip(s) {
                *m += v / k;
            }
        }
        // Paper's Eq. 3 unrolls to 1/k · Σ_i ‖x_i − μ_i‖² with the *running*
        // mean μ_i at step i — NOT the textbook batch variance. Check the
        // recursion against its own closed form instead.
        let mut var = 0.0;
        let mut st = TedaState::<f64>::new(n);
        let mut running: Vec<Vec<f64>> = Vec::new();
        for s in samples {
            st.step(s, 3.0);
            running.push(st.mean.clone());
        }
        for (i, s) in samples.iter().enumerate() {
            let d2: f64 = s
                .iter()
                .zip(&running[i])
                .map(|(x, m)| (x - m) * (x - m))
                .sum();
            var += d2;
        }
        (mean, var / k)
    }

    fn gen_samples(seed: u64, count: usize, n: usize) -> Vec<Vec<f64>> {
        let mut rng = crate::util::prng::SplitMix64::new(seed);
        (0..count)
            .map(|_| (0..n).map(|_| rng.next_f64() * 4.0 - 2.0).collect())
            .collect()
    }

    #[test]
    fn recursive_mean_matches_batch_mean() {
        for seed in 0..10u64 {
            let samples = gen_samples(seed, 64, 3);
            let mut st = TedaState::<f64>::new(3);
            for s in &samples {
                st.step(s, 3.0);
            }
            let (mean, _) = batch_stats(&samples);
            for (a, b) in st.mean.iter().zip(&mean) {
                assert!((a - b).abs() < 1e-9, "seed={seed}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn recursive_var_matches_unrolled_recursion() {
        for seed in 0..10u64 {
            let samples = gen_samples(seed, 64, 2);
            let mut st = TedaState::<f64>::new(2);
            for s in &samples {
                st.step(s, 3.0);
            }
            let (_, var) = batch_stats(&samples);
            assert!((st.var - var).abs() < 1e-9, "seed={seed}");
        }
    }

    #[test]
    fn first_sample_is_never_outlier_and_state_matches_alg1() {
        let mut st = TedaState::<f64>::new(2);
        let out = st.step(&[7.5, -3.25], 3.0);
        assert!(!out.outlier);
        assert_eq!(st.mean, vec![7.5, -3.25]); // line 4: μ ← x₁
        assert_eq!(st.var, 0.0); // line 5: σ² ← 0
        assert_eq!(st.k, 1);
    }

    #[test]
    fn identical_samples_variance_stays_negligible() {
        // With identical samples the mean tracks x exactly up to fp
        // rounding of (k-1)/k + 1/k (the paper's MMULT1/MMULT2/MSUM
        // datapath, which we reproduce verbatim); σ² must stay at
        // rounding-noise level and ξ must stay finite. NOTE: in this
        // degenerate zero-variance regime the Eq. 6 test operates on
        // pure rounding noise — the paper's FPGA float cores behave the
        // same way — so no assertion is made on `outlier` here.
        let mut st = TedaState::<f64>::new(3);
        for _ in 0..100 {
            let out = st.step(&[1.0, 2.0, 3.0], 3.0);
            assert!(st.var.abs() < 1e-28, "var={}", st.var);
            assert!(out.eccentricity.is_finite());
        }
        for (mu, x) in st.mean.iter().zip([1.0, 2.0, 3.0]) {
            assert!((mu - x).abs() < 1e-12);
        }
    }

    #[test]
    fn gross_outlier_detected_after_warmup() {
        let mut st = TedaState::<f64>::new(2);
        let mut rng = crate::util::prng::SplitMix64::new(42);
        for _ in 0..200 {
            let x = [rng.next_f64(), rng.next_f64()];
            st.step(&x, 3.0);
        }
        let out = st.step(&[1e3, -1e3], 3.0);
        assert!(out.outlier, "zeta={} thr={}", out.zeta, out.threshold);
    }

    #[test]
    fn eccentricities_sum_to_two_zeta_to_one_with_batch_stats() {
        // Eq. 5's side condition: Σ_i ξ_k(x_i) over the k current samples
        // equals 2 (hence Σ ζ = 1) when ξ is evaluated with the *batch*
        // statistics (μ = batch mean, σ² = (1/k)·Σ‖x_i − μ‖²). TEDA's
        // recursive σ² (Eq. 3) measures distances to the *running* mean,
        // so the identity is exact only in this batch form — which is
        // what we verify here.
        let samples = gen_samples(7, 40, 2);
        let k = samples.len() as f64;
        let n = samples[0].len();
        let mut mean = vec![0.0; n];
        for s in &samples {
            for (m, v) in mean.iter_mut().zip(s) {
                *m += v / k;
            }
        }
        let d2 = |s: &Vec<f64>| -> f64 {
            s.iter().zip(&mean).map(|(x, m)| (x - m) * (x - m)).sum()
        };
        let var: f64 = samples.iter().map(&d2).sum::<f64>() / k;
        let sum: f64 =
            samples.iter().map(|s| 1.0 / k + d2(s) / (k * var)).sum();
        assert!((sum - 2.0).abs() < 1e-9, "sum={sum}");
        // And the recursive σ² is in the same ballpark as the batch σ²
        // (they converge as k grows; exact equality is not expected).
        let mut st = TedaState::<f64>::new(n);
        for s in &samples {
            st.step(s, 3.0);
        }
        assert!(st.var > 0.5 * var && st.var < 2.0 * var);
    }

    #[test]
    fn f32_and_f64_agree_loosely() {
        let samples = gen_samples(3, 256, 2);
        let mut s32 = TedaState::<f32>::new(2);
        let mut s64 = TedaState::<f64>::new(2);
        for s in &samples {
            let x32: Vec<f32> = s.iter().map(|&v| v as f32).collect();
            let a = s32.step(&x32, 3.0);
            let b = s64.step(s, 3.0);
            assert!(
                (a.eccentricity as f64 - b.eccentricity).abs() < 1e-3,
                "k={}",
                s64.k
            );
            assert_eq!(a.outlier, b.outlier, "k={}", s64.k);
        }
    }

    #[test]
    fn reset_reproduces_fresh_run() {
        let samples = gen_samples(9, 32, 4);
        let mut a = TedaState::<f64>::new(4);
        for s in &samples {
            a.step(s, 3.0);
        }
        a.reset();
        let mut b = TedaState::<f64>::new(4);
        for s in &samples {
            let ra = a.step(s, 3.0);
            let rb = b.step(s, 3.0);
            assert_eq!(ra, rb);
        }
    }

    #[test]
    #[should_panic(expected = "sample dimension")]
    fn dimension_mismatch_panics() {
        let mut st = TedaState::<f64>::new(2);
        st.step(&[1.0, 2.0, 3.0], 3.0);
    }
}

//! User-facing streaming TEDA detector.

use super::{TedaState, TedaStep};

/// Classification verdict for one sample, as emitted by [`TedaDetector`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Verdict {
    /// Sample index `k` (1-based, as in the paper).
    pub k: u64,
    /// Eccentricity `ξ_k`.
    pub eccentricity: f64,
    /// Normalized eccentricity `ζ_k`.
    pub zeta: f64,
    /// The `(m²+1)/(2k)` threshold the sample was compared to.
    pub threshold: f64,
    /// `true` iff Algorithm 1 classified the sample as an outlier.
    pub outlier: bool,
}

/// Complete checkpoint of a [`TedaDetector`]: the recurrence carry
/// `(μ_k, σ²_k, k)` **plus** the detection counters. Carrying the
/// counters is what makes failover observably identical to an
/// uninterrupted run — a restore that only moves the state silently
/// resets `n_outliers` to 0 mid-stream.
#[derive(Debug, Clone, PartialEq)]
pub struct DetectorSnapshot {
    /// The TEDA recurrence carry.
    pub state: TedaState<f64>,
    /// Outliers flagged up to and including sample `state.k`.
    pub n_outliers: u64,
    /// Chebyshev multiplier the counters were accumulated under — a
    /// restore into a detector with a different `m` would produce
    /// verdicts matching neither the old run nor a fresh one.
    pub m: f64,
}

/// Streaming TEDA anomaly detector over `R^N` samples (Algorithm 1).
///
/// Owns a [`TedaState<f64>`] plus the comparison threshold `m`, and keeps
/// simple detection counters. This is the reference "software platform"
/// implementation used in the paper's Table 5 comparison, and the oracle
/// against which the RTL and XLA engines are validated.
///
/// ```
/// use teda_fpga::teda::TedaDetector;
/// let mut det = TedaDetector::new(1, 3.0);
/// for _ in 0..50 { det.step(&[0.0]); }
/// assert!(det.step(&[1000.0]).outlier);
/// ```
#[derive(Debug, Clone)]
pub struct TedaDetector {
    state: TedaState<f64>,
    m: f64,
    n_outliers: u64,
}

impl TedaDetector {
    /// New detector for `n_features`-dimensional samples with Chebyshev
    /// multiplier `m` (the paper uses `m = 3`).
    ///
    /// # Panics
    /// Panics if `n_features == 0` or `m <= 0` (Eq. 6 requires `m > 0`).
    pub fn new(n_features: usize, m: f64) -> Self {
        assert!(n_features > 0, "TEDA needs at least one feature");
        assert!(m > 0.0, "Eq. 6 requires m > 0, got {m}");
        TedaDetector { state: TedaState::new(n_features), m, n_outliers: 0 }
    }

    /// Absorb one sample and classify it.
    pub fn step(&mut self, x: &[f64]) -> Verdict {
        let out: TedaStep<f64> = self.state.step(x, self.m);
        if out.outlier {
            self.n_outliers += 1;
        }
        Verdict {
            k: self.state.k,
            eccentricity: out.eccentricity,
            zeta: out.zeta,
            threshold: out.threshold,
            outlier: out.outlier,
        }
    }

    /// Run a whole slice of samples, returning one verdict per sample.
    pub fn run(&mut self, samples: &[Vec<f64>]) -> Vec<Verdict> {
        samples.iter().map(|s| self.step(s)).collect()
    }

    /// Run the recurrence over a run of samples in one tight loop,
    /// handing each verdict to `sink` as it is produced — the
    /// batch-native kernel behind [`crate::engine::Engine::process_batch`].
    /// The caller resolves this detector once per run of consecutive
    /// same-stream samples, so the loop body touches no map and
    /// allocates nothing; verdicts are bit-identical to calling
    /// [`TedaDetector::step`] per sample.
    pub fn run_with<'a, I, F>(&mut self, samples: I, mut sink: F)
    where
        I: IntoIterator<Item = &'a [f64]>,
        F: FnMut(Verdict),
    {
        for x in samples {
            sink(self.step(x));
        }
    }

    /// Samples absorbed so far.
    pub fn k(&self) -> u64 {
        self.state.k
    }

    /// Outliers flagged so far.
    pub fn n_outliers(&self) -> u64 {
        self.n_outliers
    }

    /// Chebyshev multiplier `m`.
    pub fn m(&self) -> f64 {
        self.m
    }

    /// Current running mean (read-only view).
    pub fn mean(&self) -> &[f64] {
        &self.state.mean
    }

    /// Current running variance σ²_k.
    pub fn variance(&self) -> f64 {
        self.state.var
    }

    /// Reset to a fresh stream (keeps N and m).
    pub fn reset(&mut self) {
        self.state.reset();
        self.n_outliers = 0;
    }

    /// Snapshot of the internal state (for checkpointing in the
    /// coordinator's state manager).
    pub fn state(&self) -> &TedaState<f64> {
        &self.state
    }

    /// Full checkpoint: recurrence state **and** detection counters.
    pub fn snapshot(&self) -> DetectorSnapshot {
        DetectorSnapshot {
            state: self.state.clone(),
            n_outliers: self.n_outliers,
            m: self.m,
        }
    }

    /// Restore from a snapshot, counters included.
    ///
    /// # Panics
    /// Panics if the snapshot dimensionality or threshold `m` differs
    /// from this detector's (callers that need a recoverable error
    /// validate first, as [`crate::engine::SoftwareEngine`] does).
    pub fn restore(&mut self, snapshot: DetectorSnapshot) {
        assert_eq!(snapshot.state.n_features(), self.state.n_features());
        assert_eq!(
            snapshot.m, self.m,
            "snapshot was taken under a different threshold m"
        );
        self.state = snapshot.state;
        self.n_outliers = snapshot.n_outliers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_track_outliers() {
        let mut det = TedaDetector::new(1, 3.0);
        let mut rng = crate::util::prng::SplitMix64::new(11);
        for _ in 0..500 {
            det.step(&[rng.next_f64()]);
        }
        let before = det.n_outliers();
        let v = det.step(&[1e6]);
        assert!(v.outlier);
        assert_eq!(det.n_outliers(), before + 1);
        assert_eq!(det.k(), 501);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut a = TedaDetector::new(2, 3.0);
        let mut rng = crate::util::prng::SplitMix64::new(5);
        for _ in 0..100 {
            a.step(&[rng.next_f64(), rng.next_f64()]);
        }
        let snap = a.snapshot();
        let mut b = TedaDetector::new(2, 3.0);
        b.restore(snap);
        assert_eq!(a.n_outliers(), b.n_outliers());
        let x = [0.33, 0.44];
        assert_eq!(a.step(&x), b.step(&x));
    }

    #[test]
    fn restore_carries_counters() {
        // Regression: a restored detector must report the same outlier
        // count as the one it was snapshotted from, not restart at 0.
        let mut a = TedaDetector::new(1, 3.0);
        let mut rng = crate::util::prng::SplitMix64::new(13);
        for _ in 0..300 {
            a.step(&[rng.next_f64()]);
        }
        a.step(&[1e9]); // guaranteed outlier
        assert!(a.n_outliers() > 0);
        let mut b = TedaDetector::new(1, 3.0);
        b.restore(a.snapshot());
        assert_eq!(b.n_outliers(), a.n_outliers());
        assert_eq!(b.k(), a.k());
    }

    #[test]
    #[should_panic(expected = "m > 0")]
    fn zero_m_rejected() {
        TedaDetector::new(1, 0.0);
    }

    #[test]
    fn run_with_matches_step() {
        let samples: Vec<Vec<f64>> =
            (0..48).map(|i| vec![(i % 9) as f64 * 0.3]).collect();
        let mut a = TedaDetector::new(1, 3.0);
        let mut got = Vec::new();
        a.run_with(samples.iter().map(|s| s.as_slice()), |v| got.push(v));
        let mut b = TedaDetector::new(1, 3.0);
        for (s, v) in samples.iter().zip(got) {
            let w = b.step(s);
            assert_eq!(w.zeta.to_bits(), v.zeta.to_bits());
            assert_eq!(w.threshold.to_bits(), v.threshold.to_bits());
            assert_eq!(w, v);
        }
        assert_eq!(a.k(), b.k());
        assert_eq!(a.n_outliers(), b.n_outliers());
    }

    #[test]
    fn run_matches_step() {
        let samples: Vec<Vec<f64>> = (0..32).map(|i| vec![i as f64 * 0.1]).collect();
        let mut a = TedaDetector::new(1, 3.0);
        let verdicts = a.run(&samples);
        let mut b = TedaDetector::new(1, 3.0);
        for (s, v) in samples.iter().zip(verdicts) {
            assert_eq!(b.step(s), v);
        }
    }
}

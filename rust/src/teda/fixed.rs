//! Fixed-point TEDA — the ablation the paper's §5.2.1 invites.
//!
//! The paper implements the datapath in floating point and notes that a
//! fixed-point implementation "demands less hardware resources"; related
//! FPGA detectors ([20], [21] in its bibliography) chose fixed point.
//! This module quantifies the other side of that trade: what a Qm.n
//! datapath does to detection quality.
//!
//! [`Q16_16`] is a 32-bit Q16.16 signed fixed-point scalar with
//! round-to-nearest on multiply/divide (64-bit intermediates, saturating
//! pack — the behaviour of a DSP48E1 multiplier followed by a saturating
//! shift). [`TedaFixed`] runs Algorithm 1 entirely in that format; the
//! `fixed_point_ablation` test (and the EXPERIMENTS.md §Ablations row)
//! compares its flags against the f64 reference on the DAMADICS
//! workload.

/// Q16.16 signed fixed point (range ±32768, resolution ≈ 1.5e-5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Q16_16(pub i32);

impl Q16_16 {
    pub const FRAC_BITS: u32 = 16;
    pub const ONE: Q16_16 = Q16_16(1 << 16);
    pub const ZERO: Q16_16 = Q16_16(0);
    pub const MAX: Q16_16 = Q16_16(i32::MAX);

    /// Quantize an f64 (round-to-nearest, saturating).
    pub fn from_f64(v: f64) -> Self {
        let scaled = (v * (1i64 << Self::FRAC_BITS) as f64).round();
        Q16_16(scaled.clamp(i32::MIN as f64, i32::MAX as f64) as i32)
    }

    /// Back to f64 (exact).
    pub fn to_f64(self) -> f64 {
        self.0 as f64 / (1i64 << Self::FRAC_BITS) as f64
    }

    /// Saturating add.
    #[inline]
    pub fn add(self, rhs: Self) -> Self {
        Q16_16(self.0.saturating_add(rhs.0))
    }

    /// Saturating subtract.
    #[inline]
    pub fn sub(self, rhs: Self) -> Self {
        Q16_16(self.0.saturating_sub(rhs.0))
    }

    /// Round-to-nearest multiply (64-bit intermediate, saturating pack).
    #[inline]
    pub fn mul(self, rhs: Self) -> Self {
        let wide = self.0 as i64 * rhs.0 as i64;
        let rounded = (wide + (1i64 << (Self::FRAC_BITS - 1)))
            >> Self::FRAC_BITS;
        Q16_16(rounded.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
    }

    /// Round-to-nearest divide (returns MAX on division by zero, like a
    /// saturating hardware divider's overflow flag).
    #[inline]
    pub fn div(self, rhs: Self) -> Self {
        if rhs.0 == 0 {
            return if self.0 >= 0 { Self::MAX } else { Q16_16(i32::MIN) };
        }
        let num = (self.0 as i64) << Self::FRAC_BITS;
        let d = rhs.0 as i64;
        // Round half away from zero on magnitudes.
        let neg = (num < 0) != (d < 0);
        let (an, ad) = (num.unsigned_abs(), d.unsigned_abs());
        let q = ((an + ad / 2) / ad) as i64;
        let q = if neg { -q } else { q };
        Q16_16(q.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
    }

    /// Divide by an *integer* (the sample counter k lives in the integer
    /// counter domain — Q16.16 itself saturates at 32 768, far below a
    /// day of samples).
    #[inline]
    pub fn div_int(self, k: u64) -> Self {
        if k == 0 {
            return Self::MAX;
        }
        let num = self.0 as i64;
        let neg = num < 0;
        let q = ((num.unsigned_abs() + k / 2) / k) as i64;
        Q16_16((if neg { -q } else { q }) as i32)
    }

    /// Multiply by an integer, saturating.
    #[inline]
    pub fn mul_int(self, k: u64) -> Self {
        let wide = self.0 as i64 * k as i64;
        Q16_16(wide.clamp(i32::MIN as i64, i32::MAX as i64) as i32)
    }

    /// 1/k as Q16.16 (round-to-nearest).
    #[inline]
    pub fn recip_int(k: u64) -> Self {
        Self::ONE.div_int(k)
    }

    /// (k−1)/k as Q16.16.
    #[inline]
    pub fn ratio_int(k: u64) -> Self {
        if k == 0 {
            return Self::ZERO;
        }
        let num = (k - 1) << Self::FRAC_BITS;
        Q16_16(((num + k / 2) / k).min(i32::MAX as u64) as i32)
    }

    /// Exact halving (arithmetic shift — the ODIV1 analogue).
    #[inline]
    pub fn half(self) -> Self {
        Q16_16(self.0 >> 1)
    }
}

/// TEDA state with the entire datapath in Q16.16.
#[derive(Debug, Clone)]
pub struct TedaFixed {
    mean: Vec<Q16_16>,
    var: Q16_16,
    k: u64,
    m2_plus_1_half: Q16_16, // (m²+1)/2, the OUTLIER-module constant
}

/// One fixed-point step result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FixedStep {
    pub zeta: Q16_16,
    pub threshold: Q16_16,
    pub outlier: bool,
}

impl TedaFixed {
    /// New detector; `m` is quantized once into the threshold constant.
    pub fn new(n_features: usize, m: f64) -> Self {
        assert!(n_features > 0 && m > 0.0);
        TedaFixed {
            mean: vec![Q16_16::ZERO; n_features],
            var: Q16_16::ZERO,
            k: 0,
            m2_plus_1_half: Q16_16::from_f64((m * m + 1.0) * 0.5),
        }
    }

    /// Samples absorbed.
    pub fn k(&self) -> u64 {
        self.k
    }

    /// Algorithm 1 in fixed point (same op order as the RTL datapath).
    pub fn step(&mut self, x: &[f64]) -> FixedStep {
        assert_eq!(x.len(), self.mean.len());
        self.k += 1;
        let k = self.k;
        // k stays in the integer counter domain (a Q16.16 k would
        // saturate at 32 768 — less than half a DAMADICS day).
        let inv_k = Q16_16::recip_int(k);
        let ratio = Q16_16::ratio_int(k);
        let xq: Vec<Q16_16> = x.iter().map(|&v| Q16_16::from_f64(v)).collect();

        if k == 1 {
            self.mean.copy_from_slice(&xq);
            self.var = Q16_16::ZERO;
            return FixedStep {
                zeta: Q16_16::ONE.half(),
                threshold: self.m2_plus_1_half,
                outlier: false,
            };
        }
        for (mu, &xi) in self.mean.iter_mut().zip(&xq) {
            *mu = mu.mul(ratio).add(xi.mul(inv_k));
        }
        let mut sq = Q16_16::ZERO;
        for (mu, &xi) in self.mean.iter().zip(&xq) {
            let d = xi.sub(*mu);
            sq = sq.add(d.mul(d));
        }
        self.var = self.var.mul(ratio).add(sq.mul(inv_k));
        let ecc = if self.var > Q16_16::ZERO {
            inv_k.add(sq.div(self.var.mul_int(k)))
        } else {
            inv_k
        };
        let zeta = ecc.half();
        let threshold = self.m2_plus_1_half.div_int(k);
        FixedStep { zeta, threshold, outlier: zeta > threshold }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::damadics::{schedule_item, ActuatorSim};
    use crate::teda::chebyshev_threshold;
    use crate::teda::TedaDetector;
    use crate::util::prng::SplitMix64;

    #[test]
    fn q16_16_roundtrip_and_arith() {
        let a = Q16_16::from_f64(1.5);
        let b = Q16_16::from_f64(-0.25);
        assert_eq!(a.to_f64(), 1.5);
        assert_eq!(a.mul(b).to_f64(), -0.375);
        assert_eq!(a.add(b).to_f64(), 1.25);
        assert_eq!(a.div(b).to_f64(), -6.0);
        assert_eq!(a.half().to_f64(), 0.75);
    }

    #[test]
    fn q16_16_saturates_not_wraps() {
        let big = Q16_16::from_f64(30000.0);
        assert_eq!(big.mul(big), Q16_16::MAX);
        assert_eq!(Q16_16::ONE.div(Q16_16::ZERO), Q16_16::MAX);
    }

    #[test]
    fn quantization_resolution() {
        // Anything below 2^-17 quantizes to 0 or 1 ulp.
        let tiny = Q16_16::from_f64(1e-6);
        assert!(tiny.0 <= 1);
    }

    #[test]
    fn fixed_point_ablation_flags_against_f64() {
        // The EXPERIMENTS.md §Ablations row: Q16.16 vs f64 on random
        // unit-scale streams. Fixed point must agree on the easy
        // decisions; disagreements concentrate near the threshold.
        let mut fixed = TedaFixed::new(2, 3.0);
        let mut float = TedaDetector::new(2, 3.0);
        let mut rng = SplitMix64::new(17);
        let mut diff = 0u32;
        let total = 5_000u32;
        for _ in 0..total {
            let x = [rng.next_f64(), rng.next_f64()];
            let a = fixed.step(&x);
            let b = float.step(&x);
            if a.outlier != b.outlier {
                diff += 1;
            }
        }
        assert!(
            (diff as f64) < 0.02 * total as f64,
            "fixed/float disagreement {diff}/{total}"
        );
    }

    #[test]
    fn fixed_point_detects_damadics_fault() {
        // The practical question: does the cheaper datapath still catch
        // the paper's faults? (Answer: yes for the abrupt f18 — the
        // eccentricity excursion is far above quantization noise.)
        let event = schedule_item(1).unwrap();
        let trace = ActuatorSim::with_seed(2001).generate_day(Some(&event));
        let mut det = TedaFixed::new(2, 3.0);
        let mut hits = 0;
        for (i, s) in trace.samples.iter().enumerate() {
            let v = det.step(s);
            if v.outlier && event.contains(i) {
                hits += 1;
            }
        }
        assert!(hits > 0, "fixed-point TEDA missed the f18 fault");
    }

    #[test]
    fn fixed_threshold_decays_like_5_over_k() {
        let mut det = TedaFixed::new(1, 3.0);
        for i in 0..100 {
            let v = det.step(&[i as f64 * 0.01]);
            let want = chebyshev_threshold(3.0f64, det.k());
            let got = v.threshold.to_f64();
            assert!(
                (got - want).abs() < 2e-4 + want * 1e-3,
                "k={}: {got} vs {want}",
                det.k()
            );
        }
    }
}

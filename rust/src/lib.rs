//! # teda-fpga — TEDA streaming anomaly detection, three-layer reproduction
//!
//! Reproduction of *"Hardware Architecture Proposal for TEDA algorithm to
//! Data Streaming Anomaly Detection"* (da Silva et al., 2020) as a
//! production-shaped stack:
//!
//! - [`teda`] — the TEDA recurrences (Eqs. 1–6) as a software reference.
//! - [`rtl`] — a cycle-accurate simulator of the paper's pipelined RTL
//!   architecture (Figs. 1–5).
//! - [`synth`] — Virtex-6 resource/timing model regenerating Tables 3–4.
//! - [`damadics`] — a DAMADICS-like actuator/fault simulator (Tables 1–2,
//!   the data behind Figs. 6–7).
//! - [`engine`] — pluggable detector backends: software, RTL-sim, XLA.
//! - [`ensemble`] — multi-detector fusion: N heterogeneous members
//!   (TEDA software/RTL, m·σ, sliding z-score, TEDA `m`-sweeps) behind
//!   one [`engine::Engine`], with pluggable combiners and a Virtex-6
//!   partition/occupation planner ("multiple TEDA modules applied in
//!   parallel", §5.2.1, generalized fSEAD-style).
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifact (L1/L2 live in `python/compile/`).
//! - [`stream`] / [`coordinator`] — the L3 streaming service: sources,
//!   backpressure, routing, dynamic batching, per-stream state.
//! - [`persist`] — durable checkpoint store: versioned binary codec +
//!   atomic-rename file backend, so failover survives full-process
//!   death (`Service::start_from_store`).
//! - [`baselines`] — m-sigma and sliding z-score detectors for comparison.
//! - [`obs`] — observability plane: flight recorder, stage-latency
//!   windows, Prometheus scrape endpoint.
//! - [`metrics`], [`config`], [`util`] — ops surface and support kit.
//!
//! ## Quickstart
//!
//! ```
//! use teda_fpga::teda::TedaDetector;
//!
//! let mut det = TedaDetector::new(2, 3.0); // N=2 features, m=3 threshold
//! for k in 0..100u32 {
//!     let x = [k as f64 * 0.01, 1.0 - k as f64 * 0.01];
//!     let _v = det.step(&x);
//! }
//! let verdict = det.step(&[50.0, -50.0]); // gross outlier
//! assert!(verdict.outlier);
//! ```

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod damadics;
pub mod engine;
pub mod ensemble;
pub mod metrics;
pub mod obs;
pub mod persist;
pub mod rtl;
pub mod runtime;
pub mod stream;
pub mod synth;
pub mod teda;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
///
/// (`Display`/`Error` are hand-implemented: `thiserror` is unavailable
/// in this registry-less build environment, DESIGN.md §3.)
#[derive(Debug)]
pub enum Error {
    /// Errors bubbling out of the PJRT/XLA runtime layer.
    Runtime(String),
    /// Configuration file / CLI parse errors.
    Config(String),
    /// Artifact manifest / HLO loading problems.
    Artifact(String),
    /// Coordinator / streaming errors (closed channels, unknown streams...).
    Stream(String),
    /// RTL netlist construction or simulation errors.
    Rtl(String),
    /// Checkpoint persistence: corrupt/truncated records, foreign
    /// store directories, unsupported format versions.
    Persist(String),
    /// I/O with context.
    Io {
        context: String,
        source: std::io::Error,
    },
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Stream(m) => write!(f, "stream: {m}"),
            Error::Rtl(m) => write!(f, "rtl: {m}"),
            Error::Persist(m) => write!(f, "persist: {m}"),
            Error::Io { context, source } => {
                write!(f, "io: {context}: {source}")
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Wrap an `io::Error` with a human context string.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { context: context.into(), source }
    }
}

//! # teda-fpga — TEDA streaming anomaly detection, three-layer reproduction
//!
//! Reproduction of *"Hardware Architecture Proposal for TEDA algorithm to
//! Data Streaming Anomaly Detection"* (da Silva et al., 2020) as a
//! production-shaped stack:
//!
//! - [`teda`] — the TEDA recurrences (Eqs. 1–6) as a software reference.
//! - [`rtl`] — a cycle-accurate simulator of the paper's pipelined RTL
//!   architecture (Figs. 1–5).
//! - [`synth`] — Virtex-6 resource/timing model regenerating Tables 3–4.
//! - [`damadics`] — a DAMADICS-like actuator/fault simulator (Tables 1–2,
//!   the data behind Figs. 6–7).
//! - [`engine`] — pluggable detector backends: software, RTL-sim, XLA.
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifact (L1/L2 live in `python/compile/`).
//! - [`stream`] / [`coordinator`] — the L3 streaming service: sources,
//!   backpressure, routing, dynamic batching, per-stream state.
//! - [`baselines`] — m-sigma and sliding z-score detectors for comparison.
//! - [`metrics`], [`config`], [`util`] — ops surface and support kit.
//!
//! ## Quickstart
//!
//! ```
//! use teda_fpga::teda::TedaDetector;
//!
//! let mut det = TedaDetector::new(2, 3.0); // N=2 features, m=3 threshold
//! for k in 0..100u32 {
//!     let x = [k as f64 * 0.01, 1.0 - k as f64 * 0.01];
//!     let _v = det.step(&x);
//! }
//! let verdict = det.step(&[50.0, -50.0]); // gross outlier
//! assert!(verdict.outlier);
//! ```

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod damadics;
pub mod engine;
pub mod metrics;
pub mod rtl;
pub mod runtime;
pub mod stream;
pub mod synth;
pub mod teda;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Errors bubbling out of the PJRT/XLA runtime layer.
    #[error("runtime: {0}")]
    Runtime(String),
    /// Configuration file / CLI parse errors.
    #[error("config: {0}")]
    Config(String),
    /// Artifact manifest / HLO loading problems.
    #[error("artifact: {0}")]
    Artifact(String),
    /// Coordinator / streaming errors (closed channels, unknown streams...).
    #[error("stream: {0}")]
    Stream(String),
    /// RTL netlist construction or simulation errors.
    #[error("rtl: {0}")]
    Rtl(String),
    /// I/O with context.
    #[error("io: {context}: {source}")]
    Io {
        context: String,
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    /// Wrap an `io::Error` with a human context string.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { context: context.into(), source }
    }
}

//! Fusion strategies combining per-member votes into one verdict.
//!
//! Semantics (N = member count, `s_i ∈ [-1, 1]` the margin score,
//! `o_i` the hard flag, `w_i > 0` the weight):
//!
//! - **majority** — outlier iff `|{i : o_i}| · 2 > N` (strict; ties
//!   resolve to inlier, biasing toward precision).
//! - **weighted-score** — outlier iff `Σ w_i·s_i > 0` with the *static*
//!   per-member weights from the member specs. Confident members (big
//!   threshold margins) can overrule timid majorities.
//! - **any-of** — OR of the flags: maximum sensitivity, for workloads
//!   where a miss costs more than a false alarm.
//! - **all-of** — AND of the flags: maximum precision.
//! - **adaptive** — weighted *vote* (`Σ w_i·sign(o_i)`) whose weights
//!   are learned online, fSEAD-style: after each fusion, members that
//!   disagreed with the fused verdict decay (`w ← max(w·(1−η), w_min)`)
//!   and members that agreed recover toward 1 (`w ← w + ρ·(1−w)`), so a
//!   detector family that keeps mis-voting on this workload loses its
//!   franchise without ever being silenced permanently. η = 0.1,
//!   ρ = 0.01, w_min = 0.05; weights start at the spec weights.
//!
//! Combiners may be stateful (adaptive), so each engine instance owns
//! its combiner — coordinator shards each adapt to their own streams.

use crate::config::CombinerKind;

use super::member::MemberVote;

/// A fused decision for one sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fused {
    /// The ensemble's verdict.
    pub outlier: bool,
    /// The decision statistic that produced it (combiner-specific:
    /// vote fraction, weighted score...). Diagnostic only.
    pub score: f64,
}

/// A fusion strategy: member votes in (member order), one verdict out.
pub trait Combiner {
    /// Display name for logs/reports.
    fn name(&self) -> &'static str;

    /// Fuse one sample's aligned votes (one per member, member order).
    fn fuse(&mut self, votes: &[MemberVote]) -> Fused;

    /// Current effective member weights (adaptive combiners evolve
    /// them; static ones return the configured weights).
    fn weights(&self) -> Vec<f64>;
}

/// Build the combiner for a roster of `weights.len()` members.
pub fn build_combiner(
    kind: CombinerKind,
    weights: Vec<f64>,
) -> Box<dyn Combiner> {
    match kind {
        CombinerKind::Majority => Box::new(MajorityVote { n: weights.len() }),
        CombinerKind::WeightedScore => Box::new(WeightedScore { weights }),
        CombinerKind::AnyOf => Box::new(AnyOf { n: weights.len() }),
        CombinerKind::AllOf => Box::new(AllOf { n: weights.len() }),
        CombinerKind::Adaptive => Box::new(AdaptiveWeighted::new(weights)),
    }
}

/// Strict majority of hard flags.
pub struct MajorityVote {
    n: usize,
}

impl Combiner for MajorityVote {
    fn name(&self) -> &'static str {
        "majority"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let ayes = votes.iter().filter(|v| v.outlier).count();
        Fused {
            outlier: ayes * 2 > votes.len(),
            score: ayes as f64 / votes.len().max(1) as f64,
        }
    }

    fn weights(&self) -> Vec<f64> {
        vec![1.0; self.n]
    }
}

/// Static-weighted sum of margin scores.
pub struct WeightedScore {
    weights: Vec<f64>,
}

impl Combiner for WeightedScore {
    fn name(&self) -> &'static str {
        "weighted-score"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let score: f64 = votes
            .iter()
            .zip(&self.weights)
            .map(|(v, w)| w * v.score)
            .sum();
        Fused { outlier: score > 0.0, score }
    }

    fn weights(&self) -> Vec<f64> {
        self.weights.clone()
    }
}

/// OR of the flags.
pub struct AnyOf {
    n: usize,
}

impl Combiner for AnyOf {
    fn name(&self) -> &'static str {
        "any-of"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let ayes = votes.iter().filter(|v| v.outlier).count();
        Fused {
            outlier: ayes > 0,
            score: ayes as f64 / votes.len().max(1) as f64,
        }
    }

    fn weights(&self) -> Vec<f64> {
        vec![1.0; self.n]
    }
}

/// AND of the flags.
pub struct AllOf {
    n: usize,
}

impl Combiner for AllOf {
    fn name(&self) -> &'static str {
        "all-of"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let ayes = votes.iter().filter(|v| v.outlier).count();
        Fused {
            outlier: !votes.is_empty() && ayes == votes.len(),
            score: ayes as f64 / votes.len().max(1) as f64,
        }
    }

    fn weights(&self) -> Vec<f64> {
        vec![1.0; self.n]
    }
}

/// Online-weighted vote with multiplicative decay on disagreement.
pub struct AdaptiveWeighted {
    weights: Vec<f64>,
    /// Decay factor η applied to disagreeing members.
    eta: f64,
    /// Recovery rate ρ pulling agreeing members back toward 1.
    rho: f64,
    /// Weight floor: no member is ever fully silenced.
    w_min: f64,
}

impl AdaptiveWeighted {
    /// Start from the spec weights with the documented defaults.
    pub fn new(weights: Vec<f64>) -> Self {
        AdaptiveWeighted { weights, eta: 0.1, rho: 0.01, w_min: 0.05 }
    }
}

impl Combiner for AdaptiveWeighted {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let score: f64 = votes
            .iter()
            .zip(&self.weights)
            .map(|(v, w)| if v.outlier { *w } else { -*w })
            .sum();
        let outlier = score > 0.0;
        // fSEAD-style reweighting against the fused verdict.
        for (v, w) in votes.iter().zip(self.weights.iter_mut()) {
            if v.outlier != outlier {
                *w = (*w * (1.0 - self.eta)).max(self.w_min);
            } else {
                *w += self.rho * (1.0 - *w);
            }
        }
        Fused { outlier, score }
    }

    fn weights(&self) -> Vec<f64> {
        self.weights.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vote(outlier: bool, score: f64) -> MemberVote {
        MemberVote { stream_id: 0, seq: 0, outlier, score, detail: None }
    }

    fn flags(v: &[bool]) -> Vec<MemberVote> {
        v.iter()
            .map(|&o| vote(o, if o { 1.0 } else { -1.0 }))
            .collect()
    }

    #[test]
    fn majority_is_strict() {
        let mut c = build_combiner(CombinerKind::Majority, vec![1.0; 4]);
        assert!(!c.fuse(&flags(&[true, true, false, false])).outlier); // tie
        assert!(c.fuse(&flags(&[true, true, true, false])).outlier);
        let mut c = build_combiner(CombinerKind::Majority, vec![1.0]);
        assert!(c.fuse(&flags(&[true])).outlier);
        assert!(!c.fuse(&flags(&[false])).outlier);
    }

    #[test]
    fn any_and_all() {
        let mut any = build_combiner(CombinerKind::AnyOf, vec![1.0; 3]);
        let mut all = build_combiner(CombinerKind::AllOf, vec![1.0; 3]);
        let one = flags(&[false, true, false]);
        assert!(any.fuse(&one).outlier);
        assert!(!all.fuse(&one).outlier);
        let every = flags(&[true, true, true]);
        assert!(any.fuse(&every).outlier);
        assert!(all.fuse(&every).outlier);
        let none = flags(&[false, false, false]);
        assert!(!any.fuse(&none).outlier);
        assert!(!all.fuse(&none).outlier);
    }

    #[test]
    fn weighted_score_uses_margins_and_weights() {
        // A single confident member outweighs two timid dissenters.
        let mut c =
            build_combiner(CombinerKind::WeightedScore, vec![1.0, 1.0, 1.0]);
        let votes = vec![vote(true, 0.9), vote(false, -0.3), vote(false, -0.3)];
        assert!(c.fuse(&votes).outlier);
        // Downweighting the confident member flips the verdict.
        let mut c =
            build_combiner(CombinerKind::WeightedScore, vec![0.5, 1.0, 1.0]);
        assert!(!c.fuse(&votes).outlier);
    }

    #[test]
    fn adaptive_decays_persistent_dissenters() {
        let mut c = AdaptiveWeighted::new(vec![1.0, 1.0, 1.0]);
        // Member 2 keeps disagreeing with the (majority) fused verdict.
        for _ in 0..50 {
            c.fuse(&flags(&[false, false, true]));
        }
        let w = c.weights();
        assert!(w[2] < 0.1, "dissenter weight {}", w[2]);
        assert!(w[0] > 0.9 && w[1] > 0.9);
        // Floor: never silenced entirely.
        assert!(w[2] >= 0.05);
        // After decay, the dissenter alone can no longer flip a fusion
        // even if the others are split... (2 members, one decayed)
        let mut c2 = AdaptiveWeighted::new(vec![1.0, 0.05]);
        assert!(!c2.fuse(&flags(&[false, true])).outlier);
    }

    #[test]
    fn adaptive_agreeing_members_recover() {
        let mut c = AdaptiveWeighted::new(vec![0.5, 1.0, 1.0]);
        for _ in 0..400 {
            c.fuse(&flags(&[false, false, false]));
        }
        assert!(c.weights()[0] > 0.95, "w0={}", c.weights()[0]);
    }

    #[test]
    fn fused_score_is_reported() {
        let mut c = build_combiner(CombinerKind::Majority, vec![1.0; 4]);
        let f = c.fuse(&flags(&[true, true, true, false]));
        assert!((f.score - 0.75).abs() < 1e-12);
    }
}

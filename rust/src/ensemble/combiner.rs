//! Fusion strategies combining per-member votes into one verdict.
//!
//! Semantics (N = member count, `s_i ∈ [-1, 1]` the margin score,
//! `o_i` the hard flag, `w_i > 0` the weight):
//!
//! - **majority** — outlier iff `|{i : o_i}| · 2 > N` (strict; ties
//!   resolve to inlier, biasing toward precision).
//! - **weighted-score** — outlier iff `Σ w_i·s_i > 0` with the *static*
//!   per-member weights from the member specs. Confident members (big
//!   threshold margins) can overrule timid majorities.
//! - **any-of** — OR of the flags: maximum sensitivity, for workloads
//!   where a miss costs more than a false alarm.
//! - **all-of** — AND of the flags: maximum precision.
//! - **adaptive** — weighted *vote* (`Σ w_i·sign(o_i)`) whose weights
//!   are learned online, fSEAD-style: after each fusion, members that
//!   disagreed with the fused verdict decay (`w ← max(w·(1−η), w_min)`)
//!   and members that agreed recover toward 1 (`w ← w + ρ·(1−w)`), so a
//!   detector family that keeps mis-voting on this workload loses its
//!   franchise without ever being silenced permanently. η = 0.1,
//!   ρ = 0.01, w_min = 0.05; weights start at the spec weights.
//!
//! Adaptive weights are **per stream** (lazily initialized from the
//! spec weights on a stream's first fusion): interleaved streams with
//! different regimes must not cross-contaminate each other's decay —
//! a member that mis-votes on a noisy stream keeps its full franchise
//! on a calm one. Per-stream weights are also exactly what failover
//! must checkpoint, so the [`Combiner`] trait exposes them via
//! [`Combiner::stream_weights`] / [`Combiner::set_stream_weights`].
//!
//! Combiners may be stateful (adaptive), so each engine instance owns
//! its combiner — coordinator shards each adapt to their own streams.

use std::collections::HashMap;

use crate::config::CombinerKind;

use super::member::MemberVote;

/// A fused decision for one sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fused {
    /// The ensemble's verdict.
    pub outlier: bool,
    /// The decision statistic that produced it (combiner-specific:
    /// vote fraction, weighted score...). Diagnostic only.
    pub score: f64,
}

/// A fusion strategy: member votes in (member order), one verdict out.
pub trait Combiner {
    /// Display name for logs/reports.
    fn name(&self) -> &'static str;

    /// Fuse one sample's aligned votes (one per member, member order).
    /// Stateful combiners key their state on the votes' stream id.
    fn fuse(&mut self, votes: &[MemberVote]) -> Fused;

    /// The configured (initial) member weights.
    fn weights(&self) -> Vec<f64>;

    /// Effective weights for one stream. Adaptive combiners evolve
    /// these independently per stream; stateless combiners return the
    /// configured weights.
    fn stream_weights(&self, stream_id: u64) -> Vec<f64> {
        let _ = stream_id;
        self.weights()
    }

    /// Restore one stream's learned weights (checkpoint/failover hook;
    /// no-op for stateless combiners).
    fn set_stream_weights(&mut self, stream_id: u64, weights: Vec<f64>) {
        let _ = (stream_id, weights);
    }

    /// Drop a finished stream's learned state (no-op when stateless).
    fn evict_stream(&mut self, stream_id: u64) {
        let _ = stream_id;
    }
}

/// Build the combiner for a roster of `weights.len()` members.
pub fn build_combiner(
    kind: CombinerKind,
    weights: Vec<f64>,
) -> Box<dyn Combiner> {
    match kind {
        CombinerKind::Majority => Box::new(MajorityVote { n: weights.len() }),
        CombinerKind::WeightedScore => Box::new(WeightedScore { weights }),
        CombinerKind::AnyOf => Box::new(AnyOf { n: weights.len() }),
        CombinerKind::AllOf => Box::new(AllOf { n: weights.len() }),
        CombinerKind::Adaptive => Box::new(AdaptiveWeighted::new(weights)),
    }
}

/// Strict majority of hard flags.
pub struct MajorityVote {
    n: usize,
}

impl Combiner for MajorityVote {
    fn name(&self) -> &'static str {
        "majority"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let ayes = votes.iter().filter(|v| v.outlier).count();
        Fused {
            outlier: ayes * 2 > votes.len(),
            score: ayes as f64 / votes.len().max(1) as f64,
        }
    }

    fn weights(&self) -> Vec<f64> {
        vec![1.0; self.n]
    }
}

/// Static-weighted sum of margin scores.
pub struct WeightedScore {
    weights: Vec<f64>,
}

impl Combiner for WeightedScore {
    fn name(&self) -> &'static str {
        "weighted-score"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let score: f64 = votes
            .iter()
            .zip(&self.weights)
            .map(|(v, w)| w * v.score)
            .sum();
        Fused { outlier: score > 0.0, score }
    }

    fn weights(&self) -> Vec<f64> {
        self.weights.clone()
    }
}

/// OR of the flags.
pub struct AnyOf {
    n: usize,
}

impl Combiner for AnyOf {
    fn name(&self) -> &'static str {
        "any-of"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let ayes = votes.iter().filter(|v| v.outlier).count();
        Fused {
            outlier: ayes > 0,
            score: ayes as f64 / votes.len().max(1) as f64,
        }
    }

    fn weights(&self) -> Vec<f64> {
        vec![1.0; self.n]
    }
}

/// AND of the flags.
pub struct AllOf {
    n: usize,
}

impl Combiner for AllOf {
    fn name(&self) -> &'static str {
        "all-of"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        let ayes = votes.iter().filter(|v| v.outlier).count();
        Fused {
            outlier: !votes.is_empty() && ayes == votes.len(),
            score: ayes as f64 / votes.len().max(1) as f64,
        }
    }

    fn weights(&self) -> Vec<f64> {
        vec![1.0; self.n]
    }
}

/// Online-weighted vote with multiplicative decay on disagreement.
///
/// Weights are per stream: each stream's vector starts from the spec
/// weights on its first fusion and then evolves only on that stream's
/// samples.
pub struct AdaptiveWeighted {
    /// Spec weights every new stream starts from.
    initial: Vec<f64>,
    /// Learned per-stream weights, lazily initialized from `initial`.
    streams: HashMap<u64, Vec<f64>>,
    /// Decay factor η applied to disagreeing members.
    eta: f64,
    /// Recovery rate ρ pulling agreeing members back toward 1.
    rho: f64,
    /// Weight floor: no member is ever fully silenced.
    w_min: f64,
}

impl AdaptiveWeighted {
    /// Start from the spec weights with the documented defaults.
    pub fn new(weights: Vec<f64>) -> Self {
        AdaptiveWeighted {
            initial: weights,
            streams: HashMap::new(),
            eta: 0.1,
            rho: 0.01,
            w_min: 0.05,
        }
    }
}

impl Combiner for AdaptiveWeighted {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn fuse(&mut self, votes: &[MemberVote]) -> Fused {
        // Votes are aligned per sample, so every vote carries the same
        // stream id; the engine never fuses an empty quorum.
        let sid = votes[0].stream_id;
        let (eta, rho, w_min) = (self.eta, self.rho, self.w_min);
        let weights = self
            .streams
            .entry(sid)
            .or_insert_with(|| self.initial.clone());
        let score: f64 = votes
            .iter()
            .zip(weights.iter())
            .map(|(v, w)| if v.outlier { *w } else { -*w })
            .sum();
        let outlier = score > 0.0;
        // fSEAD-style reweighting against the fused verdict.
        for (v, w) in votes.iter().zip(weights.iter_mut()) {
            if v.outlier != outlier {
                *w = (*w * (1.0 - eta)).max(w_min);
            } else {
                *w += rho * (1.0 - *w);
            }
        }
        Fused { outlier, score }
    }

    fn weights(&self) -> Vec<f64> {
        self.initial.clone()
    }

    fn stream_weights(&self, stream_id: u64) -> Vec<f64> {
        self.streams
            .get(&stream_id)
            .cloned()
            .unwrap_or_else(|| self.initial.clone())
    }

    fn set_stream_weights(&mut self, stream_id: u64, weights: Vec<f64>) {
        debug_assert_eq!(weights.len(), self.initial.len());
        self.streams.insert(stream_id, weights);
    }

    fn evict_stream(&mut self, stream_id: u64) {
        self.streams.remove(&stream_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vote(outlier: bool, score: f64) -> MemberVote {
        MemberVote { stream_id: 0, seq: 0, outlier, score, detail: None }
    }

    fn flags(v: &[bool]) -> Vec<MemberVote> {
        v.iter()
            .map(|&o| vote(o, if o { 1.0 } else { -1.0 }))
            .collect()
    }

    fn flags_on(stream_id: u64, v: &[bool]) -> Vec<MemberVote> {
        v.iter()
            .map(|&o| MemberVote {
                stream_id,
                seq: 0,
                outlier: o,
                score: if o { 1.0 } else { -1.0 },
                detail: None,
            })
            .collect()
    }

    #[test]
    fn majority_is_strict() {
        let mut c = build_combiner(CombinerKind::Majority, vec![1.0; 4]);
        assert!(!c.fuse(&flags(&[true, true, false, false])).outlier); // tie
        assert!(c.fuse(&flags(&[true, true, true, false])).outlier);
        let mut c = build_combiner(CombinerKind::Majority, vec![1.0]);
        assert!(c.fuse(&flags(&[true])).outlier);
        assert!(!c.fuse(&flags(&[false])).outlier);
    }

    #[test]
    fn any_and_all() {
        let mut any = build_combiner(CombinerKind::AnyOf, vec![1.0; 3]);
        let mut all = build_combiner(CombinerKind::AllOf, vec![1.0; 3]);
        let one = flags(&[false, true, false]);
        assert!(any.fuse(&one).outlier);
        assert!(!all.fuse(&one).outlier);
        let every = flags(&[true, true, true]);
        assert!(any.fuse(&every).outlier);
        assert!(all.fuse(&every).outlier);
        let none = flags(&[false, false, false]);
        assert!(!any.fuse(&none).outlier);
        assert!(!all.fuse(&none).outlier);
    }

    #[test]
    fn weighted_score_uses_margins_and_weights() {
        // A single confident member outweighs two timid dissenters.
        let mut c =
            build_combiner(CombinerKind::WeightedScore, vec![1.0, 1.0, 1.0]);
        let votes = vec![vote(true, 0.9), vote(false, -0.3), vote(false, -0.3)];
        assert!(c.fuse(&votes).outlier);
        // Downweighting the confident member flips the verdict.
        let mut c =
            build_combiner(CombinerKind::WeightedScore, vec![0.5, 1.0, 1.0]);
        assert!(!c.fuse(&votes).outlier);
    }

    #[test]
    fn adaptive_decays_persistent_dissenters() {
        let mut c = AdaptiveWeighted::new(vec![1.0, 1.0, 1.0]);
        // Member 2 keeps disagreeing with the (majority) fused verdict.
        for _ in 0..50 {
            c.fuse(&flags(&[false, false, true]));
        }
        let w = c.stream_weights(0);
        assert!(w[2] < 0.1, "dissenter weight {}", w[2]);
        assert!(w[0] > 0.9 && w[1] > 0.9);
        // Floor: never silenced entirely.
        assert!(w[2] >= 0.05);
        // The configured weights are untouched by learning.
        assert_eq!(c.weights(), vec![1.0, 1.0, 1.0]);
        // After decay, the dissenter alone can no longer flip a fusion
        // even if the others are split... (2 members, one decayed)
        let mut c2 = AdaptiveWeighted::new(vec![1.0, 0.05]);
        assert!(!c2.fuse(&flags(&[false, true])).outlier);
    }

    #[test]
    fn adaptive_agreeing_members_recover() {
        let mut c = AdaptiveWeighted::new(vec![0.5, 1.0, 1.0]);
        for _ in 0..400 {
            c.fuse(&flags(&[false, false, false]));
        }
        let w = c.stream_weights(0);
        assert!(w[0] > 0.95, "w0={}", w[0]);
    }

    #[test]
    fn adaptive_weights_are_per_stream() {
        // Stream 0's dissenter decays; stream 1 (where the same member
        // always agrees) must keep it at full weight — no cross-stream
        // contamination.
        let mut c = AdaptiveWeighted::new(vec![1.0, 1.0, 1.0]);
        for _ in 0..50 {
            c.fuse(&flags_on(0, &[false, false, true]));
            c.fuse(&flags_on(1, &[false, false, false]));
        }
        assert!(c.stream_weights(0)[2] < 0.1);
        assert!(c.stream_weights(1)[2] >= 1.0 - 1e-9);
        // Unknown streams report the initial weights.
        assert_eq!(c.stream_weights(42), vec![1.0, 1.0, 1.0]);
        // Eviction forgets the learned vector.
        c.evict_stream(0);
        assert_eq!(c.stream_weights(0), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn adaptive_weights_restore_roundtrip() {
        // Checkpoint/restore: a fresh combiner seeded with a stream's
        // exported weights continues fusing identically.
        let mut a = AdaptiveWeighted::new(vec![1.0, 1.0]);
        for _ in 0..30 {
            a.fuse(&flags_on(7, &[true, false]));
        }
        let mut b = AdaptiveWeighted::new(vec![1.0, 1.0]);
        b.set_stream_weights(7, a.stream_weights(7));
        for _ in 0..10 {
            let fa = a.fuse(&flags_on(7, &[true, false]));
            let fb = b.fuse(&flags_on(7, &[true, false]));
            assert_eq!(fa, fb);
        }
        assert_eq!(a.stream_weights(7), b.stream_weights(7));
    }

    #[test]
    fn fused_score_is_reported() {
        let mut c = build_combiner(CombinerKind::Majority, vec![1.0; 4]);
        let f = c.fuse(&flags(&[true, true, true, false]));
        assert!((f.score - 0.75).abs() < 1e-12);
    }
}

//! Ensemble member adapter: one uniform wrapper around any
//! [`Engine`] or [`AnomalyDetector`], with per-member state and
//! latency accounting.
//!
//! Engine-backed members (TEDA software / RTL-sim) emit full
//! [`EngineVerdict`]s and a *margin score*; baseline members (m·σ,
//! sliding z-score) keep one detector per stream and emit hard ±1
//! votes. Either way the ensemble sees the same [`MemberVote`].

use std::collections::HashMap;
use std::time::Instant;

use crate::baselines::{AnomalyDetector, MSigmaDetector, SlidingZScore};
use crate::config::{MemberKind, MemberSpec};
use crate::engine::{Engine, EngineVerdict, RtlEngine, Snapshot, SoftwareEngine};
use crate::stream::Sample;
use crate::{Error, Result};

/// One member's opinion about one sample.
#[derive(Debug, Clone, PartialEq)]
pub struct MemberVote {
    pub stream_id: u64,
    pub seq: u64,
    /// The member's hard outlier flag.
    pub outlier: bool,
    /// Signed, scale-free confidence in `[-1, 1]`: positive votes
    /// outlier. TEDA members report the relative threshold margin
    /// `(ζ − thr) / thr` (clamped); baselines report ±1.
    pub score: f64,
    /// Full TEDA verdict when the member computes one (engine-backed
    /// members); `None` for boolean baselines.
    pub detail: Option<EngineVerdict>,
}

/// Cumulative per-member accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemberStats {
    /// Votes produced.
    pub votes: u64,
    /// Votes that flagged an outlier.
    pub outliers: u64,
    /// Wall-clock ns spent inside this member's ingest/flush calls.
    pub busy_ns: u64,
}

/// Checkpoint of one member's state for ONE stream.
///
/// Engine-backed members reuse the engine-level [`Snapshot`]; baseline
/// members are plain-data recursions, so their snapshot is a value copy
/// of the per-stream detector itself.
#[derive(Debug, Clone, PartialEq)]
pub enum MemberSnapshot {
    /// TEDA software / RTL-sim member ([`Snapshot::Software`] /
    /// [`Snapshot::Rtl`]).
    Engine(Snapshot),
    /// Running m·σ baseline state.
    MSigma(MSigmaDetector),
    /// Sliding z-score baseline state (window buffer included).
    ZScore(SlidingZScore),
}

enum MemberImpl {
    /// Full multi-stream engine (TEDA software / RTL-sim).
    Engine(Box<dyn Engine>),
    /// Per-stream boolean baseline detectors, created on first sample.
    /// Concrete types (not `dyn AnomalyDetector`) so checkpointing can
    /// value-copy their state.
    MSigma(HashMap<u64, MSigmaDetector>),
    ZScore(HashMap<u64, SlidingZScore>),
}

/// A detector enrolled in an ensemble: uniform ingest/flush surface
/// plus latency/vote accounting, whatever the backing implementation.
pub struct EnsembleMember {
    spec: MemberSpec,
    n_features: usize,
    imp: MemberImpl,
    stats: MemberStats,
}

impl EnsembleMember {
    /// Instantiate a member from its spec for `n_features`-dim streams.
    pub fn build(spec: &MemberSpec, n_features: usize) -> Self {
        let imp = match spec.kind {
            MemberKind::TedaSoftware => MemberImpl::Engine(Box::new(
                SoftwareEngine::new(n_features, spec.m),
            )),
            MemberKind::TedaRtl => MemberImpl::Engine(Box::new(
                RtlEngine::new(n_features, spec.m),
            )),
            MemberKind::MSigma => MemberImpl::MSigma(HashMap::new()),
            MemberKind::ZScore => MemberImpl::ZScore(HashMap::new()),
        };
        EnsembleMember {
            spec: spec.clone(),
            n_features,
            imp,
            stats: MemberStats::default(),
        }
    }

    /// The spec this member was built from.
    pub fn spec(&self) -> &MemberSpec {
        &self.spec
    }

    /// Display label (`"teda(m=3)"`, `"zscore(m=3,w=64)"`, ...).
    pub fn label(&self) -> String {
        self.spec.label()
    }

    /// Cumulative accounting snapshot.
    pub fn stats(&self) -> MemberStats {
        self.stats
    }

    /// Static fusion weight from the spec.
    pub fn weight(&self) -> f64 {
        self.spec.weight
    }

    /// Absorb one sample; returns this member's votes that became ready
    /// (engine-backed members may answer for earlier samples — the RTL
    /// pipeline has 2-cycle latency — or not at all yet).
    pub fn ingest(&mut self, sample: &Sample) -> Result<Vec<MemberVote>> {
        let t0 = Instant::now();
        let n = self.n_features;
        let spec = &self.spec;
        let votes = match &mut self.imp {
            MemberImpl::Engine(eng) => {
                let verdicts = eng.ingest(sample)?;
                verdicts.into_iter().map(vote_from_verdict).collect()
            }
            MemberImpl::MSigma(streams) => {
                let det = streams
                    .entry(sample.stream_id)
                    .or_insert_with(|| MSigmaDetector::new(n, spec.m));
                vec![baseline_vote(sample, det.step(&sample.values))]
            }
            MemberImpl::ZScore(streams) => {
                let det = streams.entry(sample.stream_id).or_insert_with(
                    || SlidingZScore::new(n, spec.m, spec.window),
                );
                vec![baseline_vote(sample, det.step(&sample.values))]
            }
        };
        self.account(t0, &votes);
        Ok(votes)
    }

    /// Batch-native ingest: one pass over a whole burst, resolving each
    /// stream's detector once per run of consecutive same-stream
    /// samples. Votes are bit-identical to calling
    /// [`EnsembleMember::ingest`] per sample in order; only the
    /// accounting granularity changes (`busy_ns` accrues one elapsed
    /// interval per burst instead of one per sample).
    pub fn ingest_batch(
        &mut self,
        samples: &[Sample],
    ) -> Result<Vec<MemberVote>> {
        let t0 = Instant::now();
        let n = self.n_features;
        let spec = &self.spec;
        let mut votes = Vec::with_capacity(samples.len());
        match &mut self.imp {
            MemberImpl::Engine(eng) => {
                let mut verdicts = Vec::with_capacity(samples.len());
                eng.process_batch(samples, &mut verdicts)?;
                votes.extend(verdicts.into_iter().map(vote_from_verdict));
            }
            MemberImpl::MSigma(streams) => baseline_batch(
                streams,
                samples,
                || MSigmaDetector::new(n, spec.m),
                &mut votes,
            ),
            MemberImpl::ZScore(streams) => baseline_batch(
                streams,
                samples,
                || SlidingZScore::new(n, spec.m, spec.window),
                &mut votes,
            ),
        }
        self.account(t0, &votes);
        Ok(votes)
    }

    /// Force out everything pending (end of stream).
    pub fn flush(&mut self) -> Result<Vec<MemberVote>> {
        let t0 = Instant::now();
        let votes = match &mut self.imp {
            MemberImpl::Engine(eng) => eng
                .flush()?
                .into_iter()
                .map(vote_from_verdict)
                .collect(),
            // Baselines answer immediately — nothing ever pends.
            MemberImpl::MSigma(_) | MemberImpl::ZScore(_) => Vec::new(),
        };
        self.account(t0, &votes);
        Ok(votes)
    }

    /// Streams with in-flight state.
    pub fn active_streams(&self) -> usize {
        match &self.imp {
            MemberImpl::Engine(eng) => eng.active_streams(),
            MemberImpl::MSigma(streams) => streams.len(),
            MemberImpl::ZScore(streams) => streams.len(),
        }
    }

    /// Checkpoint this member's state for one stream (`None` until the
    /// member has seen the stream).
    pub fn snapshot(&self, stream_id: u64) -> Option<MemberSnapshot> {
        match &self.imp {
            MemberImpl::Engine(eng) => {
                eng.snapshot(stream_id).map(MemberSnapshot::Engine)
            }
            MemberImpl::MSigma(streams) => streams
                .get(&stream_id)
                .cloned()
                .map(MemberSnapshot::MSigma),
            MemberImpl::ZScore(streams) => streams
                .get(&stream_id)
                .cloned()
                .map(MemberSnapshot::ZScore),
        }
    }

    /// Restore one stream's state from a snapshot taken by a member of
    /// the same kind.
    pub fn restore(
        &mut self,
        stream_id: u64,
        snapshot: MemberSnapshot,
    ) -> Result<()> {
        match (&mut self.imp, snapshot) {
            (MemberImpl::Engine(eng), MemberSnapshot::Engine(s)) => {
                eng.restore(stream_id, s)
            }
            (MemberImpl::MSigma(streams), MemberSnapshot::MSigma(det)) => {
                streams.insert(stream_id, det);
                Ok(())
            }
            (MemberImpl::ZScore(streams), MemberSnapshot::ZScore(det)) => {
                streams.insert(stream_id, det);
                Ok(())
            }
            _ => Err(Error::Stream(format!(
                "member snapshot kind does not match member '{}'",
                self.label()
            ))),
        }
    }

    /// Drop this member's state for one finished stream.
    pub fn evict(&mut self, stream_id: u64) {
        match &mut self.imp {
            MemberImpl::Engine(eng) => eng.evict(stream_id),
            MemberImpl::MSigma(streams) => {
                streams.remove(&stream_id);
            }
            MemberImpl::ZScore(streams) => {
                streams.remove(&stream_id);
            }
        }
    }

    fn account(&mut self, t0: Instant, votes: &[MemberVote]) {
        self.stats.busy_ns += t0.elapsed().as_nanos() as u64;
        self.stats.votes += votes.len() as u64;
        self.stats.outliers +=
            votes.iter().filter(|v| v.outlier).count() as u64;
    }
}

/// Run-coalesced batch kernel for the per-stream baseline maps: one
/// map resolution per run of consecutive same-stream samples.
fn baseline_batch<D: AnomalyDetector>(
    streams: &mut HashMap<u64, D>,
    samples: &[Sample],
    mut make: impl FnMut() -> D,
    votes: &mut Vec<MemberVote>,
) {
    for run in crate::engine::runs(samples) {
        let det = streams
            .entry(run[0].stream_id)
            .or_insert_with(&mut make);
        for sample in run {
            votes.push(baseline_vote(sample, det.step(&sample.values)));
        }
    }
}

/// Hard ±1 vote for a baseline member's boolean flag.
fn baseline_vote(sample: &Sample, outlier: bool) -> MemberVote {
    MemberVote {
        stream_id: sample.stream_id,
        seq: sample.seq,
        outlier,
        score: if outlier { 1.0 } else { -1.0 },
        detail: None,
    }
}

/// Relative threshold margin → `[-1, 1]` score (NaN-safe: the RTL
/// pipeline reports ζ₁ = NaN, which must not poison weighted sums).
fn vote_from_verdict(v: EngineVerdict) -> MemberVote {
    let margin = (v.zeta - v.threshold) / v.threshold;
    let score = if margin.is_finite() {
        margin.clamp(-1.0, 1.0)
    } else {
        0.0
    };
    MemberVote {
        stream_id: v.stream_id,
        seq: v.seq,
        outlier: v.outlier,
        score,
        detail: Some(v),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(sid: u64, seq: u64, v: f64) -> Sample {
        Sample { stream_id: sid, seq, values: vec![v, -v] }
    }

    #[test]
    fn software_member_votes_immediately_with_detail() {
        let spec: MemberSpec = "teda:m=3".parse().unwrap();
        let mut member = EnsembleMember::build(&spec, 2);
        let votes = member.ingest(&sample(7, 0, 0.5)).unwrap();
        assert_eq!(votes.len(), 1);
        assert_eq!(votes[0].stream_id, 7);
        assert_eq!(votes[0].seq, 0);
        assert!(votes[0].detail.is_some());
        assert!(!votes[0].outlier); // k=1 is never an outlier
        assert!(member.flush().unwrap().is_empty());
        assert_eq!(member.stats().votes, 1);
        assert_eq!(member.active_streams(), 1);
    }

    #[test]
    fn rtl_member_votes_arrive_after_pipeline_latency() {
        let spec: MemberSpec = "rtl:m=3".parse().unwrap();
        let mut member = EnsembleMember::build(&spec, 2);
        let mut got = 0;
        for seq in 0..5u64 {
            got += member
                .ingest(&sample(1, seq, 0.1 * seq as f64))
                .unwrap()
                .len();
        }
        assert!(got < 5, "RTL latency should delay some votes");
        got += member.flush().unwrap().len();
        assert_eq!(got, 5, "flush must emit the tail");
    }

    #[test]
    fn baseline_member_is_per_stream() {
        let spec: MemberSpec = "msigma:m=3".parse().unwrap();
        let mut member = EnsembleMember::build(&spec, 1);
        // Stream 0 near 0, stream 1 near 100.
        for seq in 0..200u64 {
            member
                .ingest(&Sample {
                    stream_id: 0,
                    seq,
                    values: vec![(seq % 5) as f64 * 0.01],
                })
                .unwrap();
            member
                .ingest(&Sample {
                    stream_id: 1,
                    seq,
                    values: vec![100.0 + (seq % 5) as f64 * 0.01],
                })
                .unwrap();
        }
        assert_eq!(member.active_streams(), 2);
        let v0 = member
            .ingest(&Sample { stream_id: 0, seq: 200, values: vec![100.0] })
            .unwrap();
        let v1 = member
            .ingest(&Sample { stream_id: 1, seq: 200, values: vec![100.0] })
            .unwrap();
        assert!(v0[0].outlier && v0[0].score == 1.0);
        assert!(!v1[0].outlier && v1[0].score == -1.0);
        assert!(v0[0].detail.is_none());
    }

    #[test]
    fn margin_score_is_clamped_and_signed() {
        let v = EngineVerdict {
            stream_id: 0,
            seq: 9,
            k: 10,
            eccentricity: 1.0,
            zeta: 0.5,
            threshold: 0.1,
            outlier: true,
        };
        let vote = vote_from_verdict(v);
        assert_eq!(vote.score, 1.0); // margin 4.0 clamps to 1
        let v = EngineVerdict {
            stream_id: 0,
            seq: 9,
            k: 10,
            eccentricity: 1.0,
            zeta: f64::NAN,
            threshold: 0.1,
            outlier: false,
        };
        assert_eq!(vote_from_verdict(v).score, 0.0); // NaN-safe
    }

    #[test]
    fn every_member_kind_snapshots_and_restores() {
        for spec_s in ["teda:m=3", "rtl:m=3", "msigma:m=3", "zscore:m=3,w=16"]
        {
            let spec: MemberSpec = spec_s.parse().unwrap();
            let mut a = EnsembleMember::build(&spec, 2);
            assert!(a.snapshot(0).is_none(), "{spec_s}: unseen stream");
            for seq in 0..40u64 {
                a.ingest(&sample(0, seq, seq as f64 * 0.1)).unwrap();
            }
            let snap = a.snapshot(0).unwrap();
            let mut b = EnsembleMember::build(&spec, 2);
            b.restore(0, snap).unwrap();
            // Both continue identically (flush tail included).
            let mut va = Vec::new();
            let mut vb = Vec::new();
            for seq in 40..60u64 {
                va.extend(a.ingest(&sample(0, seq, seq as f64 * 0.1)).unwrap());
                vb.extend(b.ingest(&sample(0, seq, seq as f64 * 0.1)).unwrap());
            }
            va.extend(a.flush().unwrap());
            vb.extend(b.flush().unwrap());
            assert_eq!(va.len(), vb.len(), "{spec_s}");
            for (x, y) in va.iter().zip(&vb) {
                assert_eq!(x.seq, y.seq, "{spec_s}");
                assert_eq!(x.outlier, y.outlier, "{spec_s} seq={}", x.seq);
            }
        }
    }

    #[test]
    fn restore_rejects_cross_kind_snapshot() {
        let teda: MemberSpec = "teda:m=3".parse().unwrap();
        let msigma: MemberSpec = "msigma:m=3".parse().unwrap();
        let mut a = EnsembleMember::build(&teda, 2);
        a.ingest(&sample(0, 0, 0.5)).unwrap();
        let snap = a.snapshot(0).unwrap();
        let mut b = EnsembleMember::build(&msigma, 2);
        assert!(b.restore(0, snap).is_err());
    }

    #[test]
    fn busy_ns_accumulates() {
        let spec: MemberSpec = "zscore:m=3,w=8".parse().unwrap();
        let mut member = EnsembleMember::build(&spec, 1);
        for seq in 0..50u64 {
            member
                .ingest(&Sample {
                    stream_id: 0,
                    seq,
                    values: vec![seq as f64],
                })
                .unwrap();
        }
        assert!(member.stats().busy_ns > 0);
        assert_eq!(member.stats().votes, 50);
    }
}

//! Static partition planner: can this ensemble fit the paper's FPGA,
//! and how should members spread across coordinator worker shards?
//!
//! ## Model
//!
//! The paper scales TEDA by instantiating "multiple TEDA modules
//! applied in parallel" (§5.2.1); an ensemble generalizes that to
//! *heterogeneous* modules. The planner treats each member as one
//! hardware block:
//!
//! - **TEDA members** (software or RTL spec) cost exactly what the
//!   [`crate::rtl`] netlist costs on the target device — the same
//!   netlist the simulator executes, analyzed by
//!   [`OccupationReport::analyze`], so plan and function cannot drift.
//! - **Baseline members** are estimated from the same calibrated
//!   [`ResourceModel`] primitives a direct datapath implementation
//!   would instantiate (documented per member in
//!   [`baseline_footprint`]); the z-score window buffer is counted as
//!   FF bits (a real implementation would use BRAM — this is the
//!   conservative bound).
//!
//! Members are placed on `shards` coordinator workers by greedy
//! longest-processing-time (LPT) bin packing on LUT cost, the dominant
//! resource. The **aggregate** occupation (Σ members, instantiated once
//! each) is reported as a standard [`OccupationReport`] against the
//! xc6vlx240t, answering the ISSUE's sizing question directly:
//! `fits()` is true iff every resource stays under 100%.

use crate::config::{MemberKind, MemberSpec};
use crate::rtl::{CompKind, TedaRtl};
use crate::synth::{OccupationReport, ResourceModel, Virtex6};
use crate::{Error, Result};

/// One member's modeled hardware cost.
#[derive(Debug, Clone, PartialEq)]
pub struct MemberFootprint {
    pub label: String,
    /// DSP48E1 slices.
    pub dsp: usize,
    /// LUTs.
    pub lut: usize,
    /// Flip-flop bits.
    pub ff: usize,
    /// FP multiplier core instances.
    pub mult_cores: usize,
    /// FP divider core instances.
    pub div_cores: usize,
    /// FP adder/subtractor core instances.
    pub addsub_cores: usize,
}

/// The planned placement of an ensemble on a device.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    /// Per-member modeled cost (member order).
    pub footprints: Vec<MemberFootprint>,
    /// Member indices assigned to each shard (LPT on LUTs).
    pub shards: Vec<Vec<usize>>,
    /// Aggregate occupation of the whole ensemble on the device.
    pub occupation: OccupationReport,
    device: Virtex6,
}

impl PartitionPlan {
    /// Plan `specs` across `shards` workers for `n_features`-dim
    /// streams on `device`.
    pub fn plan(
        specs: &[MemberSpec],
        n_features: usize,
        shards: usize,
        device: Virtex6,
    ) -> Result<PartitionPlan> {
        if specs.is_empty() {
            return Err(Error::Config(
                "cannot partition an empty ensemble".into(),
            ));
        }
        if shards == 0 {
            return Err(Error::Config("need at least one shard".into()));
        }
        let footprints: Result<Vec<MemberFootprint>> = specs
            .iter()
            .map(|s| member_footprint(s, n_features, device))
            .collect();
        let footprints = footprints?;

        // Greedy LPT on LUTs: heaviest member onto the lightest shard.
        let mut order: Vec<usize> = (0..footprints.len()).collect();
        order.sort_by(|&a, &b| {
            footprints[b]
                .lut
                .cmp(&footprints[a].lut)
                .then_with(|| a.cmp(&b))
        });
        let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); shards];
        let mut shard_lut = vec![0usize; shards];
        for idx in order {
            let lightest = shard_lut
                .iter()
                .enumerate()
                .min_by_key(|&(_, &l)| l)
                .map(|(i, _)| i)
                .unwrap();
            assignment[lightest].push(idx);
            shard_lut[lightest] += footprints[idx].lut;
        }
        for members in &mut assignment {
            members.sort_unstable();
        }

        let occupation = aggregate_occupation(&footprints, device);
        Ok(PartitionPlan {
            footprints,
            shards: assignment,
            occupation,
            device,
        })
    }

    /// Does the whole ensemble fit the device?
    pub fn fits(&self) -> bool {
        self.occupation.multipliers_pct <= 100.0
            && self.occupation.registers_pct <= 100.0
            && self.occupation.luts_pct <= 100.0
    }

    /// How many copies of this ensemble the device could host (the
    /// §5.2.1 "multiple modules in parallel" headroom).
    pub fn max_replicas(&self) -> usize {
        let per = [
            (self.occupation.multipliers, self.device.dsp48e1),
            (self.occupation.registers, self.device.ffs),
            (self.occupation.luts, self.device.luts),
        ];
        per.iter()
            .map(|&(used, cap)| if used == 0 { usize::MAX } else { cap / used })
            .min()
            .unwrap_or(0)
    }

    /// Human-readable plan (member table, shard map, occupation).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("ensemble partition plan\n\n");
        out.push_str("member                     DSP48E1    LUT     FF\n");
        for fp in &self.footprints {
            out.push_str(&format!(
                "  {:<24} {:>7} {:>7} {:>6}\n",
                fp.label, fp.dsp, fp.lut, fp.ff
            ));
        }
        out.push('\n');
        for (i, members) in self.shards.iter().enumerate() {
            let labels: Vec<&str> = members
                .iter()
                .map(|&m| self.footprints[m].label.as_str())
                .collect();
            let lut: usize =
                members.iter().map(|&m| self.footprints[m].lut).sum();
            out.push_str(&format!(
                "  shard {i}: [{}] ({lut} LUT)\n",
                labels.join(", ")
            ));
        }
        out.push('\n');
        out.push_str(&self.occupation.render_table3());
        out.push_str(&format!(
            "fits {}: {} (≤ {} replica{} of the full ensemble)\n",
            self.device.name,
            if self.fits() { "YES" } else { "NO" },
            self.max_replicas(),
            if self.max_replicas() == 1 { "" } else { "s" },
        ));
        out
    }
}

/// Cost of one member on `device`.
fn member_footprint(
    spec: &MemberSpec,
    n_features: usize,
    device: Virtex6,
) -> Result<MemberFootprint> {
    match spec.kind {
        MemberKind::TedaSoftware | MemberKind::TedaRtl => {
            // Both map to the paper's TEDA datapath in hardware; the
            // software/RTL distinction only matters for host execution.
            let rtl = TedaRtl::new(n_features, spec.m as f32)?;
            let rep = OccupationReport::analyze(rtl.netlist(), device);
            Ok(MemberFootprint {
                label: spec.label(),
                dsp: rep.multipliers,
                lut: rep.luts,
                ff: rep.registers,
                mult_cores: rep.mult_cores,
                div_cores: rep.div_cores,
                addsub_cores: rep.addsub_cores,
            })
        }
        MemberKind::MSigma => {
            Ok(baseline_footprint(spec.label(), n_features, 0))
        }
        MemberKind::ZScore => {
            Ok(baseline_footprint(spec.label(), n_features, spec.window))
        }
    }
}

/// Datapath estimate for the m·σ / z-score baselines, priced with the
/// calibrated [`ResourceModel`] primitives:
///
/// per feature — 1 subtractor (x−μ), 1 multiplier (m·σ or squaring),
/// 1 divider (running-mean update), 2 adders (mean/var accumulate),
/// 1 comparator (flag), 2 state registers (μ, σ² accumulators);
/// plus one shared sample counter. A `window > 0` (z-score) adds
/// `window · n_features` 32-bit buffer words, costed as registers.
fn baseline_footprint(
    label: String,
    n_features: usize,
    window: usize,
) -> MemberFootprint {
    let model = ResourceModel;
    let mut dsp = 0;
    let mut lut = 0;
    let mut ff = 0;
    {
        let mut add = |kind: &CompKind, count: usize| {
            let c = model.cost(kind);
            dsp += c.dsp * count;
            lut += c.lut * count;
            ff += c.ff * count;
        };
        add(&CompKind::Sub, n_features);
        add(&CompKind::Mult, n_features);
        add(&CompKind::Div, n_features);
        add(&CompKind::Add, 2 * n_features);
        add(&CompKind::CompGt, n_features);
        add(&CompKind::Reg { init: 0.0 }, 2 * n_features);
        add(&CompKind::Counter, 1);
        // Window buffer: one 32-bit word per buffered value.
        add(&CompKind::Reg { init: 0.0 }, window * n_features);
    }
    MemberFootprint {
        label,
        dsp,
        lut,
        ff,
        mult_cores: n_features,
        div_cores: n_features,
        addsub_cores: 3 * n_features,
    }
}

/// Sum member footprints into a standard Table-3-shaped report.
fn aggregate_occupation(
    footprints: &[MemberFootprint],
    device: Virtex6,
) -> OccupationReport {
    let dsp: usize = footprints.iter().map(|f| f.dsp).sum();
    let lut: usize = footprints.iter().map(|f| f.lut).sum();
    let ff: usize = footprints.iter().map(|f| f.ff).sum();
    OccupationReport {
        multipliers: dsp,
        registers: ff,
        luts: lut,
        multipliers_pct: 100.0 * dsp as f64 / device.dsp48e1 as f64,
        registers_pct: 100.0 * ff as f64 / device.ffs as f64,
        luts_pct: 100.0 * lut as f64 / device.luts as f64,
        mult_cores: footprints.iter().map(|f| f.mult_cores).sum(),
        div_cores: footprints.iter().map(|f| f.div_cores).sum(),
        addsub_cores: footprints.iter().map(|f| f.addsub_cores).sum(),
        device: device.name,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnsembleConfig;

    fn specs(list: &str) -> Vec<MemberSpec> {
        EnsembleConfig::from_member_list(
            list,
            crate::config::CombinerKind::Majority,
        )
        .unwrap()
        .members
    }

    #[test]
    fn teda_member_footprint_matches_table3() {
        let plan = PartitionPlan::plan(
            &specs("teda"),
            2,
            1,
            Virtex6::xc6vlx240t(),
        )
        .unwrap();
        // One TEDA member = the paper's Table 3 exactly.
        assert_eq!(plan.occupation.multipliers, 27);
        assert_eq!(plan.occupation.luts, 11_567);
        assert!(plan.fits());
    }

    #[test]
    fn five_member_sweep_fits_xc6vlx240t() {
        // The ISSUE's sizing question: a TEDA m-sweep plus baselines.
        let plan = PartitionPlan::plan(
            &specs("teda+teda:m=2.5+teda:m=4+msigma+zscore:m=3,w=64"),
            2,
            2,
            Virtex6::xc6vlx240t(),
        )
        .unwrap();
        assert!(plan.fits(), "{}", plan.render());
        assert!(plan.max_replicas() >= 1);
        // All members placed, exactly once.
        let mut placed: Vec<usize> =
            plan.shards.iter().flatten().copied().collect();
        placed.sort_unstable();
        assert_eq!(placed, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn lpt_balances_lut_load() {
        let plan = PartitionPlan::plan(
            &specs("teda+teda+teda+teda"),
            2,
            2,
            Virtex6::xc6vlx240t(),
        )
        .unwrap();
        // Four identical members over two shards → 2 + 2.
        assert_eq!(plan.shards[0].len(), 2);
        assert_eq!(plan.shards[1].len(), 2);
    }

    #[test]
    fn oversized_ensemble_reports_not_fitting() {
        // 14 TEDA netlists ≈ 14 × 11 567 LUT > 150 720.
        let list = vec!["teda"; 14].join("+");
        let plan = PartitionPlan::plan(
            &specs(&list),
            2,
            4,
            Virtex6::xc6vlx240t(),
        )
        .unwrap();
        assert!(!plan.fits());
        assert_eq!(plan.max_replicas(), 0);
    }

    #[test]
    fn zscore_window_costs_registers() {
        let small = member_footprint(
            &"zscore:m=3,w=8".parse().unwrap(),
            2,
            Virtex6::xc6vlx240t(),
        )
        .unwrap();
        let big = member_footprint(
            &"zscore:m=3,w=512".parse().unwrap(),
            2,
            Virtex6::xc6vlx240t(),
        )
        .unwrap();
        assert!(big.ff > small.ff);
        assert_eq!(big.lut, small.lut);
    }

    #[test]
    fn plan_rejects_degenerate_inputs() {
        assert!(PartitionPlan::plan(&[], 2, 1, Virtex6::xc6vlx240t())
            .is_err());
        assert!(PartitionPlan::plan(
            &specs("teda"),
            2,
            0,
            Virtex6::xc6vlx240t()
        )
        .is_err());
        let plan = PartitionPlan::plan(
            &specs("teda"),
            2,
            1,
            Virtex6::xc6vlx240t(),
        )
        .unwrap();
        assert!(plan.render().contains("shard 0"));
    }
}

//! Ensemble subsystem — multi-detector fusion over pluggable engines.
//!
//! The paper scales TEDA by instantiating "multiple TEDA modules
//! applied in parallel" (§5.2.1); fSEAD (Lou et al. 2024) shows the
//! production version of that idea is a *composable ensemble* of
//! heterogeneous streaming detectors, because no single detector wins
//! across workloads (Choudhary et al. 2017). This module supplies that
//! layer:
//!
//! - [`member`] — [`EnsembleMember`] adapts any [`Engine`]
//!   (TEDA software / RTL-sim) or [`crate::baselines::AnomalyDetector`]
//!   (m·σ, sliding z-score) into one uniform voting surface with
//!   per-member latency/vote accounting.
//! - [`combiner`] — pluggable fusion: majority, static weighted score,
//!   any-of, all-of, and an adaptive weighted vote that decays members
//!   disagreeing with the fused verdict (see the module doc for exact
//!   semantics).
//! - [`partition`] — static planner answering "does this ensemble fit
//!   the xc6vlx240t, and how does it spread across worker shards?" via
//!   the calibrated [`crate::synth`] occupation model.
//! - [`EnsembleEngine`] — the composition, itself an [`Engine`], so the
//!   coordinator drives a fused N-member ensemble exactly like a single
//!   backend (`[engine] kind = "ensemble"`).
//!
//! ## Vote alignment
//!
//! Members emit votes at different latencies (software TEDA answers
//! immediately, the RTL pipeline answers 2 samples late, batching
//! engines in bursts). The engine aligns votes by `(stream, seq)` and
//! fuses a sample only when *every* member has voted on it, so fusion
//! semantics are latency-independent: the fused stream is identical
//! whatever mix of member latencies is enrolled. Per-stream order is
//! preserved because each member emits per-stream in order and a
//! sample's quorum therefore completes in order too.
//!
//! ## Equivalence guarantee
//!
//! A single-member ensemble is verdict-for-verdict identical to the
//! wrapped engine (property-tested against
//! [`crate::engine::SoftwareEngine`]):
//! the fused verdict copies the member's full TEDA statistics and every
//! combiner degenerates to the member's own flag at N = 1.

pub mod combiner;
pub mod member;
pub mod partition;

pub use combiner::{build_combiner, Combiner, Fused};
pub use member::{EnsembleMember, MemberSnapshot, MemberStats, MemberVote};
pub use partition::{MemberFootprint, PartitionPlan};

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

use crate::config::EnsembleConfig;
use crate::engine::{Engine, EngineVerdict, Snapshot};
use crate::metrics::EnsembleMetrics;
use crate::stream::Sample;
use crate::{Error, Result};

/// Checkpoint of ONE stream's complete ensemble state, captured at a
/// single `(stream, seq)` watermark:
///
/// - every member's own snapshot (engine state or baseline recursion),
/// - the per-stream combiner weights (the adaptive combiner's learned
///   state — exactly what a per-shard design could not checkpoint),
/// - the unfused quorum slots for the stream: votes from fast members
///   waiting on slow ones (the fusion barrier). Restoring them means no
///   member restores "ahead" of fusion — re-fed samples complete the
///   same quorums the dead worker was holding open.
#[derive(Debug, Clone, PartialEq)]
pub struct EnsembleSnapshot {
    /// One snapshot per member, in member (roster) order.
    pub members: Vec<MemberSnapshot>,
    /// Effective combiner weights for this stream.
    pub weights: Vec<f64>,
    /// Unfused votes: (seq, one optional vote per member slot).
    pub pending: Vec<(u64, Vec<Option<MemberVote>>)>,
}

/// Per-sample record of how the fused verdict came about (kept only
/// when breakdown capture is enabled — see
/// [`EnsembleEngine::with_breakdown`]).
#[derive(Debug, Clone)]
pub struct FusedBreakdown {
    pub stream_id: u64,
    pub seq: u64,
    /// The ensemble's verdict.
    pub outlier: bool,
    /// Combiner decision statistic.
    pub score: f64,
    /// `(member label, member flag, member score)` per member.
    pub votes: Vec<(String, bool, f64)>,
}

/// An ensemble of heterogeneous detectors behind the [`Engine`] trait.
pub struct EnsembleEngine {
    members: Vec<EnsembleMember>,
    combiner: Box<dyn Combiner>,
    /// Votes waiting for quorum, keyed by (stream, seq); one slot per
    /// member in member order.
    pending: HashMap<(u64, u64), Vec<Option<MemberVote>>>,
    /// Stream ids ever seen (the engine-level active-stream count).
    seen: HashSet<u64>,
    /// Shared per-member counters (coordinator wiring); optional so the
    /// engine also runs standalone (examples, benches, CLI one-shots).
    metrics: Option<Arc<EnsembleMetrics>>,
    /// busy_ns already flushed into `metrics` per member.
    synced_busy_ns: Vec<u64>,
    /// Per-sample vote breakdowns (only when enabled).
    breakdowns: Option<Vec<FusedBreakdown>>,
    /// Samples evicted at flush because their quorum never completed.
    quorum_evictions: u64,
}

impl EnsembleEngine {
    /// Build the roster + combiner from a validated config.
    pub fn new(cfg: &EnsembleConfig, n_features: usize) -> Result<Self> {
        cfg.validate()?;
        let members: Vec<EnsembleMember> = cfg
            .members
            .iter()
            .map(|spec| EnsembleMember::build(spec, n_features))
            .collect();
        let weights = members.iter().map(EnsembleMember::weight).collect();
        let combiner = build_combiner(cfg.combiner, weights);
        let n = members.len();
        Ok(EnsembleEngine {
            members,
            combiner,
            pending: HashMap::new(),
            seen: HashSet::new(),
            metrics: None,
            synced_busy_ns: vec![0; n],
            breakdowns: None,
            quorum_evictions: 0,
        })
    }

    /// Attach shared per-member counters (must match the member count).
    ///
    /// # Panics
    /// Panics when the counter bundle was built for a different roster
    /// size — silently mis-attributing votes would be worse.
    pub fn with_metrics(mut self, metrics: Arc<EnsembleMetrics>) -> Self {
        assert_eq!(
            metrics.members.len(),
            self.members.len(),
            "EnsembleMetrics rows must match the member roster"
        );
        self.metrics = Some(metrics);
        self
    }

    /// Capture per-sample vote breakdowns (diagnostics; costs memory —
    /// drain with [`EnsembleEngine::take_breakdowns`]).
    pub fn with_breakdown(mut self, enabled: bool) -> Self {
        self.breakdowns = if enabled { Some(Vec::new()) } else { None };
        self
    }

    /// Member count.
    pub fn n_members(&self) -> usize {
        self.members.len()
    }

    /// Per-member labels (member order).
    pub fn member_labels(&self) -> Vec<String> {
        self.members.iter().map(EnsembleMember::label).collect()
    }

    /// Per-member accounting snapshots (member order).
    pub fn member_stats(&self) -> Vec<MemberStats> {
        self.members.iter().map(EnsembleMember::stats).collect()
    }

    /// Configured (initial) combiner weights.
    pub fn combiner_weights(&self) -> Vec<f64> {
        self.combiner.weights()
    }

    /// Effective combiner weights for one stream (per-stream adaptive
    /// combiners evolve these independently).
    pub fn stream_weights(&self, stream_id: u64) -> Vec<f64> {
        self.combiner.stream_weights(stream_id)
    }

    /// Samples evicted at flush because their quorum never completed
    /// (a member erred or a stream ended mid-flight).
    pub fn quorum_evictions(&self) -> u64 {
        self.quorum_evictions
    }

    /// Drain captured breakdowns (empty unless `with_breakdown(true)`).
    pub fn take_breakdowns(&mut self) -> Vec<FusedBreakdown> {
        self.breakdowns.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Feed one member's votes into the pending table.
    fn stage_votes(
        &mut self,
        member_idx: usize,
        votes: Vec<MemberVote>,
    ) -> Result<()> {
        let n = self.members.len();
        for vote in votes {
            let key = (vote.stream_id, vote.seq);
            let slots =
                self.pending.entry(key).or_insert_with(|| vec![None; n]);
            if slots[member_idx].is_some() {
                return Err(Error::Stream(format!(
                    "member {member_idx} voted twice on stream {} seq {}",
                    key.0, key.1
                )));
            }
            slots[member_idx] = Some(vote);
        }
        Ok(())
    }

    /// Fuse every sample whose quorum is complete; returns verdicts
    /// sorted by (stream, seq).
    fn drain_ready(&mut self) -> Vec<EngineVerdict> {
        let mut ready: Vec<(u64, u64)> = self
            .pending
            .iter()
            .filter(|(_, slots)| slots.iter().all(Option::is_some))
            .map(|(&k, _)| k)
            .collect();
        // Fuse in (stream, seq) order — stateful combiners (adaptive)
        // must see samples deterministically, not in HashMap order.
        // `out` inherits this order, so no second sort is needed.
        ready.sort_unstable();
        let mut out = Vec::with_capacity(ready.len());
        for key in ready {
            let slots = self.pending.remove(&key).unwrap();
            let votes: Vec<MemberVote> =
                slots.into_iter().map(Option::unwrap).collect();
            out.push(self.fuse_one(key, &votes));
        }
        out
    }

    /// Combine one sample's aligned votes into the fused verdict.
    fn fuse_one(
        &mut self,
        (stream_id, seq): (u64, u64),
        votes: &[MemberVote],
    ) -> EngineVerdict {
        // Fuse time is only clocked when someone will read it — the
        // standalone (metrics-less) engine pays zero clock reads here.
        let t_fuse = self.metrics.is_some().then(Instant::now);
        let fused = self.combiner.fuse(votes);
        if let Some(m) = &self.metrics {
            if let Some(t) = t_fuse {
                m.fuse_time.record(t.elapsed().as_nanos() as u64);
            }
            m.fused_verdicts.inc();
            if fused.outlier {
                m.fused_outliers.inc();
            }
            for (vote, mm) in votes.iter().zip(&m.members) {
                mm.votes.inc();
                if vote.outlier {
                    mm.outliers.inc();
                }
                if vote.outlier != fused.outlier {
                    mm.disagreements.inc();
                }
            }
        }
        if let Some(b) = &mut self.breakdowns {
            b.push(FusedBreakdown {
                stream_id,
                seq,
                outlier: fused.outlier,
                score: fused.score,
                votes: votes
                    .iter()
                    .zip(&self.members)
                    .map(|(v, m)| (m.label(), v.outlier, v.score))
                    .collect(),
            });
        }
        // The fused verdict carries the first TEDA member's statistics
        // (eccentricity/ζ/threshold) so downstream consumers keep the
        // paper's observables; baseline-only ensembles synthesize them.
        match votes.iter().find_map(|v| v.detail.clone()) {
            Some(mut detail) => {
                detail.outlier = fused.outlier;
                detail
            }
            None => EngineVerdict {
                stream_id,
                seq,
                k: seq + 1,
                eccentricity: 0.0,
                zeta: fused.score,
                threshold: 0.0,
                outlier: fused.outlier,
            },
        }
    }

    /// Push each member's busy-time delta into the shared counters.
    fn sync_busy_ns(&mut self) {
        if let Some(m) = &self.metrics {
            for (i, member) in self.members.iter().enumerate() {
                let total = member.stats().busy_ns;
                let delta = total - self.synced_busy_ns[i];
                if delta > 0 {
                    m.members[i].busy_ns.add(delta);
                    self.synced_busy_ns[i] = total;
                }
            }
        }
    }
}

impl Engine for EnsembleEngine {
    fn name(&self) -> &'static str {
        "ensemble"
    }

    fn ingest(&mut self, sample: &Sample) -> Result<Vec<EngineVerdict>> {
        self.seen.insert(sample.stream_id);
        for i in 0..self.members.len() {
            let t_vote = self.metrics.is_some().then(Instant::now);
            let votes = self.members[i].ingest(sample)?;
            if let (Some(m), Some(t)) = (&self.metrics, t_vote) {
                m.members[i].vote_time.record(t.elapsed().as_nanos() as u64);
            }
            self.stage_votes(i, votes)?;
        }
        self.sync_busy_ns();
        Ok(self.drain_ready())
    }

    fn process_batch(
        &mut self,
        samples: &[Sample],
        out: &mut Vec<EngineVerdict>,
    ) -> Result<()> {
        if samples.is_empty() {
            return Ok(());
        }
        for sample in samples {
            self.seen.insert(sample.stream_id);
        }
        // One batch pass per member, then ONE quorum drain for the whole
        // burst. Fusion stays bit-identical to the per-sample path:
        // `drain_ready` fuses in (stream, seq) order and the stateful
        // combiners key their weights per stream, so each stream's
        // fusion sequence — and therefore every adaptive weight update —
        // is unchanged; only the drain granularity moves.
        for i in 0..self.members.len() {
            let t_vote = self.metrics.is_some().then(Instant::now);
            let votes = self.members[i].ingest_batch(samples)?;
            if let (Some(m), Some(t)) = (&self.metrics, t_vote) {
                m.members[i].vote_time.record(t.elapsed().as_nanos() as u64);
            }
            self.stage_votes(i, votes)?;
        }
        self.sync_busy_ns();
        out.extend(self.drain_ready());
        Ok(())
    }

    fn flush(&mut self) -> Result<Vec<EngineVerdict>> {
        for i in 0..self.members.len() {
            let votes = self.members[i].flush()?;
            self.stage_votes(i, votes)?;
        }
        self.sync_busy_ns();
        let out = self.drain_ready();
        if !self.pending.is_empty() {
            // A quorum that flush could not complete will never
            // complete (a member erred or the stream ended mid-flight).
            // Retaining the slots forever would leak; evict them with a
            // warning metric instead of wedging shutdown on an error.
            // The signal surface is machine-readable on purpose: the
            // shared `quorum_evictions` counter plus the engine-local
            // [`EnsembleEngine::quorum_evictions`] getter — a library
            // must not write to stderr behind its embedder's back.
            let n = self.pending.len() as u64;
            self.quorum_evictions += n;
            if let Some(m) = &self.metrics {
                m.quorum_evictions.add(n);
            }
            self.pending.clear();
        }
        Ok(out)
    }

    fn active_streams(&self) -> usize {
        self.seen.len()
    }

    fn snapshot(&self, stream_id: u64) -> Option<Snapshot> {
        if !self.seen.contains(&stream_id) {
            return None;
        }
        // Every member ingests every sample, so a seen stream has state
        // in all members; a partially missing roster means the stream
        // was never actually ingested here.
        let members: Vec<MemberSnapshot> = self
            .members
            .iter()
            .map(|m| m.snapshot(stream_id))
            .collect::<Option<_>>()?;
        let pending: Vec<(u64, Vec<Option<MemberVote>>)> = {
            let mut p: Vec<_> = self
                .pending
                .iter()
                .filter(|((sid, _), _)| *sid == stream_id)
                .map(|(&(_, seq), slots)| (seq, slots.clone()))
                .collect();
            p.sort_unstable_by_key(|(seq, _)| *seq);
            p
        };
        Some(Snapshot::Ensemble(EnsembleSnapshot {
            members,
            weights: self.combiner.stream_weights(stream_id),
            pending,
        }))
    }

    fn restore(&mut self, stream_id: u64, snapshot: Snapshot) -> Result<()> {
        let snap = match snapshot {
            Snapshot::Ensemble(s) => s,
            other => return Err(other.kind_mismatch("ensemble")),
        };
        let n = self.members.len();
        if snap.members.len() != n
            || snap.weights.len() != n
            || snap.pending.iter().any(|(_, slots)| slots.len() != n)
        {
            return Err(Error::Stream(format!(
                "ensemble snapshot shaped for {} members, roster has {n}",
                snap.members.len()
            )));
        }
        for (member, ms) in self.members.iter_mut().zip(snap.members) {
            member.restore(stream_id, ms)?;
        }
        self.combiner.set_stream_weights(stream_id, snap.weights);
        // Re-open the quorums the snapshotted engine was holding: votes
        // already cast stay cast, missing slots are filled as re-fed
        // samples flow through the slower members.
        self.pending.retain(|(sid, _), _| *sid != stream_id);
        for (seq, slots) in snap.pending {
            self.pending.insert((stream_id, seq), slots);
        }
        self.seen.insert(stream_id);
        Ok(())
    }

    fn evict(&mut self, stream_id: u64) {
        for member in &mut self.members {
            member.evict(stream_id);
        }
        self.combiner.evict_stream(stream_id);
        // Open quorums die with the stream (they could never complete).
        self.pending.retain(|(sid, _), _| *sid != stream_id);
        self.seen.remove(&stream_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CombinerKind, EnsembleConfig};
    use crate::engine::testutil::{interleaved, run_engine};
    use crate::engine::SoftwareEngine;
    use crate::util::propkit::forall;

    fn ensemble(members: &str, combiner: CombinerKind) -> EnsembleEngine {
        let cfg =
            EnsembleConfig::from_member_list(members, combiner).unwrap();
        EnsembleEngine::new(&cfg, 2).unwrap()
    }

    /// Satellite: a single-software-TEDA ensemble is verdict-for-verdict
    /// identical to `SoftwareEngine` on interleaved multi-stream input,
    /// across every combiner — the ensemble layer adds no verdict drift.
    #[test]
    fn prop_single_member_matches_software_engine() {
        forall("single-member ensemble ≡ SoftwareEngine", 48, |g| {
            let streams = g.usize_in(1, 4) as u64;
            let per_stream = g.usize_in(2, 40);
            let seed = g.rng().next_u64();
            let m = g.f64_in(1.5, 4.5);
            let combiner = match g.usize_in(0, 4) {
                0 => CombinerKind::Majority,
                1 => CombinerKind::WeightedScore,
                2 => CombinerKind::AnyOf,
                3 => CombinerKind::AllOf,
                _ => CombinerKind::Adaptive,
            };
            let samples = interleaved(streams, per_stream, 2, seed);

            let cfg = EnsembleConfig::from_member_list(
                &format!("teda:m={m}"),
                combiner,
            )
            .unwrap();
            let mut ens = EnsembleEngine::new(&cfg, 2).unwrap();
            let mut sw = SoftwareEngine::new(2, m);

            let a = run_engine(&mut ens, &samples);
            let b = run_engine(&mut sw, &samples);
            assert_eq!(a, b, "drift with combiner {combiner}");
        });
    }

    #[test]
    fn mixed_latency_members_align_votes() {
        // Software answers instantly, RTL two cycles late: quorum logic
        // must still classify every sample exactly once.
        let mut ens = ensemble("teda+rtl", CombinerKind::Majority);
        let samples = interleaved(3, 40, 2, 17);
        let out = run_engine(&mut ens, &samples);
        assert_eq!(out.len(), 120);
        assert_eq!(ens.active_streams(), 3);
        // Verdict numerics come from the first TEDA member (f64).
        for ((sid, seq), v) in &out {
            assert_eq!(v.stream_id, *sid);
            assert_eq!(v.seq, *seq);
            assert_eq!(v.k, seq + 1);
        }
    }

    #[test]
    fn evict_drops_members_weights_and_quorums() {
        use crate::stream::Sample;
        // Adaptive combiner + mixed latency: stream 0 accumulates
        // learned weights, member state AND an open quorum (the RTL
        // member is 2 samples behind). Eviction must clear all three,
        // and a re-appearing stream 0 must start fresh.
        let mut ens = ensemble("teda+rtl:m=1.5", CombinerKind::Adaptive);
        let samples = interleaved(2, 30, 2, 41);
        for s in &samples {
            ens.ingest(s).unwrap();
        }
        assert_eq!(ens.active_streams(), 2);
        assert!(ens
            .snapshot(0)
            .is_some_and(|s| matches!(s, Snapshot::Ensemble(_))));
        ens.evict(0);
        assert_eq!(ens.active_streams(), 1);
        assert!(ens.snapshot(0).is_none(), "evicted stream has no state");
        // Learned per-stream weights reset to the spec weights.
        assert_eq!(ens.stream_weights(0), ens.combiner_weights());
        // Re-appearing stream id starts fresh: after one new sample,
        // the software member's recurrence is back at k = 1 instead of
        // resuming the evicted detector.
        ens.ingest(&Sample { stream_id: 0, seq: 60, values: vec![0.1, 0.2] })
            .unwrap();
        let Some(Snapshot::Ensemble(snap)) = ens.snapshot(0) else {
            panic!("re-appearing stream has ensemble state again")
        };
        let MemberSnapshot::Engine(Snapshot::Software(det)) =
            &snap.members[0]
        else {
            panic!("first member is software TEDA")
        };
        assert_eq!(det.state.k, 1, "evicted stream must start fresh");
        // The surviving stream was untouched by the eviction.
        assert!(ens.snapshot(1).is_some());
    }

    #[test]
    fn three_member_heterogeneous_ensemble_classifies_everything() {
        let mut ens = ensemble(
            "teda+msigma+zscore:m=3,w=32",
            CombinerKind::Majority,
        );
        let samples = interleaved(4, 60, 2, 5);
        let out = run_engine(&mut ens, &samples);
        assert_eq!(out.len(), 240);
        let stats = ens.member_stats();
        assert_eq!(stats.len(), 3);
        for s in &stats {
            assert_eq!(s.votes, 240);
        }
    }

    #[test]
    fn anyof_flags_superset_of_allof() {
        let samples = interleaved(2, 150, 2, 23);
        let mut any = ensemble("teda+msigma", CombinerKind::AnyOf);
        let mut all = ensemble("teda+msigma", CombinerKind::AllOf);
        let a = run_engine(&mut any, &samples);
        let b = run_engine(&mut all, &samples);
        for (key, fused_all) in &b {
            if fused_all.outlier {
                assert!(a[key].outlier, "all-of flagged {key:?} but any-of not");
            }
        }
    }

    #[test]
    fn fused_outlier_on_gross_anomaly() {
        let mut ens = ensemble(
            "teda+msigma+zscore:m=3,w=32",
            CombinerKind::Majority,
        );
        for seq in 0..200u64 {
            let v = (seq % 7) as f64 * 0.01;
            let out = ens
                .ingest(&Sample { stream_id: 0, seq, values: vec![v, -v] })
                .unwrap();
            assert!(!out.iter().any(|o| o.outlier), "false alarm at {seq}");
        }
        let out = ens
            .ingest(&Sample {
                stream_id: 0,
                seq: 200,
                values: vec![500.0, -500.0],
            })
            .unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].outlier);
    }

    #[test]
    fn breakdown_capture_records_votes() {
        let mut ens = ensemble("teda+msigma", CombinerKind::Majority)
            .with_breakdown(true);
        let samples = interleaved(1, 10, 2, 9);
        run_engine(&mut ens, &samples);
        let breakdowns = ens.take_breakdowns();
        assert_eq!(breakdowns.len(), 10);
        assert_eq!(breakdowns[0].votes.len(), 2);
        assert!(breakdowns[0].votes[0].0.starts_with("teda"));
        // Drained: second take is empty.
        assert!(ens.take_breakdowns().is_empty());
    }

    #[test]
    fn metrics_wiring_counts_votes_and_disagreements() {
        let cfg = EnsembleConfig::from_member_list(
            "teda+msigma",
            CombinerKind::Majority,
        )
        .unwrap();
        let metrics = EnsembleMetrics::new(cfg.labels());
        let mut ens = EnsembleEngine::new(&cfg, 2)
            .unwrap()
            .with_metrics(metrics.clone());
        let samples = interleaved(2, 50, 2, 3);
        run_engine(&mut ens, &samples);
        assert_eq!(metrics.fused_verdicts.get(), 100);
        assert_eq!(metrics.members[0].votes.get(), 100);
        assert_eq!(metrics.members[1].votes.get(), 100);
        assert!(metrics.members[0].busy_ns.get() > 0);
        // Stage timing (ISSUE 7): fuse + per-member vote histograms
        // fill whenever the counter bundle is attached.
        assert_eq!(metrics.fuse_time.count(), 100);
        assert_eq!(metrics.members[0].vote_time.count(), 100);
        assert_eq!(metrics.members[1].vote_time.count(), 100);
    }

    #[test]
    fn empty_roster_rejected() {
        let cfg = EnsembleConfig {
            members: vec![],
            combiner: CombinerKind::Majority,
        };
        assert!(EnsembleEngine::new(&cfg, 2).is_err());
    }

    #[test]
    fn adaptive_weights_evolve_in_engine() {
        // m·σ flags nothing early (k ≤ 2 guard) while TEDA never flags
        // either on calm data — weights barely move. Force disagreement
        // with an any-flagging workload instead: drive a spike regime.
        let mut ens = ensemble("teda:m=1.1+msigma:m=6", CombinerKind::Adaptive);
        let mut rng = crate::util::prng::SplitMix64::new(77);
        for seq in 0..400u64 {
            let spread = if seq % 3 == 0 { 4.0 } else { 0.1 };
            ens.ingest(&Sample {
                stream_id: 0,
                seq,
                values: vec![rng.normal() * spread, rng.normal() * spread],
            })
            .unwrap();
        }
        let w = ens.stream_weights(0);
        assert_eq!(w.len(), 2);
        // A tight-threshold TEDA disagrees with a loose m·σ often enough
        // that at least one weight must have moved off 1.0.
        assert!(w.iter().any(|&x| (x - 1.0).abs() > 1e-6), "weights {w:?}");
        // The configured weights stay pristine.
        assert_eq!(ens.combiner_weights(), vec![1.0, 1.0]);
    }

    #[test]
    fn snapshot_restore_mid_quorum_continues_identically() {
        // teda answers immediately, rtl two samples late: cutting
        // mid-stream leaves open quorums. The snapshot must carry them
        // (fusion barrier) so the restored engine fuses every sample
        // exactly once, identically to the uninterrupted run.
        let samples = interleaved(2, 40, 2, 31);
        let cut = samples.len() / 2;
        let mut oracle = ensemble("teda+rtl", CombinerKind::Adaptive);
        let full = run_engine(&mut oracle, &samples);

        let mut live = ensemble("teda+rtl", CombinerKind::Adaptive);
        let mut got = std::collections::BTreeMap::new();
        for s in &samples[..cut] {
            for v in live.ingest(s).unwrap() {
                got.insert((v.stream_id, v.seq), v);
            }
        }
        let mut restored = ensemble("teda+rtl", CombinerKind::Adaptive);
        for sid in 0..2u64 {
            let snap = live.snapshot(sid).unwrap();
            // The snapshot carries the open quorum slots.
            let Snapshot::Ensemble(es) = &snap else { unreachable!() };
            assert!(!es.pending.is_empty(), "rtl lag leaves open quorums");
            restored.restore(sid, snap).unwrap();
        }
        for s in &samples[cut..] {
            for v in restored.ingest(s).unwrap() {
                got.insert((v.stream_id, v.seq), v);
            }
        }
        for v in restored.flush().unwrap() {
            got.insert((v.stream_id, v.seq), v);
        }
        assert_eq!(got.len(), full.len());
        for (key, a) in &got {
            let b = &full[key];
            assert_eq!(a.outlier, b.outlier, "{key:?}");
            assert_eq!(a.k, b.k, "{key:?}");
        }
        // Learned per-stream weights travelled with the snapshot.
        for sid in 0..2u64 {
            assert_eq!(
                restored.stream_weights(sid),
                oracle.stream_weights(sid),
                "stream {sid} weights diverged"
            );
        }
        assert_eq!(restored.quorum_evictions(), 0);
    }

    #[test]
    fn snapshot_rejects_unknown_stream_and_wrong_roster() {
        let mut a = ensemble("teda+msigma", CombinerKind::Majority);
        assert!(a.snapshot(0).is_none());
        run_engine(&mut a, &interleaved(1, 10, 2, 2));
        let snap = a.snapshot(0).unwrap();
        // Restoring into a differently sized roster is rejected.
        let mut b = ensemble("teda", CombinerKind::Majority);
        assert!(b.restore(0, snap).is_err());
    }

    #[test]
    fn flush_evicts_quorumless_samples_with_warning_metric() {
        // Inject an open quorum whose missing member will never vote
        // (the member never sees the sample), then flush: the entry must
        // be evicted and counted, not retained forever or turned into a
        // shutdown error.
        let cfg = EnsembleConfig::from_member_list(
            "teda+msigma",
            CombinerKind::Majority,
        )
        .unwrap();
        let metrics = EnsembleMetrics::new(cfg.labels());
        let mut ens = EnsembleEngine::new(&cfg, 2)
            .unwrap()
            .with_metrics(metrics.clone());
        let samples = interleaved(1, 5, 2, 4);
        for s in &samples {
            ens.ingest(s).unwrap();
        }
        // Simulate a member that dropped a vote: restore a snapshot
        // whose pending table has a half-filled quorum for a sample the
        // members themselves never ingested.
        let Snapshot::Ensemble(mut es) = ens.snapshot(0).unwrap() else {
            unreachable!()
        };
        es.pending.push((
            99,
            vec![
                Some(MemberVote {
                    stream_id: 0,
                    seq: 99,
                    outlier: false,
                    score: -1.0,
                    detail: None,
                }),
                None,
            ],
        ));
        ens.restore(0, Snapshot::Ensemble(es)).unwrap();
        let out = ens.flush().unwrap();
        assert!(out.is_empty(), "no complete quorums were pending");
        assert_eq!(ens.quorum_evictions(), 1);
        assert_eq!(metrics.quorum_evictions.get(), 1);
        // Flush is terminal for the leak: nothing left pending.
        assert!(ens.flush().unwrap().is_empty());
        assert_eq!(ens.quorum_evictions(), 1);
    }
}

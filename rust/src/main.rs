//! teda-fpga CLI — launcher for the TEDA streaming anomaly-detection
//! service and the paper's experiment drivers.
//!
//! ```text
//! teda-fpga serve    [--config FILE] [--engine software|rtl|xla|ensemble]
//!                    [--workers N] [--workers-max N] [--streams S]
//!                    [--samples K] [--seed X]
//!                    [--virtual-shards V] [--rebalance-interval N]
//!                    [--checkpoint-interval N] [--restore]
//!                    [--checkpoint-dir DIR] [--recover] [--evict-after N]
//!                    [--metrics-addr HOST:PORT] [--trace-dump]
//!                    [--cluster-listen ADDR] [--node-id N]
//!                    [--peer ID=ADDR]... [--heartbeat-ms N]
//!                    [--failover-ms N]
//! teda-fpga cluster  --addr HOST:PORT
//! teda-fpga trace    --addr HOST:PORT
//! teda-fpga shards   [--config FILE] [--workers N] [--virtual-shards V]
//!                    [--streams S] [--full]
//! teda-fpga rebalance [--engine ...] [--workers N] [--streams S]
//!                    [--samples K] [--seed X]
//! teda-fpga detect   [--item 1..7] [--m 3.0] [--engine ...] [--csv OUT]
//! teda-fpga synth    [--n-features N] [--netlist]
//! teda-fpga damadics [--catalog] [--schedule] [--csv OUT --item I]
//! teda-fpga ensemble [--members LIST] [--combiner KIND] [--item 1..7]
//! teda-fpga bench-trend [--root DIR]
//! teda-fpga bench-gate  [--root DIR] [--max-regress 0.20]
//! teda-fpga doctor
//! ```
//!
//! (Argument parsing is hand-rolled: crates.io — and therefore clap —
//! is unavailable in this build environment; see DESIGN.md §3.)

use std::collections::HashMap;
use std::process::ExitCode;

use teda_fpga::config::{
    CombinerKind, EngineKind, EnsembleConfig, Json, ServiceConfig,
};
use teda_fpga::coordinator::transport::frame::Msg;
use teda_fpga::coordinator::transport::net::{PeerAddr, RpcClient};
use teda_fpga::coordinator::{
    scale_up_wanted, ClusterNode, Service, ShardTable,
};
use teda_fpga::damadics::{
    actuator1_schedule, evaluate_detection, fault_catalog, schedule_item,
    ActuatorSim,
};
use teda_fpga::engine::Engine as _;
use teda_fpga::ensemble::{EnsembleEngine, PartitionPlan};
use teda_fpga::rtl::TedaRtl;
use teda_fpga::stream::{ReplaySource, Sample, StreamSource, SyntheticSource};
use teda_fpga::synth::{critical_path, OccupationReport, PipelineTiming, Virtex6};
use teda_fpga::util::prng::SplitMix64;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", USAGE);
        return ExitCode::FAILURE;
    };
    let flags = match Flags::parse(rest) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}\n{}", USAGE);
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&flags),
        "cluster" => cmd_cluster(&flags),
        "trace" => cmd_trace(&flags),
        "shards" => cmd_shards(&flags),
        "rebalance" => cmd_rebalance(&flags),
        "detect" => cmd_detect(&flags),
        "synth" => cmd_synth(&flags),
        "damadics" => cmd_damadics(&flags),
        "ensemble" => cmd_ensemble(&flags),
        "bench-trend" => cmd_bench_trend(&flags),
        "bench-gate" => cmd_bench_gate(&flags),
        "doctor" => cmd_doctor(),
        "help" | "--help" | "-h" => {
            println!("{}", USAGE);
            Ok(())
        }
        other => Err(format!("unknown command '{other}'").into()),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
teda-fpga — TEDA streaming anomaly detection (paper reproduction)

USAGE:
  teda-fpga serve    [--config FILE(.toml|.json)]
                     [--engine software|rtl|xla|ensemble]
                     [--workers N] [--workers-max N]
                     [--streams S] [--samples K] [--seed X]
                     [--virtual-shards V] [--rebalance-interval N]
                     [--members LIST] [--combiner KIND]
                     [--checkpoint-interval N] [--restore]
                     [--checkpoint-dir DIR] [--recover] [--evict-after N]
                     [--metrics-addr HOST:PORT] [--trace-dump]
                     [--cluster-listen ADDR] [--node-id N]
                     [--peer ID=ADDR]... [--heartbeat-ms N]
                     [--failover-ms N] [--join ADDR]
                     [--cluster-rebalance-ms N] [--ingest-buffer N]
  teda-fpga cluster  --addr HOST:PORT
  teda-fpga trace    --addr HOST:PORT
  teda-fpga shards   [--config FILE] [--workers N] [--virtual-shards V]
                     [--streams S] [--full]
  teda-fpga rebalance [--engine software|rtl|ensemble] [--workers N]
                     [--streams S] [--samples K] [--seed X]
  teda-fpga detect   [--item 1..7] [--m 3.0]
                     [--engine software|rtl|ensemble] [--csv OUT]
                     [--members LIST] [--combiner KIND]
  teda-fpga synth    [--n-features N] [--netlist]
  teda-fpga damadics [--catalog] [--schedule] [--csv OUT --item I] [--seed X]
  teda-fpga ensemble [--members LIST] [--combiner KIND] [--workers N]
                     [--n-features N] [--item 1..7] [--seed X]
  teda-fpga bench-trend [--root DIR]
  teda-fpga bench-gate  [--root DIR] [--max-regress 0.20]
  teda-fpga doctor

  LIST is `+`-separated member specs, e.g. 'teda+teda:m=2.5+zscore:m=3,w=64'
  (kinds: teda|rtl|msigma|zscore; params: m=, w=, weight=).
  KIND is majority|weighted-score|any-of|all-of|adaptive.
  --checkpoint-dir persists checkpoints durably (atomic-rename files);
  --recover cold-starts from that dir after a process death (implies
  --restore); --evict-after drops idle streams after N samples.
  --workers-max N lets serve scale the worker pool up live mid-run,
  triggered by real pressure: a data ring ≥ 3/4 full, backpressure
  events in the last window, or queue-wait p99 over a 5 ms SLO;
  --rebalance-interval N rebalances hot shards every N samples.
  --cluster-listen ADDR (host:port or unix:/path) makes this serve a
  cluster node; --peer ID=ADDR (repeatable) names the other nodes of
  the logical shard map; --node-id N identifies this one. Nodes
  heartbeat every --heartbeat-ms; with --failover-ms N > 0, the
  lowest-id survivor adopts a silent peer's shards from the shared
  --checkpoint-dir after N ms of silence. --join ADDR registers with
  a live member instead of a static --peer roster and pulls this
  node's uniform share of shards mid-stream; --cluster-rebalance-ms N
  lets a node sustaining > cluster.rebalance_threshold × the average
  ingest rate shed hot shards to the coldest peer at most every N ms;
  --ingest-buffer N bounds the park-and-replay buffer that absorbs
  bursts while an owner is mid-failover (0 = off). `cluster --addr`
  probes a running node's status over the framed transport.
  `shards` prints the shard→worker table; `rebalance` is a live-
  migration smoke: it forces mid-stream shard moves + a worker resize
  and asserts verdict parity against an undisturbed run.
  --metrics-addr exposes /metrics (Prometheus), / (human text) and
  /trace (flight-recorder tail) while serve runs; `trace` fetches the
  /trace tail of a running serve; --trace-dump prints the local
  recorder tail after serve finishes.
  `bench-trend` folds BENCH_*.json into the cumulative BENCH_trend.json;
  `bench-gate` compares a fresh BENCH_shard.json against the previous
  trend entry and fails on a routing/throughput regression beyond
  --max-regress (default 20%).";

type CliError = Box<dyn std::error::Error>;

/// `--key value` / `--flag` parser.
struct Flags {
    map: HashMap<String, String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags, CliError> {
        let mut map = HashMap::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got '{a}'"))?;
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                _ => "true".to_string(), // boolean flag
            };
            // Repeatable flags (--peer 1=A --peer 2=B) accumulate
            // comma-separated; single-valued flags just read the join.
            map.entry(key.to_string())
                .and_modify(|prev| {
                    prev.push(',');
                    prev.push_str(&value);
                })
                .or_insert(value);
        }
        Ok(Flags { map })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(String::as_str)
    }

    fn parse_as<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => {
                raw.parse().map_err(|e| format!("--{key} '{raw}': {e}").into())
            }
        }
    }

    fn has(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }
}

/// `--members` / `--combiner` overrides on top of a base ensemble
/// config. Without `--members`, a `--m` flag re-thresholds the whole
/// default roster (with `--members`, each spec carries its own `m`).
fn ensemble_from_flags(
    flags: &Flags,
    base: EnsembleConfig,
) -> Result<EnsembleConfig, CliError> {
    let combiner = match flags.get("combiner") {
        Some(c) => c.parse::<CombinerKind>()?,
        None => base.combiner,
    };
    match flags.get("members") {
        Some(list) => Ok(EnsembleConfig::from_member_list(list, combiner)?),
        None => {
            let mut cfg = EnsembleConfig { combiner, ..base };
            if flags.has("m") {
                let m: f64 = flags.parse_as("m", 3.0f64)?;
                if m <= 0.0 {
                    return Err("--m must be > 0".into());
                }
                for member in &mut cfg.members {
                    member.m = m;
                }
            }
            Ok(cfg)
        }
    }
}

/// Replay a recorded trace through an ensemble as stream 0; returns the
/// fused outlier flag per sample (trace order).
fn run_ensemble_over_trace(
    cfg: &EnsembleConfig,
    samples: &[Vec<f64>],
    n_features: usize,
) -> Result<Vec<bool>, CliError> {
    let mut eng = EnsembleEngine::new(cfg, n_features)?;
    let mut out = vec![false; samples.len()];
    for (seq, values) in samples.iter().enumerate() {
        let sample = Sample {
            stream_id: 0,
            seq: seq as u64,
            values: values.clone(),
        };
        for v in eng.ingest(&sample)? {
            out[v.seq as usize] = v.outlier;
        }
    }
    for v in eng.flush()? {
        out[v.seq as usize] = v.outlier;
    }
    Ok(out)
}

fn cmd_serve(flags: &Flags) -> Result<(), CliError> {
    let mut cfg = match flags.get("config") {
        Some(path) => ServiceConfig::load(path)?,
        None => ServiceConfig::default(),
    };
    if let Some(engine) = flags.get("engine") {
        cfg.engine = engine.parse::<EngineKind>()?;
    }
    cfg.ensemble = ensemble_from_flags(flags, cfg.ensemble)?;
    cfg.workers = flags.parse_as("workers", cfg.workers)?;
    cfg.seed = flags.parse_as("seed", cfg.seed)?;
    cfg.checkpoint_every =
        flags.parse_as("checkpoint-interval", cfg.checkpoint_every)?;
    if flags.has("restore") {
        cfg.restore_on_resume = true;
    }
    if let Some(dir) = flags.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(dir.into());
    }
    cfg.evict_after = flags.parse_as("evict-after", cfg.evict_after)?;
    if flags.has("recover") {
        // Recovered checkpoints are useless unless resuming streams
        // adopt them.
        cfg.restore_on_resume = true;
    }
    cfg.sharding.virtual_shards =
        flags.parse_as("virtual-shards", cfg.sharding.virtual_shards)?;
    cfg.sharding.rebalance_interval = flags
        .parse_as("rebalance-interval", cfg.sharding.rebalance_interval)?;
    if let Some(addr) = flags.get("metrics-addr") {
        cfg.obs.metrics_addr = Some(addr.to_string());
    }
    if let Some(listen) = flags.get("cluster-listen") {
        cfg.cluster.listen = Some(listen.to_string());
    }
    cfg.cluster.node_id = flags.parse_as("node-id", cfg.cluster.node_id)?;
    if let Some(peers) = flags.get("peer") {
        cfg.cluster
            .peers
            .extend(peers.split(',').map(str::to_string));
    }
    cfg.cluster.heartbeat_ms =
        flags.parse_as("heartbeat-ms", cfg.cluster.heartbeat_ms)?;
    cfg.cluster.failover_ms =
        flags.parse_as("failover-ms", cfg.cluster.failover_ms)?;
    if let Some(sponsor) = flags.get("join") {
        cfg.cluster.join = Some(sponsor.to_string());
    }
    cfg.cluster.rebalance_ms =
        flags.parse_as("cluster-rebalance-ms", cfg.cluster.rebalance_ms)?;
    cfg.cluster.ingest_buffer =
        flags.parse_as("ingest-buffer", cfg.cluster.ingest_buffer)?;
    if !cfg.cluster.peers.is_empty() && !cfg.cluster.enabled() {
        return Err("--peer needs --cluster-listen (this node must be \
                    reachable too)"
            .into());
    }
    if cfg.cluster.join.is_some() && !cfg.cluster.enabled() {
        return Err("--join needs --cluster-listen (peers must be able \
                    to dial back)"
            .into());
    }
    teda_fpga::obs::recorder()
        .configure(cfg.obs.recorder, cfg.obs.recorder_capacity);
    let workers_max: usize = flags.parse_as("workers-max", cfg.workers)?;
    if workers_max < cfg.workers {
        return Err("--workers-max must be ≥ --workers".into());
    }
    let streams: u64 = flags.parse_as("streams", 16u64)?;
    let samples: usize = flags.parse_as("samples", 10_000usize)?;

    println!(
        "serving {streams} streams × {samples} samples on {} engine, {} workers",
        cfg.engine, cfg.workers
    );
    let t0 = std::time::Instant::now();
    let svc = if flags.has("recover") {
        let dir = cfg.checkpoint_dir.clone().ok_or(
            "--recover needs --checkpoint-dir (or checkpoint.dir in the \
             config file)",
        )?;
        let store = teda_fpga::persist::FileStore::open(
            &dir,
            cfg.checkpoint_keep,
        )?;
        let svc =
            Service::start_from_store(cfg.clone(), std::sync::Arc::new(store))?;
        println!(
            "recovered {} stream checkpoints from {}",
            svc.state_manager().len(),
            dir.display()
        );
        svc
    } else {
        Service::start(cfg.clone())?
    };
    // The cluster control plane shares the service with this loop;
    // single-node serves skip the Arc indirection's plumbing entirely.
    let svc = std::sync::Arc::new(svc);
    let cluster = if cfg.cluster.enabled() {
        let node = ClusterNode::start(svc.clone(), &cfg.cluster)?;
        let up = node.hello_peers();
        println!(
            "cluster node {} on {} — epoch {}, {} of {} shards owned, \
             {} peers up",
            node.node_id(),
            node.bound_addr(),
            node.epoch(),
            node.owned_shards().len(),
            cfg.sharding.virtual_shards,
            up,
        );
        if cfg.cluster.join.is_some() {
            // Dynamic join: the roster + table arrived from the
            // sponsor; now take on a uniform share of the shards via
            // the ordinary seal → adopt pulls (mid-stream safe).
            let pulled = node.pull_share()?;
            println!(
                "joined via {} — pulled {pulled} shard(s), epoch {}, \
                 {} of {} owned",
                cfg.cluster.join.as_deref().unwrap_or("?"),
                node.epoch(),
                node.owned_shards().len(),
                cfg.sharding.virtual_shards,
            );
        }
        Some(node)
    } else {
        None
    };
    let mut metrics_server = match &cfg.obs.metrics_addr {
        Some(addr) => {
            let srv = teda_fpga::obs::MetricsServer::start(
                addr,
                svc.metrics(),
                svc.ensemble_metrics(),
            )?;
            println!(
                "metrics endpoint on http://{}/metrics (also / and /trace)",
                srv.local_addr()
            );
            Some(srv)
        }
        None => None,
    };
    let mut sources: Vec<SyntheticSource> = (0..streams)
        .map(|sid| {
            SyntheticSource::new(sid, cfg.n_features, samples, cfg.seed)
                .with_outliers(0.001)
        })
        .collect();
    let rebalance_every = cfg.sharding.rebalance_interval;
    let handle = svc.handle();
    let cluster_handle = cluster.as_ref().map(|n| n.handle());
    let mut submitted: u64 = 0;
    let mut next_rebalance = rebalance_every;
    let mut round: usize = 0;
    // Windowed progress: deltas-per-interval, not lifetime counters.
    let mut window = svc.metrics_window();
    let report_every = (samples / 4).max(1);
    // Autoscale signals: a dedicated delta window so scale checks see
    // rates since the *last check*, not since the last progress line.
    let mut scale_window = svc.metrics_window();
    let scale_check_every = (samples / 20).max(1);
    // Queue-wait p99 SLO the autoscaler defends (5 ms).
    const SCALE_SLO_NS: u64 = 5_000_000;
    loop {
        // One batched submit per round: the whole cross-stream burst
        // is routed under a single snapshot and enqueued with one
        // ring/channel operation per worker.
        let mut round_burst = Vec::with_capacity(sources.len());
        for src in &mut sources {
            if let Some(s) = src.next_sample() {
                round_burst.push(s);
            }
        }
        if round_burst.is_empty() {
            break;
        }
        submitted += round_burst.len() as u64;
        match &cluster_handle {
            // Cluster mode: route by node ownership — locally-owned
            // samples take the local hot path, the rest ship to peers.
            // A peer that is briefly unreachable (still starting, just
            // killed, mid-failover) is absorbed by the handle's bounded
            // park-and-replay buffer, so submit_batch usually succeeds
            // even mid-failover. It only errs once the buffer is full
            // (or buffering is off); then retry until the table heals.
            // The locally-submitted half of a partial first attempt is
            // re-dropped by the workers' watermark dedup, so
            // re-submitting the whole burst is safe.
            Some(ch) => {
                let deadline = std::time::Instant::now()
                    + std::time::Duration::from_secs(10);
                loop {
                    match ch.submit_batch(round_burst.clone()) {
                        Ok(()) => break,
                        Err(_) if std::time::Instant::now() < deadline => {
                            std::thread::sleep(
                                std::time::Duration::from_millis(50),
                            );
                        }
                        Err(e) => return Err(e.into()),
                    }
                }
            }
            None => handle.submit_batch(round_burst)?,
        }
        round += 1;
        // Live worker scaling: grow toward --workers-max when the
        // observability plane reports real pressure — a data ring
        // ≥ 3/4 full, backpressure events in the last window, or a
        // windowed queue-wait p99 over the SLO. (Was: a fixed
        // halfway-sample demo trigger.)
        if round % scale_check_every == 0
            && (workers_max > svc.workers() || cluster.is_some())
        {
            let report = scale_window.tick(&svc.metrics());
            let wanted = scale_up_wanted(
                &svc.queue_depths(),
                cfg.queue_capacity,
                report.delta("backpressure_events"),
                report.p99("queue_wait"),
                SCALE_SLO_NS,
            );
            if wanted && workers_max > svc.workers() {
                let n = (svc.workers() + 1).min(workers_max);
                svc.scale_to(n)?;
                println!(
                    "scaled to {n} workers at sample {submitted} \
                     (queue pressure; epoch {})",
                    svc.table().epoch()
                );
            } else if let Some(node) = &cluster {
                // Same pressure trigger, escalated cluster-wide:
                // local worker scaling exhausted means this node
                // recommends adding a node (visible as the
                // node_scale_hint gauge and in `teda-fpga cluster`).
                node.set_scale_hint(wanted);
            }
        }
        if rebalance_every > 0 && submitted >= next_rebalance {
            next_rebalance += rebalance_every;
            let moves = svc.maybe_rebalance()?;
            if !moves.is_empty() {
                println!(
                    "rebalanced {} shard(s) at sample {} (epoch {})",
                    moves.len(),
                    submitted,
                    svc.table().epoch()
                );
            }
        }
        if round % report_every == 0 {
            println!("  {}", window.tick(&svc.metrics()).render());
        }
    }
    let metrics = svc.metrics();
    let ens_metrics = svc.ensemble_metrics();
    let state_mgr = svc.state_manager();
    // Tear down the control plane before finishing the node core: the
    // cluster handle and node both share the service Arc.
    drop(cluster_handle);
    if let Some(node) = cluster {
        node.shutdown()?;
    }
    let svc = std::sync::Arc::try_unwrap(svc)
        .map_err(|_| "service still shared at shutdown")?;
    let out = svc.finish()?;
    let dt = t0.elapsed();
    if let Some(srv) = metrics_server.as_mut() {
        srv.stop();
    }
    if flags.has("trace-dump") {
        println!("{}", teda_fpga::obs::recorder().render_tail(64));
    }
    println!("{}", metrics.render());
    if let Some(em) = ens_metrics {
        println!("{}", em.render());
    }
    if cfg.checkpoint_every > 0 {
        println!(
            "checkpoints: {} streams (interval {} samples, restore {}, \
             durable {})",
            state_mgr.len(),
            cfg.checkpoint_every,
            if cfg.restore_on_resume { "on" } else { "off" },
            match &cfg.checkpoint_dir {
                Some(dir) => dir.display().to_string(),
                None => "off".into(),
            }
        );
        if state_mgr.persist_errors() > 0 {
            eprintln!(
                "warning: {} checkpoint persist errors",
                state_mgr.persist_errors()
            );
        }
    }
    println!(
        "processed {} samples in {:.3}s — {:.0} samples/s end-to-end",
        out.len(),
        dt.as_secs_f64(),
        out.len() as f64 / dt.as_secs_f64()
    );
    Ok(())
}

/// `teda-fpga cluster` — probe a running cluster node over the framed
/// transport: one Status request, print the StatusText reply (node id,
/// bound address, table epoch, shard ownership, peer liveness).
fn cmd_cluster(flags: &Flags) -> Result<(), CliError> {
    let addr = flags.get("addr").ok_or(
        "cluster needs --addr HOST:PORT or unix:/path (the serve \
         --cluster-listen)",
    )?;
    let client = RpcClient::new(PeerAddr::parse(addr)?);
    match client.rpc(&Msg::Status)? {
        Msg::StatusText { text } => {
            print!("{text}");
            Ok(())
        }
        other => Err(format!(
            "node {addr} sent an unexpected {} reply to a status probe",
            other.label()
        )
        .into()),
    }
}

/// `teda-fpga trace` — fetch and print the flight-recorder tail of a
/// *running* serve process via its metrics endpoint. (The journal
/// lives in the serving process; a fresh CLI process has its own,
/// empty recorder, so this goes over HTTP on purpose.)
fn cmd_trace(flags: &Flags) -> Result<(), CliError> {
    let addr = flags
        .get("addr")
        .ok_or("trace needs --addr HOST:PORT (the serve --metrics-addr)")?;
    print!("{}", http_get_text(addr, "/trace")?);
    Ok(())
}

/// Minimal HTTP/1.1 GET returning the response body (dependency-free;
/// pairs with [`teda_fpga::obs::MetricsServer`]'s one-request model).
fn http_get_text(addr: &str, path: &str) -> Result<String, CliError> {
    use std::io::{Read, Write};
    let mut conn = std::net::TcpStream::connect(addr)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    conn.set_read_timeout(Some(std::time::Duration::from_secs(2)))?;
    conn.write_all(
        format!(
            "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
        )
        .as_bytes(),
    )?;
    let mut raw = String::new();
    conn.read_to_string(&mut raw)?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .ok_or_else(|| format!("malformed HTTP response from {addr}"))?;
    let status = head.split_whitespace().nth(1).unwrap_or("");
    if status != "200" {
        return Err(format!("{addr}{path} returned HTTP {status}").into());
    }
    Ok(body.to_string())
}

/// `teda-fpga shards` — shard-map diagnostic: the shard → worker
/// table, per-shard/per-worker stream counts for a synthetic id range
/// (what `Router::load` used to report), and the epoch.
fn cmd_shards(flags: &Flags) -> Result<(), CliError> {
    let mut cfg = match flags.get("config") {
        Some(path) => ServiceConfig::load(path)?,
        None => ServiceConfig::default(),
    };
    cfg.workers = flags.parse_as("workers", cfg.workers)?;
    cfg.sharding.virtual_shards =
        flags.parse_as("virtual-shards", cfg.sharding.virtual_shards)?;
    cfg.validate()?; // clean CLI error instead of a construction panic
    let streams: u64 = flags.parse_as("streams", 16u64)?;
    let table =
        ShardTable::new_uniform(cfg.sharding.virtual_shards, cfg.workers);
    println!(
        "shard map: {} virtual shards × {} workers, epoch {}",
        table.virtual_shards(),
        table.workers(),
        table.epoch()
    );
    let per_worker = table.load(0..streams);
    let per_shard = table.shard_load(0..streams);
    let shard_counts = table.shard_counts();
    println!("\n  worker  shards  streams (of {streams})");
    for (w, (&shards, &strms)) in
        shard_counts.iter().zip(per_worker.iter()).enumerate()
    {
        println!("  {w:>6}  {shards:>6}  {strms:>7}");
    }
    if flags.has("full") {
        println!("\n  shard → worker   streams");
        for shard in 0..table.virtual_shards() {
            println!(
                "  {shard:>5} → {:>6}   {:>7}",
                table.worker_of(shard),
                per_shard[shard as usize]
            );
        }
    } else {
        let occupied =
            per_shard.iter().filter(|&&c| c > 0).count();
        println!(
            "\n  {occupied} of {} shards occupied (--full for the whole \
             table)",
            table.virtual_shards()
        );
    }
    Ok(())
}

/// `teda-fpga rebalance` — the rebalance-under-churn smoke: run the
/// same deterministic workload twice, once undisturbed and once with
/// forced mid-stream shard migrations plus a live worker resize, and
/// fail unless the verdicts match bit-for-bit.
fn cmd_rebalance(flags: &Flags) -> Result<(), CliError> {
    let engine: EngineKind =
        flags.get("engine").unwrap_or("software").parse()?;
    let workers: usize = flags.parse_as("workers", 3usize)?;
    let streams: u64 = flags.parse_as("streams", 8u64)?;
    let samples: u64 = flags.parse_as("samples", 3000u64)?;
    let seed: u64 = flags.parse_as("seed", 0x7EDAu64)?;
    if workers < 2 {
        return Err("rebalance needs --workers ≥ 2".into());
    }
    let cfg = ServiceConfig {
        engine,
        workers,
        n_features: 2,
        queue_capacity: 1024,
        ..Default::default()
    };
    let sample = |sid: u64, seq: u64| {
        let mut rng = SplitMix64::new(seed ^ sid.wrapping_mul(0x9E37) ^ seq);
        Sample {
            stream_id: sid,
            seq,
            values: vec![rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0)],
        }
    };
    type VerdictMap =
        std::collections::BTreeMap<(u64, u64), teda_fpga::engine::EngineVerdict>;
    let index = |out: Vec<teda_fpga::coordinator::Classified>| -> Result<VerdictMap, CliError> {
        let mut map = VerdictMap::new();
        for c in out {
            let key = (c.verdict.stream_id, c.verdict.seq);
            if let Some(prev) = map.get(&key) {
                // Replay duplicates are only legal as identical
                // re-derivations — contradictory ones are a bug the
                // smoke must catch, not mask by overwrite.
                if prev.k != c.verdict.k
                    || prev.outlier != c.verdict.outlier
                    || prev.zeta.to_bits() != c.verdict.zeta.to_bits()
                {
                    return Err(format!(
                        "contradictory duplicate verdicts at {key:?}"
                    )
                    .into());
                }
            } else {
                map.insert(key, c.verdict);
            }
        }
        Ok(map)
    };

    println!(
        "rebalance smoke: {streams} streams × {samples} samples, {engine} \
         engine, {workers} workers"
    );
    // Undisturbed reference run.
    let svc = Service::start(cfg.clone())?;
    for seq in 0..samples {
        for sid in 0..streams {
            svc.submit(sample(sid, seq))?;
        }
    }
    let reference = index(svc.finish()?)?;

    // Churn run: migrate all of worker 0's shards away at 1/3, scale
    // the pool up at 1/2, back down at 3/4.
    let svc = Service::start(cfg)?;
    for seq in 0..samples {
        for sid in 0..streams {
            svc.submit(sample(sid, seq))?;
        }
        if seq == samples / 3 {
            let moves: Vec<(u32, usize)> = svc
                .table()
                .shards_on(0)
                .into_iter()
                .map(|s| (s, workers - 1))
                .collect();
            svc.migrate_shards(&moves)?;
            println!(
                "  seq {seq}: migrated {} shards 0 → {} (epoch {})",
                moves.len(),
                workers - 1,
                svc.table().epoch()
            );
        }
        if seq == samples / 2 {
            svc.scale_to(workers + 1)?;
            println!(
                "  seq {seq}: scaled to {} workers (epoch {})",
                workers + 1,
                svc.table().epoch()
            );
        }
        if seq == samples * 3 / 4 {
            svc.scale_to(workers)?;
            println!(
                "  seq {seq}: scaled back to {workers} workers (epoch {})",
                svc.table().epoch()
            );
        }
    }
    let metrics = svc.metrics();
    let state = svc.state_manager();
    let churned = index(svc.finish()?)?;

    if metrics.migrations.get() == 0 {
        return Err("churn run performed no migrations".into());
    }
    // Every migrated stream left a seal watermark behind.
    let checkpointed = state.stream_ids();
    if checkpointed.is_empty() {
        return Err("migrations published no seal watermarks".into());
    }
    if churned.len() != reference.len() {
        return Err(format!(
            "verdict count diverged: {} churned vs {} reference",
            churned.len(),
            reference.len()
        )
        .into());
    }
    for (key, a) in &reference {
        let Some(b) = churned.get(key) else {
            return Err(format!("verdict missing at {key:?}").into());
        };
        if a.k != b.k
            || a.outlier != b.outlier
            || a.zeta.to_bits() != b.zeta.to_bits()
            || a.threshold.to_bits() != b.threshold.to_bits()
        {
            return Err(format!(
                "verdict diverged at {key:?}: {a:?} vs {b:?}"
            )
            .into());
        }
    }
    println!(
        "  parity OK: {} verdicts bit-identical across {} migrations \
         ({} streams handed over, {} strays re-routed, {} seal \
         watermarks published)",
        churned.len(),
        metrics.migrations.get(),
        metrics.streams_migrated.get(),
        metrics.stray_reroutes.get(),
        checkpointed.len(),
    );
    Ok(())
}

/// `teda-fpga bench-trend` — fold every `BENCH_*.json` at the repo
/// root into the cumulative `BENCH_trend.json` (CI runs this after its
/// bench step so per-PR perf trajectory accumulates).
fn cmd_bench_trend(flags: &Flags) -> Result<(), CliError> {
    let root = match flags.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .ok_or("cargo manifest dir has no parent")?
            .to_path_buf(),
    };
    let updated = teda_fpga::util::benchkit::sync_trend(&root)?;
    if updated.is_empty() {
        println!("BENCH_trend.json already up to date in {}", root.display());
    } else {
        println!(
            "appended {} bench result(s) to {}: {}",
            updated.len(),
            root.join("BENCH_trend.json").display(),
            updated.join(", ")
        );
    }
    Ok(())
}

/// Pull `{"metric": .., "value": ..}` rows out of a bench result doc.
fn metric_map(doc: &Json) -> HashMap<String, f64> {
    let mut map = HashMap::new();
    if let Some(rows) = doc.get("results").and_then(Json::as_arr) {
        for row in rows {
            if let (Some(name), Some(v)) = (
                row.get("metric").and_then(Json::as_str),
                row.get("value").and_then(Json::as_f64),
            ) {
                map.insert(name.to_string(), v);
            }
        }
    }
    map
}

/// One trend series gated by `bench-gate`: the key it was appended
/// under in `BENCH_trend.json`, the fresh `BENCH_<key>.json` file it
/// is compared against, and which metric directions count as a
/// regression. Counter metrics (migration totals, drop counts) are
/// informational and never gate.
struct GateSeries {
    key: &'static str,
    lower_better: &'static [&'static str],
    higher_better: &'static [&'static str],
    /// A required series errors when its fresh file is missing; an
    /// optional one skips with a notice (partial CI runs and older
    /// checkouts don't emit every bench).
    required: bool,
}

const GATE_SERIES: [GateSeries; 3] = [
    GateSeries {
        key: "shard",
        lower_better: &[
            "route_ns",
            "route_snapshot_ns",
            "migration_ns",
            "migration_p99_ns",
        ],
        higher_better: &[
            "throughput_single_sps",
            "throughput_before_sps",
            "throughput_after_rebalance_sps",
        ],
        required: true,
    },
    GateSeries {
        key: "cluster",
        lower_better: &[
            "join_to_routable_ns",
            "shard_move_ns",
            "burst_drain_ns",
        ],
        higher_better: &[],
        required: false,
    },
    // Batch-native engine kernels: the coalesced path must stay ahead
    // of (or at least not regress against) its committed baseline, and
    // the single-submit baseline guards the per-sample path the batch
    // kernels share state with. XLA rows are artifact-gated and so not
    // listed — a missing metric skips with a notice.
    GateSeries {
        key: "engine",
        lower_better: &[],
        higher_better: &[
            "software_single_sps",
            "software_batch_rl64_sps",
            "rtl_batch_rl64_sps",
            "ensemble_batch_rl64_sps",
        ],
        required: true,
    },
];

/// `teda-fpga bench-gate` — the CI perf regression gate: compare each
/// freshly emitted `BENCH_<series>.json` against the most recent
/// *different* entry in that series of the committed
/// `BENCH_trend.json` (the fresh run usually self-appended as the
/// tail) and fail when a gated latency or throughput metric regressed
/// beyond `--max-regress`. A missing trend, series, or metric passes
/// with a notice — the gate only bites once a baseline exists to
/// compare against.
fn cmd_bench_gate(flags: &Flags) -> Result<(), CliError> {
    let root = match flags.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .ok_or("cargo manifest dir has no parent")?
            .to_path_buf(),
    };
    let max_regress: f64 = flags.parse_as("max-regress", 0.20f64)?;
    if !(0.0..1.0).contains(&max_regress) {
        return Err("--max-regress must be in [0, 1)".into());
    }
    let trend_path = root.join("BENCH_trend.json");
    let trend = match std::fs::read_to_string(&trend_path) {
        Ok(text) => Some(
            Json::parse(&text)
                .map_err(|e| format!("{}: {e}", trend_path.display()))?,
        ),
        Err(_) => {
            println!(
                "bench-gate: no {} — pass with notice (no baseline yet)",
                trend_path.display()
            );
            None
        }
    };
    println!("bench-gate: max regression {:.0}%", max_regress * 100.0);
    let mut checked = 0usize;
    let mut failures: Vec<String> = Vec::new();
    for series in &GATE_SERIES {
        let fresh_path = root.join(format!("BENCH_{}.json", series.key));
        let fresh_text = match std::fs::read_to_string(&fresh_path) {
            Ok(t) => t,
            Err(e) if series.required => {
                return Err(format!(
                    "{}: {e} (run `cargo bench --bench {}` first)",
                    fresh_path.display(),
                    series.key
                )
                .into());
            }
            Err(_) => {
                println!(
                    "bench-gate: no {} — {} series skipped",
                    fresh_path.display(),
                    series.key
                );
                continue;
            }
        };
        let fresh = Json::parse(&fresh_text)
            .map_err(|e| format!("{}: {e}", fresh_path.display()))?;
        let current = metric_map(&fresh);
        if current.is_empty() {
            return Err(format!(
                "{} emitted no metric rows — the bench is broken, not \
                 merely slow",
                fresh_path.display()
            )
            .into());
        }
        let baseline = trend
            .as_ref()
            .and_then(|t| t.get(series.key))
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .rev()
            .filter_map(|entry| entry.get("results"))
            .find(|doc| **doc != fresh)
            .map(metric_map);
        let Some(baseline) = baseline else {
            println!(
                "bench-gate: no prior {} baseline in {} — pass with notice",
                series.key,
                trend_path.display()
            );
            continue;
        };
        let gated = series
            .lower_better
            .iter()
            .map(|&n| (n, true))
            .chain(series.higher_better.iter().map(|&n| (n, false)));
        for (name, lower_better) in gated {
            let (Some(&cur), Some(&base)) =
                (current.get(name), baseline.get(name))
            else {
                println!("  {name:<32} no baseline — skipped");
                continue;
            };
            checked += 1;
            // Regression fraction, positive = worse.
            let regress = if lower_better {
                cur / base - 1.0
            } else {
                1.0 - cur / base
            };
            let delta_pct = (cur / base - 1.0) * 100.0;
            println!(
                "  {name:<32} {base:>14.1} → {cur:>14.1}  ({delta_pct:+.1}%)"
            );
            if base > 0.0 && regress > max_regress {
                failures.push(format!(
                    "{name}: {base:.1} → {cur:.1} ({delta_pct:+.1}%, limit \
                     ±{:.0}%)",
                    max_regress * 100.0
                ));
            }
        }
    }
    if checked == 0 {
        println!("bench-gate: no comparable metrics — pass with notice");
        return Ok(());
    }
    if !failures.is_empty() {
        return Err(format!(
            "perf regression gate failed:\n  {}",
            failures.join("\n  ")
        )
        .into());
    }
    println!(
        "bench-gate OK: {checked} metric(s) within {:.0}% of baseline",
        max_regress * 100.0
    );
    Ok(())
}

fn cmd_detect(flags: &Flags) -> Result<(), CliError> {
    let item: u32 = flags.parse_as("item", 1u32)?;
    let m: f64 = flags.parse_as("m", 3.0f64)?;
    let seed: u64 = flags.parse_as("seed", 2001u64)?;
    let engine = flags.get("engine").unwrap_or("software");
    let event =
        schedule_item(item).ok_or_else(|| format!("no Table 2 item {item}"))?;
    println!(
        "fault item {item}: {} ({}) window {}..{} — engine {engine}",
        event.fault, event.description, event.start, event.end
    );
    let trace = ActuatorSim::with_seed(seed).generate_day(Some(&event));
    // Every detect engine runs through the same service ingest path
    // (1 worker, batched submits) that `serve` uses — the CLI exercises
    // the production hot path instead of a per-engine side door.
    let kind = match engine {
        "software" => EngineKind::Software,
        "rtl" => EngineKind::Rtl,
        "ensemble" => EngineKind::Ensemble,
        other => {
            return Err(format!(
                "detect supports software|rtl|ensemble, got {other}"
            )
            .into())
        }
    };
    let mut cfg = ServiceConfig {
        engine: kind,
        workers: 1,
        n_features: 2,
        m,
        ..Default::default()
    };
    if kind == EngineKind::Ensemble {
        cfg.ensemble = ensemble_from_flags(flags, EnsembleConfig::default())?;
        println!(
            "ensemble: [{}] via {}",
            cfg.ensemble.labels().join(", "),
            cfg.ensemble.combiner
        );
    }
    let svc = Service::start(cfg)?;
    let handle = svc.handle();
    for (base, chunk) in trace.samples.chunks(256).enumerate() {
        let batch: Vec<Sample> = chunk
            .iter()
            .enumerate()
            .map(|(i, values)| Sample {
                stream_id: 0,
                seq: (base * 256 + i) as u64,
                values: values.clone(),
            })
            .collect();
        handle.submit_batch(batch)?;
    }
    let mut outlier_flags = vec![false; trace.samples.len()];
    for c in svc.finish()? {
        outlier_flags[c.verdict.seq as usize] = c.verdict.outlier;
    }
    let report = evaluate_detection(&outlier_flags, &event, 1000);
    println!(
        "detected={} latency={:?} hits={}/{} false_alarm_rate={:.5}",
        report.detected(),
        report.latency,
        report.hits_in_window,
        report.window_len,
        report.false_alarm_rate()
    );
    if let Some(csv) = flags.get("csv") {
        trace.write_csv(csv)?;
        println!("trace written to {csv}");
    }
    Ok(())
}

fn cmd_synth(flags: &Flags) -> Result<(), CliError> {
    let n: usize = flags.parse_as("n-features", 2usize)?;
    let rtl = TedaRtl::new(n, 3.0)?;
    let occ = OccupationReport::analyze(rtl.netlist(), Virtex6::xc6vlx240t());
    let timing = PipelineTiming::analyze(rtl.netlist());
    println!("TEDA RTL synthesis estimate (N={n} features)\n");
    println!("{}", occ.render_table3());
    println!("{}", timing.render_table4());
    let path = critical_path(rtl.netlist());
    println!("critical path: {}", path.path.join(" → "));
    if flags.has("netlist") {
        println!("\nnetlist:\n{}", rtl.netlist().dump());
    }
    Ok(())
}

fn cmd_damadics(flags: &Flags) -> Result<(), CliError> {
    if flags.has("catalog") {
        println!("Table 1: Fault types");
        for (f, desc) in fault_catalog() {
            println!("  {f}  {desc}");
        }
        return Ok(());
    }
    if flags.has("schedule") {
        println!("Table 2: Artificial failures introduced to actuator 1");
        for e in actuator1_schedule() {
            println!(
                "  item {} {} samples {:>5}-{:<5} {} — {}",
                e.item, e.fault, e.start, e.end, e.date, e.description
            );
        }
        return Ok(());
    }
    let item: u32 = flags.parse_as("item", 1u32)?;
    let seed: u64 = flags.parse_as("seed", 2001u64)?;
    let event =
        schedule_item(item).ok_or_else(|| format!("no Table 2 item {item}"))?;
    let trace = ActuatorSim::with_seed(seed).generate_day(Some(&event));
    match flags.get("csv") {
        Some(csv) => {
            trace.write_csv(csv)?;
            println!("wrote {} samples to {csv}", trace.len());
        }
        None => println!(
            "generated {} samples (item {item}, fault {}) — use --csv to save",
            trace.len(),
            event.fault
        ),
    }
    Ok(())
}

fn cmd_ensemble(flags: &Flags) -> Result<(), CliError> {
    let ecfg = ensemble_from_flags(flags, EnsembleConfig::default())?;
    let workers: usize = flags.parse_as("workers", 4usize)?;
    let n: usize = flags.parse_as("n-features", 2usize)?;
    println!(
        "ensemble: [{}] via {} ({} workers, N={n})\n",
        ecfg.labels().join(", "),
        ecfg.combiner,
        workers
    );
    let plan = PartitionPlan::plan(
        &ecfg.members,
        n,
        workers,
        Virtex6::xc6vlx240t(),
    )?;
    println!("{}", plan.render());

    // Optional one-shot fused detection demo on a Table 2 fault item.
    if flags.has("item") {
        let item: u32 = flags.parse_as("item", 1u32)?;
        let seed: u64 = flags.parse_as("seed", 2001u64)?;
        let event = schedule_item(item)
            .ok_or_else(|| format!("no Table 2 item {item}"))?;
        let trace = ActuatorSim::with_seed(seed).generate_day(Some(&event));
        println!(
            "fault item {item}: {} ({}) window {}..{}",
            event.fault, event.description, event.start, event.end
        );
        // Single TEDA reference.
        let mut det = teda_fpga::teda::TedaDetector::new(2, 3.0);
        let single: Vec<bool> =
            trace.samples.iter().map(|s| det.step(s).outlier).collect();
        let single_report = evaluate_detection(&single, &event, 1000);
        // Fused ensemble.
        let fused = run_ensemble_over_trace(&ecfg, &trace.samples, 2)?;
        let fused_report = evaluate_detection(&fused, &event, 1000);
        println!(
            "  single teda(m=3): detected={} latency={:?} far={:.5}",
            single_report.detected(),
            single_report.latency,
            single_report.false_alarm_rate()
        );
        println!(
            "  fused ensemble:   detected={} latency={:?} far={:.5}",
            fused_report.detected(),
            fused_report.latency,
            fused_report.false_alarm_rate()
        );
    }
    Ok(())
}

fn cmd_doctor() -> Result<(), CliError> {
    println!("teda-fpga doctor");
    // 1. artifacts + PJRT round trip
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let m = teda_fpga::runtime::Manifest::load(dir)?;
        println!(
            "  artifacts: OK ({} variants, jax {})",
            m.variants.len(),
            m.jax_version
        );
        let rt = teda_fpga::runtime::XlaRuntime::new(dir)?;
        let exe = rt.load(&m.variants[0].name)?;
        let spec = exe.spec();
        let mu = vec![0f32; spec.s * spec.n];
        let var = vec![0f32; spec.s];
        let k = vec![0f32; spec.s];
        let x = vec![0.5f32; spec.s * spec.t * spec.n];
        let outs = exe.run_f32(&[&mu, &var, &k, &x])?;
        println!(
            "  pjrt: OK (platform {}, {} outputs, k'={})",
            rt.platform(),
            outs.len(),
            outs[5][0]
        );
    } else {
        println!("  artifacts: MISSING — run `make artifacts`");
    }
    // 2. RTL self-check
    let rtl = TedaRtl::new(2, 3.0)?;
    let t = PipelineTiming::analyze(rtl.netlist());
    println!(
        "  rtl: OK (t_c = {} ns, {:.1} MSPS)",
        t.critical_ns,
        t.throughput_sps / 1e6
    );
    // 3. DAMADICS smoke
    let event = schedule_item(1).unwrap();
    let trace = ActuatorSim::with_seed(2001).generate_day(Some(&event));
    let mut src = ReplaySource::new(0, trace).with_limit(10);
    let mut n = 0;
    while src.next_sample().is_some() {
        n += 1;
    }
    println!("  damadics: OK ({n} samples replayed)");
    Ok(())
}

//! Stream → worker routing.

use crate::util::propkit::fnv1a;

/// Stable stream-id → worker-index router.
///
/// Uses FNV-1a over the little-endian stream id so the mapping is
/// deterministic across runs and processes (important for state
/// recovery: a stream's checkpoints are keyed by worker).
#[derive(Debug, Clone)]
pub struct Router {
    workers: usize,
}

impl Router {
    /// # Panics
    /// Panics when `workers == 0`.
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "router needs at least one worker");
        Router { workers }
    }

    /// Worker index for a stream.
    #[inline]
    pub fn route(&self, stream_id: u64) -> usize {
        (fnv1a(&stream_id.to_le_bytes()) % self.workers as u64) as usize
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Distribution diagnostic: per-worker stream counts for a set of ids.
    pub fn load(&self, stream_ids: impl Iterator<Item = u64>) -> Vec<usize> {
        let mut counts = vec![0usize; self.workers];
        for sid in stream_ids {
            counts[self.route(sid)] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_stable() {
        let r = Router::new(4);
        for sid in 0..100 {
            assert_eq!(r.route(sid), r.route(sid));
            assert!(r.route(sid) < 4);
        }
    }

    #[test]
    fn distribution_roughly_uniform() {
        let r = Router::new(8);
        let load = r.load(0..8000);
        // each worker should get 1000 ± 35%
        for (w, &c) in load.iter().enumerate() {
            assert!(c > 650 && c < 1350, "worker {w}: {c}");
        }
    }

    #[test]
    fn single_worker_takes_all() {
        let r = Router::new(1);
        assert_eq!(r.load(0..50), vec![50]);
    }

    #[test]
    #[should_panic(expected = "at least one worker")]
    fn zero_workers_rejected() {
        Router::new(0);
    }
}

//! The node core's worker side: the per-thread job loop, the engine
//! bookkeeping (ownership, dedup watermarks, checkpoints, eviction),
//! and the worker halves of the seal → adopt migration protocol.
//!
//! Extracted verbatim from the former monolithic `service.rs` so the
//! steady-state data path is one layer, the control plane
//! ([`crate::coordinator::cluster`]) another, and the migration
//! transport ([`crate::coordinator::transport`]) a third. Semantics
//! are intentionally untouched: `tests/rebalance_e2e.rs` and
//! `tests/ingest_stress.rs` run unmodified against this split.

use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::{EngineKind, ServiceConfig};
use crate::coordinator::senders::WorkerSlot;
use crate::coordinator::{shard_of, StateCheckpoint, StateManager};
use crate::engine::{
    runs, Engine, EngineVerdict, RtlEngine, SoftwareEngine, XlaEngine,
};
use crate::ensemble::EnsembleEngine;
use crate::metrics::{EnsembleMetrics, ServiceMetrics, ShardMetrics};
use crate::obs::recorder::{record, EventKind};
use crate::persist::codec;
use crate::runtime::XlaRuntime;
use crate::stream::{Receiver, Sample, Sender};
use crate::{Error, Result};

/// A verdict annotated with its end-to-end latency.
#[derive(Debug, Clone, PartialEq)]
pub struct Classified {
    pub verdict: EngineVerdict,
    /// submit → verdict wall time in ns.
    pub latency_ns: u64,
}

/// A sample that reached a worker no longer owning its shard, carrying
/// its original submit time so re-routing keeps latency accounting
/// honest.
pub(crate) type Stray = (Sample, Instant);

/// A worker thread's join handle (None once joined).
pub(crate) type WorkerHandle = JoinHandle<Result<()>>;

/// One sealed shard set leaving its old worker: every resident stream,
/// snapshotted at its exact watermark and encoded through the persist
/// codec (the migration wire format — the same bytes the cross-process
/// transport ships over TCP/UDS).
pub(crate) struct SealBundle {
    /// Encoded [`StateCheckpoint`]s, one per resident stream.
    pub(crate) records: Vec<Vec<u8>>,
}

pub(crate) enum Job {
    /// A sample plus its submit time. The shard-map epoch it was
    /// routed under is consumed at submit time (one table snapshot per
    /// route); the worker does not need it back: ownership is tracked
    /// by the owned/pending shard sets, which change strictly in queue
    /// order (Seal removes, Adopt adds), so a sample routed under a
    /// stale epoch is detected as "not owned here" and forwarded for
    /// re-routing rather than misprocessed.
    Sample(Sample, Instant),
    /// Amortizes queue synchronization: one ring/channel operation per
    /// burst instead of one per sample (see EXPERIMENTS.md §Perf).
    Batch(Vec<Sample>, Instant),
    /// A batch of re-routed strays, each with its original submit time
    /// (latency accounting stays honest across re-routes). Travels on
    /// the CONTROL channel only: strays must stay FIFO with the
    /// migration control jobs (before their shard's Adopt).
    Replay(Vec<Stray>),
    /// Migration step 2 (old worker): snapshot + evict every resident
    /// stream of these shards, stop owning them, reply with the
    /// encoded bundle.
    Seal { shards: Vec<u32>, reply: Sender<SealBundle> },
    /// Migration step 1 (new worker): samples for these shards may
    /// arrive before their state does — stash them until Adopt.
    Expect { shards: Vec<u32> },
    /// Cancel an Expect whose Adopt is not coming (the cluster layer
    /// lost a failover race): stop stashing for these shards and
    /// re-route anything already stashed as strays.
    Unexpect { shards: Vec<u32> },
    /// Migration step 3 (new worker): restore the sealed streams, take
    /// ownership, then replay the stash in (stream, seq) order through
    /// the inclusive-watermark dedup.
    Adopt { shards: Vec<u32>, records: Vec<Vec<u8>> },
    /// Scale-down: final flush (sent only after every shard has been
    /// migrated off this worker; the thread exits when its queue
    /// closes, so stragglers still get stray-forwarded).
    Retire,
    /// Force pending batches out (end of input).
    Flush,
    /// Die immediately WITHOUT flushing — crash simulation for failover
    /// testing and fast teardown. In-flight engine state is abandoned
    /// exactly as a killed worker would abandon it.
    Abort,
}

/// Worker-side checkpoint/eviction knobs, lifted from [`ServiceConfig`].
#[derive(Clone, Copy)]
struct CheckpointPolicy {
    /// Publish a snapshot every N samples per stream (0 = off).
    every: u64,
    /// Restore the newest checkpoint when a stream resumes mid-sequence.
    restore_on_resume: bool,
    /// Evict a stream idle for N worker-processed samples (0 = never).
    evict_after: u64,
}

impl CheckpointPolicy {
    fn from_cfg(cfg: &ServiceConfig) -> Self {
        CheckpointPolicy {
            every: cfg.checkpoint_every,
            restore_on_resume: cfg.restore_on_resume,
            evict_after: cfg.evict_after,
        }
    }
}

/// Construct the configured engine. PJRT handles are not Send (the xla
/// crate wraps an Rc), so this runs *inside* each worker thread.
fn build_engine(
    cfg: &ServiceConfig,
    ens_metrics: Option<Arc<EnsembleMetrics>>,
) -> Result<Box<dyn Engine>> {
    Ok(match cfg.engine {
        EngineKind::Software => {
            Box::new(SoftwareEngine::new(cfg.n_features, cfg.m))
        }
        EngineKind::Rtl => Box::new(RtlEngine::new(cfg.n_features, cfg.m)),
        EngineKind::Xla => {
            let rt = XlaRuntime::new(&cfg.artifact_dir)?;
            Box::new(
                XlaEngine::new(
                    &rt,
                    cfg.n_features,
                    cfg.batch_max_streams * cfg.chunk_t,
                )?
                // Wait for a full batch of stream chunks before
                // dispatching: padding lanes cost as much as real ones
                // (27× per-sample difference — see the `batcher`
                // bench); stragglers are handled by Flush.
                .with_min_ready(cfg.batch_max_streams),
            )
        }
        EngineKind::Ensemble => {
            let mut eng = EnsembleEngine::new(&cfg.ensemble, cfg.n_features)?;
            if let Some(em) = ens_metrics {
                eng = eng.with_metrics(em);
            }
            Box::new(eng)
        }
    })
}

/// Spawn one worker thread. The worker loop is guarded by
/// `catch_unwind`: a panicking engine takes down its own worker only,
/// bumps `worker_panics`, and surfaces as *that worker's* error when
/// the service drains — never as an anonymous join failure.
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn_worker(
    widx: usize,
    cfg: &ServiceConfig,
    owned: HashSet<u32>,
    slot: Arc<WorkerSlot<Job>>,
    rx: Receiver<Job>,
    res_tx: Sender<Vec<Classified>>,
    stray_tx: Sender<Stray>,
    metrics: Arc<ServiceMetrics>,
    shard_metrics: Arc<ShardMetrics>,
    ens_metrics: Option<Arc<EnsembleMetrics>>,
    state_mgr: Arc<StateManager>,
) -> Result<WorkerHandle> {
    let cfg = cfg.clone();
    std::thread::Builder::new()
        .name(format!("teda-worker-{widx}"))
        .spawn(move || {
            let panic_metrics = metrics.clone();
            let outcome = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
                let mut engine = build_engine(&cfg, ens_metrics)?;
                let mut worker = Worker {
                    widx,
                    virtual_shards: cfg.sharding.virtual_shards,
                    policy: CheckpointPolicy::from_cfg(&cfg),
                    res_tx,
                    stray_tx,
                    metrics,
                    shard_metrics,
                    state_mgr,
                    owned,
                    pending: HashSet::new(),
                    stash: Vec::new(),
                    inflight: HashMap::new(),
                    seen: HashSet::new(),
                    restored_at: HashMap::new(),
                    last_seen: HashMap::new(),
                    last_seq: HashMap::new(),
                    tick: 0,
                    verdict_buf: Vec::new(),
                    sample_buf: Vec::new(),
                    t0_buf: Vec::new(),
                };
                worker.run(rx, &slot, engine.as_mut())
            }));
            // Close the ring on EVERY exit — normal return, error, or
            // panic — so a producer blocked on a full ring unblocks
            // into the control channel's proper closed error instead
            // of spinning forever against a dead consumer.
            slot.close_ring();
            match outcome {
                Ok(result) => result,
                Err(payload) => {
                    panic_metrics.worker_panics.inc();
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| {
                            payload.downcast_ref::<String>().cloned()
                        })
                        .unwrap_or_else(|| "non-string panic".into());
                    // Postmortem: journal the death, then dump the
                    // merged recorder tail — the last events leading
                    // up to the panic, not just a counter bump.
                    record(EventKind::WorkerPanic, 0, 0, widx as u32);
                    if crate::obs::recorder().is_enabled() {
                        eprintln!(
                            "worker {widx} panicked: {msg}\n{}",
                            crate::obs::recorder().render_tail(64)
                        );
                    }
                    Err(Error::Stream(format!(
                        "worker {widx} panicked: {msg}"
                    )))
                }
            }
        })
        .map_err(|e| Error::io("spawn worker", e))
}

/// One worker's loop state: engine-adjacent bookkeeping plus the shard
/// sets driving the migration protocol. Ownership changes strictly in
/// queue order (`Seal` removes, `Adopt` adds), which is what makes the
/// protocol race-free without any cross-thread locking.
struct Worker {
    widx: usize,
    virtual_shards: u32,
    policy: CheckpointPolicy,
    res_tx: Sender<Vec<Classified>>,
    stray_tx: Sender<Stray>,
    metrics: Arc<ServiceMetrics>,
    shard_metrics: Arc<ShardMetrics>,
    state_mgr: Arc<StateManager>,
    /// Shards this worker currently owns.
    owned: HashSet<u32>,
    /// Shards announced by `Expect` whose state has not arrived yet.
    pending: HashSet<u32>,
    /// Samples for pending shards, replayed in (stream, seq) order at
    /// `Adopt`.
    stash: Vec<(Sample, Instant)>,
    /// submit-time of every in-flight sample, for latency accounting.
    inflight: HashMap<(u64, u64), Instant>,
    /// Streams this worker has fed to its engine (restore-on-resume
    /// runs once, before a stream's first sample).
    seen: HashSet<u64>,
    /// Watermark each stream was restored at: re-fed samples at or
    /// below it are already folded into the snapshot and must be
    /// dropped, so an upstream that replays from the watermark
    /// *inclusively* stays exactly-once instead of double-counting.
    restored_at: HashMap<u64, u64>,
    /// Idle-stream eviction bookkeeping: tick each stream last
    /// appeared at.
    last_seen: HashMap<u64, u64>,
    /// Last sequence number folded into the engine per stream — the
    /// exact watermark a migration seals the stream at.
    last_seq: HashMap<u64, u64>,
    /// Samples processed by this worker (eviction clock).
    tick: u64,
    /// Reusable verdict accumulator: bursts drain it into the results
    /// channel through [`Worker::emit`], keeping its capacity across
    /// jobs instead of allocating per `Job::Batch`.
    verdict_buf: Vec<EngineVerdict>,
    /// Coalescing scratch for `Job::Replay`: strays unzip into these so
    /// the run core borrows plain slices (no per-burst allocation).
    sample_buf: Vec<Sample>,
    t0_buf: Vec<Instant>,
}

/// Per-sample submit times for one burst: a direct `Job::Batch` shares
/// one submit instant across the burst, a `Job::Replay` keeps each
/// stray's original time (latency accounting stays honest across
/// re-routes).
enum RunT0<'a> {
    Uniform(Instant),
    Per(&'a [Instant]),
}

impl RunT0<'_> {
    fn at(&self, i: usize) -> Instant {
        match self {
            RunT0::Uniform(t) => *t,
            RunT0::Per(ts) => ts[i],
        }
    }
}

/// What the worker loop does after handling one job.
enum Flow {
    Continue,
    Exit,
}

impl Worker {
    /// Two-plane consumption discipline: exhaust the CONTROL channel
    /// before each single ring pop. Control items (migration protocol,
    /// diverted data from non-claimant producers, stray Replays) are
    /// always at least as old as anything on the ring — the ring
    /// claimant is a single thread, and a stream's samples switch
    /// planes only across a claim change — so channel-first preserves
    /// the per-stream order the protocol depends on. Residual
    /// cross-thread same-stream handoffs fall to the watermark guard,
    /// counted in `stale_drops` (documented contract: one submitting
    /// thread per stream).
    fn run(
        &mut self,
        rx: Receiver<Job>,
        slot: &WorkerSlot<Job>,
        engine: &mut dyn Engine,
    ) -> Result<()> {
        'live: loop {
            loop {
                match rx.try_recv() {
                    Ok(Some(job)) => {
                        if let Flow::Exit = self.handle(engine, slot, job)? {
                            slot.close_ring();
                            return Ok(());
                        }
                    }
                    Ok(None) => break,
                    Err(_) => break 'live,
                }
            }
            if let Some(job) = slot.pop_ring() {
                if let Flow::Exit = self.handle(engine, slot, job)? {
                    slot.close_ring();
                    return Ok(());
                }
                continue;
            }
            // Both planes empty: park on the doorbell (re-checks
            // emptiness under the lock; producers notify after every
            // publish).
            record(EventKind::Park, 0, 0, self.widx as u32);
            slot.park(&rx);
        }
        // Control channel closed (the service's explicit close): stop
        // accepting ring pushes, then drain what already landed —
        // producers racing the closure must not lose samples.
        slot.close_ring();
        while let Some(job) = slot.pop_ring() {
            self.handle(engine, slot, job)?;
        }
        // Final flush for whatever is still buffered.
        let mut verdicts = engine.flush()?;
        self.emit(&mut verdicts, true)?;
        Ok(())
    }

    /// Dispatch one job. Returns whether the loop continues.
    fn handle(
        &mut self,
        engine: &mut dyn Engine,
        slot: &WorkerSlot<Job>,
        job: Job,
    ) -> Result<Flow> {
        match job {
            Job::Sample(sample, t0) => {
                // Single-sample hot path: one extra clock read for the
                // queue-wait split; engine/emit stage timing stays on
                // the batched path only (the < 20% bench-gate budget).
                let t_dq = Instant::now();
                self.metrics
                    .queue_wait
                    .record(t_dq.saturating_duration_since(t0).as_nanos()
                        as u64);
                let mut verdicts = std::mem::take(&mut self.verdict_buf);
                verdicts.clear();
                self.process(engine, sample, t0, &mut verdicts)?;
                self.evict_idle(engine);
                self.emit(&mut verdicts, false)?;
                self.verdict_buf = verdicts;
            }
            Job::Batch(samples, t0) => {
                // Run-coalesced burst: accumulate the whole burst's
                // verdicts in the reusable buffer, emit once. Stage
                // split: the burst shares one submit time, so one
                // queue-wait record covers it; engine time spans the
                // whole run loop (per-burst, amortized like the queue
                // synchronization itself).
                let t_dq = Instant::now();
                self.metrics
                    .queue_wait
                    .record(t_dq.saturating_duration_since(t0).as_nanos()
                        as u64);
                record(
                    EventKind::Dequeue,
                    samples.len() as u64,
                    0,
                    self.widx as u32,
                );
                let mut all = std::mem::take(&mut self.verdict_buf);
                all.clear();
                self.burst(engine, &samples, RunT0::Uniform(t0), &mut all)?;
                self.metrics
                    .engine_time
                    .record(t_dq.elapsed().as_nanos() as u64);
                self.emit(&mut all, true)?;
                self.verdict_buf = all;
            }
            Job::Replay(strays) => {
                // Batched stray re-delivery: the same run-coalesced
                // core as Batch, but every stray carries its ORIGINAL
                // submit time (one queue-wait record per stray — their
                // waits differ). Strays unzip into the worker's
                // coalescing scratch so no per-burst Vec is allocated.
                let t_dq = Instant::now();
                record(
                    EventKind::Dequeue,
                    strays.len() as u64,
                    0,
                    self.widx as u32,
                );
                let mut samples = std::mem::take(&mut self.sample_buf);
                let mut t0s = std::mem::take(&mut self.t0_buf);
                samples.clear();
                t0s.clear();
                for (sample, t0) in strays {
                    self.metrics.queue_wait.record(
                        t_dq.saturating_duration_since(t0).as_nanos() as u64,
                    );
                    samples.push(sample);
                    t0s.push(t0);
                }
                let mut all = std::mem::take(&mut self.verdict_buf);
                all.clear();
                self.burst(engine, &samples, RunT0::Per(&t0s), &mut all)?;
                self.metrics
                    .engine_time
                    .record(t_dq.elapsed().as_nanos() as u64);
                self.emit(&mut all, true)?;
                self.verdict_buf = all;
                samples.clear();
                t0s.clear();
                self.sample_buf = samples;
                self.t0_buf = t0s;
            }
            Job::Seal { shards, reply } => {
                // The seal's backlog barrier spans BOTH queue planes:
                // drain the ring first so "the Seal answered" keeps
                // meaning "everything enqueued before it is processed
                // or stray-forwarded". Only data jobs can be on the
                // ring, so this cannot recurse into another Seal.
                while let Some(data) = slot.pop_ring() {
                    self.handle(engine, slot, data)?;
                }
                self.seal(engine, &shards, &reply)?;
            }
            Job::Expect { shards } => {
                self.pending.extend(shards);
            }
            Job::Unexpect { shards } => {
                for s in &shards {
                    self.pending.remove(s);
                }
                // Whatever outran the adopt-that-never-came belongs to
                // someone else now: hand it back for re-routing.
                let vs = self.virtual_shards;
                let (gone, keep): (Vec<_>, Vec<_>) =
                    std::mem::take(&mut self.stash)
                        .into_iter()
                        .partition(|(s, _)| {
                            shards.contains(&shard_of(s.stream_id, vs))
                        });
                self.stash = keep;
                for (sample, t0) in gone {
                    self.metrics.stray_reroutes.inc();
                    record(
                        EventKind::Stray,
                        sample.stream_id,
                        shard_of(sample.stream_id, vs),
                        self.widx as u32,
                    );
                    let _ = self.stray_tx.send((sample, t0));
                }
            }
            Job::Adopt { shards, records } => {
                self.adopt(engine, &shards, records)?;
            }
            Job::Retire => {
                // All shards were migrated off before retirement, so
                // the flush is a formality for a strictly-empty
                // engine. Do NOT exit yet: a submitter may still land
                // a last sample on either plane, which must be stray-
                // forwarded, not dropped — the loop ends when the
                // service explicitly closes this worker's queues.
                debug_assert!(self.owned.is_empty());
                let mut verdicts = engine.flush()?;
                self.emit(&mut verdicts, true)?;
            }
            Job::Flush => {
                let mut verdicts = engine.flush()?;
                self.emit(&mut verdicts, true)?;
            }
            // Crash simulation: abandon engine state without flushing.
            // The backlog already delivered to this worker (its ring)
            // is still processed first — identical to the single-queue
            // semantics where Abort queued strictly behind it — so
            // only un-flushed engine state dies with the worker.
            Job::Abort => {
                while let Some(data) = slot.pop_ring() {
                    self.handle(engine, slot, data)?;
                }
                return Ok(Flow::Exit);
            }
        }
        Ok(Flow::Continue)
    }

    /// One sample through the engine: ownership check (stash or
    /// forward when the shard is in motion), restore-on-resume before
    /// a stream's first sample, replay-window dedup, ingest, then
    /// periodic engine-agnostic checkpointing — identical on the
    /// single-sample, batch, and stash-replay paths.
    fn process(
        &mut self,
        engine: &mut dyn Engine,
        sample: Sample,
        t0: Instant,
        out: &mut Vec<EngineVerdict>,
    ) -> Result<()> {
        let (sid, seq) = (sample.stream_id, sample.seq);
        let shard = shard_of(sid, self.virtual_shards);
        if !self.owned.contains(&shard) {
            if self.pending.contains(&shard) {
                // State is on its way (Expect seen, Adopt not yet).
                self.stash.push((sample, t0));
            } else {
                // Routed under a stale table — hand it back for
                // re-routing. Never processed here, never lost.
                self.metrics.stray_reroutes.inc();
                record(EventKind::Stray, sid, shard, self.widx as u32);
                let _ = self.stray_tx.send((sample, t0));
            }
            return Ok(());
        }
        self.tick += 1;
        self.shard_metrics.shard(shard).samples.inc();
        self.last_seen.insert(sid, self.tick);
        if self.seen.insert(sid) && self.policy.restore_on_resume && seq > 0
        {
            // First sample of a mid-stream resume: adopt the newest
            // checkpoint. The upstream replays at-least-once from the
            // watermark (inclusively or after it); either way the
            // watermark filter below keeps processing exactly-once.
            if let Some(cp) = self.state_mgr.latest(sid) {
                engine.restore(sid, cp.snapshot)?;
                self.metrics.stream_restores.inc();
                record(EventKind::Restore, sid, shard, self.widx as u32);
                self.restored_at.insert(sid, cp.seq);
                self.last_seq.insert(sid, cp.seq);
            }
        }
        if let Some(&wm) = self.restored_at.get(&sid) {
            if seq <= wm {
                // Already folded into the restored snapshot: dropping
                // it (instead of re-ingesting) is what keeps the
                // detector state exactly-once under an inclusive
                // replay window.
                self.metrics.replay_skipped.inc();
                return Ok(());
            }
        }
        if self.last_seq.get(&sid).is_some_and(|&last| seq <= last) {
            // Watermark guard: a sample at or below the stream's last
            // ingested seq can only be a duplicate or a pathologically
            // late stray (a submitter stalled across an entire
            // migration). Ingesting it would corrupt the order-
            // dependent TEDA recurrence AND regress the seal
            // watermark; dropping it keeps every other verdict exact.
            self.metrics.stale_drops.inc();
            return Ok(());
        }
        self.inflight.insert((sid, seq), t0);
        self.last_seq.insert(sid, seq);
        out.extend(engine.ingest(&sample)?);
        if self.policy.every > 0 && (seq + 1) % self.policy.every == 0 {
            if let Some(snapshot) = engine.snapshot(sid) {
                self.state_mgr.publish(StateCheckpoint {
                    stream_id: sid,
                    seq,
                    snapshot,
                });
            }
        }
        Ok(())
    }

    /// Run-coalesced burst core, shared by `Job::Batch` and
    /// `Job::Replay`: split the burst into maximal runs of consecutive
    /// same-stream samples and push each through [`Worker::process_run`].
    /// Bursts arrive grouped by routed worker, so runs are long in
    /// steady state (`run_len` histogram).
    fn burst(
        &mut self,
        engine: &mut dyn Engine,
        samples: &[Sample],
        t0s: RunT0,
        out: &mut Vec<EngineVerdict>,
    ) -> Result<()> {
        let mut off = 0;
        for run in runs(samples) {
            let run_t0 = match t0s {
                RunT0::Uniform(t) => RunT0::Uniform(t),
                RunT0::Per(ts) => RunT0::Per(&ts[off..off + run.len()]),
            };
            off += run.len();
            self.process_run(engine, run, run_t0, out)?;
        }
        Ok(())
    }

    /// One run of same-stream samples through the engine. Byte-identical
    /// to calling [`Worker::process`] + [`Worker::evict_idle`] per
    /// sample — the ownership check, restore-on-resume, dedup
    /// watermarks, checkpoint cadence, and eviction clock all fire at
    /// the same per-sample points — but the per-stream map lookups
    /// happen once per run and the engine sees contiguous kept spans
    /// through [`Engine::process_batch`] instead of one `ingest` per
    /// sample.
    fn process_run(
        &mut self,
        engine: &mut dyn Engine,
        run: &[Sample],
        t0s: RunT0,
        out: &mut Vec<EngineVerdict>,
    ) -> Result<()> {
        let sid = run[0].stream_id;
        let shard = shard_of(sid, self.virtual_shards);
        self.metrics.run_len.record(run.len() as u64);
        if !self.owned.contains(&shard) {
            // Ownership changes only between jobs (Seal removes, Adopt
            // adds, both strictly in queue order), never mid-burst: one
            // check covers the whole run. Strays never tick the
            // eviction clock, exactly like the per-sample path.
            if self.pending.contains(&shard) {
                for (i, s) in run.iter().enumerate() {
                    self.stash.push((s.clone(), t0s.at(i)));
                }
            } else {
                for (i, s) in run.iter().enumerate() {
                    self.metrics.stray_reroutes.inc();
                    record(EventKind::Stray, sid, shard, self.widx as u32);
                    let _ = self.stray_tx.send((s.clone(), t0s.at(i)));
                }
            }
            return Ok(());
        }
        self.shard_metrics.shard(shard).samples.add(run.len() as u64);
        if self.seen.insert(sid)
            && self.policy.restore_on_resume
            && run[0].seq > 0
        {
            // First sample of a mid-stream resume (see
            // [`Worker::process`]): adopt the newest checkpoint before
            // anything in the run reaches the engine.
            if let Some(cp) = self.state_mgr.latest(sid) {
                engine.restore(sid, cp.snapshot)?;
                self.metrics.stream_restores.inc();
                record(EventKind::Restore, sid, shard, self.widx as u32);
                self.restored_at.insert(sid, cp.seq);
                self.last_seq.insert(sid, cp.seq);
            }
        }
        // Per-run hoists: the restore watermark is fixed for the run
        // (restores only happen above), the dedup watermark evolves in
        // a local, and the policy knobs become loop constants.
        let wm = self.restored_at.get(&sid).copied();
        let mut last = self.last_seq.get(&sid).copied();
        let every = self.policy.every;
        let after = self.policy.evict_after;
        // Start of the contiguous span of kept samples not yet fed to
        // the engine; dropped samples and checkpoint boundaries cut it.
        let mut span = 0usize;
        for (i, s) in run.iter().enumerate() {
            self.tick += 1;
            if after > 0 && self.tick % after == 0 {
                // The eviction clock ticks once per SAMPLE, exactly as
                // the per-sample path. Publish this stream's recency
                // before scanning so the scan never evicts the run it
                // is inside (the per-sample path orders it the same
                // way: `last_seen` before `evict_idle`).
                self.last_seen.insert(sid, self.tick);
                self.evict_scan(engine);
            }
            let seq = s.seq;
            if wm.is_some_and(|w| seq <= w) {
                // Inside the inclusive replay window (see
                // [`Worker::process`]): drop, and cut the span so the
                // engine never sees the duplicate.
                self.metrics.replay_skipped.inc();
                if span < i {
                    engine.process_batch(&run[span..i], out)?;
                }
                span = i + 1;
                continue;
            }
            if last.is_some_and(|l| seq <= l) {
                // Watermark guard, same contract as the per-sample
                // path: stale duplicates are dropped, counted.
                self.metrics.stale_drops.inc();
                if span < i {
                    engine.process_batch(&run[span..i], out)?;
                }
                span = i + 1;
                continue;
            }
            self.inflight.insert((sid, seq), t0s.at(i));
            last = Some(seq);
            if every > 0 && (seq + 1) % every == 0 {
                // Checkpoint cadence: the snapshot must capture the
                // engine exactly after this sample, so the span ends
                // here.
                engine.process_batch(&run[span..=i], out)?;
                span = i + 1;
                if let Some(snapshot) = engine.snapshot(sid) {
                    self.state_mgr.publish(StateCheckpoint {
                        stream_id: sid,
                        seq,
                        snapshot,
                    });
                }
            }
        }
        if span < run.len() {
            engine.process_batch(&run[span..], out)?;
        }
        self.last_seen.insert(sid, self.tick);
        if let Some(l) = last {
            self.last_seq.insert(sid, l);
        }
        Ok(())
    }

    /// Migration, old-worker side: snapshot every resident stream of
    /// the sealed shards at its exact watermark, publish the
    /// checkpoints (failover sees the same watermark), encode them as
    /// the wire bundle, evict the streams, and disown the shards.
    fn seal(
        &mut self,
        engine: &mut dyn Engine,
        shards: &[u32],
        reply: &Sender<SealBundle>,
    ) -> Result<()> {
        let sealed: HashSet<u32> = shards.iter().copied().collect();
        let vs = self.virtual_shards;
        let mut sids: Vec<u64> = self
            .last_seq
            .keys()
            .copied()
            .filter(|&sid| sealed.contains(&shard_of(sid, vs)))
            .collect();
        sids.sort_unstable();
        let mut records = Vec::with_capacity(sids.len());
        for sid in sids {
            let Some(snapshot) = engine.snapshot(sid) else { continue };
            let cp = StateCheckpoint {
                stream_id: sid,
                seq: self.last_seq[&sid],
                snapshot,
            };
            records.push(codec::encode(&cp));
            self.state_mgr.publish(cp);
            engine.evict(sid);
            self.seen.remove(&sid);
            self.restored_at.remove(&sid);
            self.last_seen.remove(&sid);
            self.last_seq.remove(&sid);
            // In-flight verdicts migrate inside the snapshot; the new
            // worker re-emits them (latency unknown there, reported as
            // 0 and kept out of the histogram).
            self.inflight.retain(|(s, _), _| *s != sid);
        }
        for shard in shards {
            self.owned.remove(shard);
        }
        record(
            EventKind::Seal,
            records.len() as u64,
            shards.len() as u32,
            self.widx as u32,
        );
        // Rebalancer gone mid-protocol (service torn down): nothing to
        // do — the checkpoints above are already published.
        let _ = reply.send(SealBundle { records });
        Ok(())
    }

    /// Migration, new-worker side: decode + restore every stream of the
    /// bundle, take ownership, then replay stashed samples in
    /// (stream, seq) order through the inclusive-watermark dedup.
    fn adopt(
        &mut self,
        engine: &mut dyn Engine,
        shards: &[u32],
        records: Vec<Vec<u8>>,
    ) -> Result<()> {
        record(
            EventKind::Adopt,
            records.len() as u64,
            shards.len() as u32,
            self.widx as u32,
        );
        for rec in records {
            let cp = codec::decode(&rec)?;
            let sid = cp.stream_id;
            engine.restore(sid, cp.snapshot)?;
            self.seen.insert(sid);
            self.restored_at.insert(sid, cp.seq);
            self.last_seq.insert(sid, cp.seq);
            self.last_seen.insert(sid, self.tick);
        }
        for &shard in shards {
            self.pending.remove(&shard);
            self.owned.insert(shard);
        }
        // Replay whatever outran its state. Stash order is arrival
        // order across two paths (direct post-swap submissions and
        // re-routed strays), so sort back into per-stream seq order;
        // the dedup drops anything the snapshots already cover.
        let vs = self.virtual_shards;
        let owned = &self.owned;
        let (ready, keep): (Vec<_>, Vec<_>) = std::mem::take(&mut self.stash)
            .into_iter()
            .partition(|(s, _)| owned.contains(&shard_of(s.stream_id, vs)));
        self.stash = keep;
        let mut ready = ready;
        ready.sort_by_key(|(s, _)| (s.stream_id, s.seq));
        let mut verdicts = Vec::new();
        for (sample, t0) in ready {
            self.process(engine, sample, t0, &mut verdicts)?;
        }
        self.evict_idle(engine);
        self.emit(&mut verdicts, true)?;
        Ok(())
    }

    /// Drop every stream idle for ≥ `evict_after` worker samples:
    /// engine state, in-memory checkpoint, durable checkpoints, and the
    /// worker's bookkeeping go together, so a re-appearing stream id
    /// starts fresh instead of resurrecting stale state. Scans once per
    /// `evict_after` ticks to keep the hot path O(1).
    fn evict_idle(&mut self, engine: &mut dyn Engine) {
        let after = self.policy.evict_after;
        if after == 0 || self.tick == 0 || self.tick % after != 0 {
            return;
        }
        self.evict_scan(engine);
    }

    /// The scan body behind [`Worker::evict_idle`], also called at the
    /// exact per-sample tick points inside [`Worker::process_run`] so
    /// the batched path's eviction clock is byte-identical to the
    /// per-sample path's.
    fn evict_scan(&mut self, engine: &mut dyn Engine) {
        let after = self.policy.evict_after;
        let idle: Vec<u64> = self
            .last_seen
            .iter()
            .filter(|(_, &at)| self.tick - at >= after)
            .map(|(&sid, _)| sid)
            .collect();
        for sid in idle {
            engine.evict(sid);
            self.state_mgr.evict(sid);
            record(EventKind::Evict, sid, 0, self.widx as u32);
            self.seen.remove(&sid);
            self.restored_at.remove(&sid);
            self.last_seen.remove(&sid);
            self.last_seq.remove(&sid);
            // The engine discarded the stream's in-flight verdicts;
            // their latency records would otherwise leak forever.
            self.inflight.retain(|(s, _), _| *s != sid);
            self.metrics.stream_evictions.inc();
        }
    }

    /// One burst send per engine call: metrics are batched too (counter
    /// adds are cheap but the channel lock is not). `timed` records the
    /// emit-stage duration (one clock-read pair per burst) — disabled
    /// on the single-sample hot path by the caller. Drains `verdicts`
    /// in place so callers can keep the buffer's capacity across bursts
    /// (the `Classified` burst itself must be owned — it crosses the
    /// results channel).
    fn emit(
        &mut self,
        verdicts: &mut Vec<EngineVerdict>,
        timed: bool,
    ) -> Result<()> {
        if verdicts.is_empty() {
            return Ok(());
        }
        let t_emit = timed.then(Instant::now);
        let mut burst = Vec::with_capacity(verdicts.len());
        let mut outliers = 0u64;
        for v in verdicts.drain(..) {
            // Verdicts without a submit record (re-emitted in-flight
            // work after a restore or migration) report 0 but are NOT
            // recorded into the histograms — fabricated 0 ns entries
            // would drag every post-failover quantile toward zero.
            let latency_ns = match self.inflight.remove(&(v.stream_id, v.seq))
            {
                Some(t) => {
                    let ns = t.elapsed().as_nanos() as u64;
                    self.metrics.latency.record(ns);
                    self.shard_metrics
                        .shard(shard_of(v.stream_id, self.virtual_shards))
                        .latency
                        .record(ns);
                    ns
                }
                None => 0,
            };
            if v.outlier {
                outliers += 1;
            }
            burst.push(Classified { verdict: v, latency_ns });
        }
        self.metrics.verdicts_out.add(burst.len() as u64);
        self.metrics.outliers.add(outliers);
        self.res_tx.send(burst).map_err(|_| {
            Error::Stream(format!(
                "worker {}: results channel closed",
                self.widx
            ))
        })?;
        if let Some(t) = t_emit {
            self.metrics.emit_time.record(t.elapsed().as_nanos() as u64);
        }
        Ok(())
    }
}

//! Cluster control plane: membership, heartbeats, node-level shard
//! ownership, and cross-process seal → adopt migration.
//!
//! Several `teda-fpga serve` processes — each one a full node core
//! ([`Service`]: workers, rings, engines, state manager) — serve one
//! logical shard map. The split of responsibilities:
//!
//! - **Node core** ([`Service`]): everything inside one process. Its
//!   node-level entry points (`expect_shards` / `seal_shards` /
//!   `adopt_shards` / `replay_strays` / `reroute_strays`) present the
//!   whole process as one [`Transport`]-shaped endpoint fanned out
//!   over the local workers.
//! - **Control plane** (this module): a peer roster (static from
//!   config, or grown at runtime via `Join`), a deterministic initial
//!   ownership table (every node of a static roster computes the same
//!   round-robin [`NodeTable`] at epoch 0, so no handshake is needed
//!   to agree), heartbeat liveness, epoch-numbered table broadcasts,
//!   node → node migration driven by the *same* [`migrate_over`]
//!   sequence the in-process rebalancer uses, and failover: when a
//!   peer dies, the lowest-id survivor adopts its shards from the
//!   shared checkpoint store.
//! - **Transport** ([`super::transport`]): the length-prefixed,
//!   CRC-framed TCP/UDS protocol. Sealed bundles cross as unmodified
//!   persist-codec records.
//!
//! Ordering across processes leans on one property: all migration
//! traffic for one move flows over ONE serialized connection (the
//! peer's [`RpcClient`]), so the far side processes Table before Seal,
//! and stray Replays before the Adopt — exactly the FIFO the
//! in-process control plane guarantees.
//!
//! Failover contract: automatic failover (`cluster.failover_ms > 0`)
//! requires every node to share `checkpoint.dir` on a common
//! filesystem and run with `checkpoint.restore = true`. The survivor
//! re-reads the store ([`StateManager::recover`]), takes ownership of
//! the dead node's shards with an empty Adopt, and resuming streams
//! restore at their checkpointed watermarks — samples at or below a
//! watermark are deduplicated, so re-feeding a window of recent
//! samples converges on bit-identical verdicts.
//!
//! Three runtime behaviours layer on top of that base:
//!
//! - **Dynamic join** (`cluster.join = ADDR`): a new node registers
//!   with any live member (`Join` → `JoinOk`). The sponsor installs
//!   the joiner, re-broadcasts the table at epoch+1 and gossips the
//!   join to the rest of the roster (each member relays a given
//!   joiner at most once, so the gossip terminates); the joiner
//!   learns the roster + table from `JoinOk` and pulls its uniform
//!   share of shards with [`ClusterNode::pull_share`] — the ordinary
//!   seal → adopt path, so in-flight work survives.
//! - **Load-driven rebalancing** (`cluster.rebalance_ms > 0`): every
//!   heartbeat carries the sender's windowed ingest rate, so each
//!   member knows every peer's load. A node sustaining more than
//!   `cluster.rebalance_threshold` × the cluster average sheds its
//!   hottest shards to the coldest live peer via
//!   [`ClusterNode::migrate_to_peer`] — at most once per
//!   `rebalance_ms` window, rebaselining the load window after each
//!   move (hysteresis against ping-pong).
//! - **Ingest buffering** (`cluster.ingest_buffer > 0`): a burst that
//!   cannot be forwarded right now (owner mid-failover, or no table
//!   yet mid-join) parks in a bounded local buffer and replays once
//!   the route heals; admission is all-or-nothing so an overflow is
//!   an error the caller can retry, never a silent drop.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::service::Service;
use super::shard_map::shard_of;
use super::transport::frame::{self, Msg};
use super::transport::net::{Listener, PeerAddr, RemoteLink, RpcClient};
use super::transport::{
    migrate_over, MigrationStats, StraySample, Transport,
};
use crate::config::ClusterConfig;
use crate::obs::{
    record, EventKind, ShardDelta, ShardWindow, NO_WORKER,
};
use crate::stream::Sample;
use crate::{Error, Result};

/// How long the accept loop naps when no connection is pending.
const ACCEPT_NAP: Duration = Duration::from_millis(5);

/// Node-level shard ownership: `owner[shard]` is the node id serving
/// that virtual shard. Epoch-numbered like the worker-level
/// [`super::ShardTable`]; higher epoch wins, equal epochs are
/// idempotent duplicates.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeTable {
    /// Monotonic version; bumps on every ownership change.
    pub epoch: u64,
    /// Shard → owning node id, indexed by virtual shard.
    pub owner: Vec<u64>,
}

impl NodeTable {
    /// The deterministic epoch-0 table: shards round-robin over the
    /// sorted member ids. Every node of a roster computes the same
    /// table, so a cluster boots agreed without any exchange.
    pub fn new_uniform(virtual_shards: u32, members: &[u64]) -> NodeTable {
        assert!(!members.is_empty(), "a cluster has at least one node");
        let mut ids = members.to_vec();
        ids.sort_unstable();
        ids.dedup();
        let owner = (0..virtual_shards)
            .map(|s| ids[s as usize % ids.len()])
            .collect();
        NodeTable { epoch: 0, owner }
    }

    /// Shards owned by `node`, ascending.
    pub fn shards_of(&self, node: u64) -> Vec<u32> {
        self.owner
            .iter()
            .enumerate()
            .filter(|(_, &o)| o == node)
            .map(|(s, _)| s as u32)
            .collect()
    }

    /// Owner of one shard (panics on out-of-range shard).
    pub fn owner_of(&self, shard: u32) -> u64 {
        self.owner[shard as usize]
    }

    /// Successor table: `shards` reassigned to `node`, epoch bumped.
    pub fn with_owner(&self, shards: &[u32], node: u64) -> NodeTable {
        let mut owner = self.owner.clone();
        for &s in shards {
            owner[s as usize] = node;
        }
        NodeTable { epoch: self.epoch + 1, owner }
    }

    /// Distinct member ids present in the table, ascending.
    pub fn members(&self) -> Vec<u64> {
        let mut ids = self.owner.clone();
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

struct PeerState {
    alive: bool,
    /// Stamped at member-install time (not first contact): a peer —
    /// static or just-admitted — gets a full failover window from the
    /// moment we learn of it before silence can declare it dead.
    last_seen: Instant,
    epoch: u64,
    /// The peer's windowed ingest rate (samples/s), as self-reported
    /// by its latest heartbeat. Feeds the cross-node rebalancer.
    load: u64,
}

struct Peer {
    id: u64,
    client: Arc<RpcClient>,
    state: Mutex<PeerState>,
}

/// Windowed view of this node's own ingest, shared between the
/// heartbeat sender (advertises `rate`) and the cross-node rebalancer
/// (ranks shards by the per-shard `deltas`).
struct BalanceState {
    window: ShardWindow,
    /// Per-shard activity of the last closed window.
    deltas: Vec<ShardDelta>,
    /// Wall seconds the last window spanned.
    dt: f64,
    /// Node-total ingest rate of the last window (samples/s).
    rate: f64,
    last_sample: Instant,
    /// Hysteresis anchor: no rebalance decision until a full quiet
    /// `rebalance_every` has passed since the previous move.
    last_move: Instant,
}

struct Shared {
    node_id: u64,
    svc: Arc<Service>,
    table: Mutex<NodeTable>,
    /// Member roster. Write-locked only by join/leave; every steady
    /// state path takes brief read locks (heartbeats, forwarding).
    peers: RwLock<BTreeMap<u64, Arc<Peer>>>,
    heartbeat_every: Duration,
    /// 0 = automatic failover off.
    failover_after: Duration,
    /// 0 = load-driven cross-node rebalancing off.
    rebalance_every: Duration,
    /// Donor gate: rebalance only above `threshold ×` cluster-average
    /// load (> 1.0, validated by config).
    rebalance_threshold: f64,
    balance: Mutex<BalanceState>,
    /// 0 = ingest park-and-replay buffering off.
    ingest_cap: usize,
    /// Samples admitted by [`ClusterHandle`] that could not be routed
    /// (owner mid-failover, table mid-join); drained every heartbeat.
    ingest_park: Mutex<VecDeque<Sample>>,
    /// Serializes park drains. Without it, two overlapping drains
    /// could deliver a newer slice of a stream before an older one
    /// finishes its (failed → repark) round-trip, and the watermark
    /// guard would then drop the older samples as stale — losing
    /// verdicts. Never held while `ingest_park` admission runs, so
    /// submitters don't block on a drain's network I/O.
    drain_lock: Mutex<()>,
    /// Cluster-autoscale recommendation (mirrors `node_scale_hint`).
    scale_hint: AtomicBool,
    /// Serializes node-level moves and failovers against each other.
    move_lock: Mutex<()>,
    stop: AtomicBool,
    bound: String,
    started: Instant,
}

impl Shared {
    fn peer(&self, id: u64) -> Result<Arc<Peer>> {
        self.peers.read().unwrap().get(&id).cloned().ok_or_else(
            || Error::Stream(format!("unknown cluster peer {id}")),
        )
    }

    fn peer_snapshot(&self) -> Vec<Arc<Peer>> {
        self.peers.read().unwrap().values().cloned().collect()
    }

    /// Install `id @ addr` into the roster. Returns `Ok(true)` when
    /// the member is newly installed (the caller relays the join
    /// exactly then, so gossip terminates), `Ok(false)` for an
    /// already-known member (liveness restamped). A known id
    /// re-joining from a *different* address replaces the entry — a
    /// restarted node is a new incarnation.
    fn add_peer(&self, id: u64, addr: &str, alive: bool) -> Result<bool> {
        if id == self.node_id {
            return Err(Error::Stream(format!(
                "node {id} cannot be its own peer"
            )));
        }
        let parsed = PeerAddr::parse(addr)?;
        let mut peers = self.peers.write().unwrap();
        if let Some(p) = peers.get(&id) {
            if p.client.addr().to_string() == parsed.to_string() {
                let mut st = p.state.lock().unwrap();
                st.last_seen = Instant::now();
                if alive {
                    st.alive = true;
                }
                return Ok(false);
            }
            // Same id, new address: fall through and replace.
        }
        peers.insert(
            id,
            Arc::new(Peer {
                id,
                client: Arc::new(RpcClient::new(parsed)),
                state: Mutex::new(PeerState {
                    alive,
                    last_seen: Instant::now(),
                    epoch: 0,
                    load: 0,
                }),
            }),
        );
        drop(peers);
        self.svc.metrics().member_joins.inc();
        record(EventKind::MemberJoin, id, 0, NO_WORKER);
        self.refresh_peers_alive();
        Ok(true)
    }

    /// Drop `id` from the roster (a clean `Leave`). Returns whether
    /// the member was known.
    fn remove_peer(&self, id: u64) -> bool {
        let removed = self.peers.write().unwrap().remove(&id);
        match removed {
            Some(p) => {
                p.client.disconnect();
                self.svc.metrics().member_leaves.inc();
                self.refresh_peers_alive();
                true
            }
            None => false,
        }
    }

    fn epoch(&self) -> u64 {
        self.table.lock().unwrap().epoch
    }

    /// Liveness bookkeeping for any message proving `id` is up.
    /// `load` is only known for heartbeat *requests* (they carry the
    /// sender's windowed ingest rate); other proofs leave it alone.
    fn note_alive(&self, id: u64, epoch: u64, load: Option<u64>) {
        let Ok(peer) = self.peer(id) else { return };
        let mut st = peer.state.lock().unwrap();
        if !st.alive {
            self.svc.metrics().peer_connects.inc();
            record(EventKind::PeerConnect, id, 0, NO_WORKER);
        }
        st.alive = true;
        st.last_seen = Instant::now();
        st.epoch = epoch;
        if let Some(load) = load {
            st.load = load;
        }
        drop(st);
        self.refresh_peers_alive();
    }

    fn note_dead(&self, id: u64) {
        if let Ok(peer) = self.peer(id) {
            peer.state.lock().unwrap().alive = false;
            peer.client.disconnect();
        }
        self.refresh_peers_alive();
    }

    fn refresh_peers_alive(&self) {
        let alive = self
            .peer_snapshot()
            .iter()
            .filter(|p| p.state.lock().unwrap().alive)
            .count();
        self.svc.metrics().peers_alive.set(alive as u64);
    }

    /// Adopt a (possibly remote) ownership table. Stale epochs are
    /// refused, the current epoch is an idempotent duplicate. The
    /// service's foreign-shard set tracks the table: shards owned
    /// elsewhere escalate their strays through the forwarder.
    fn apply_table(&self, epoch: u64, owner: Vec<u64>) -> Result<()> {
        let vs = self.svc.table().virtual_shards() as usize;
        if owner.len() != vs {
            return Err(Error::Stream(format!(
                "table for {} shards, this cluster serves {vs}",
                owner.len()
            )));
        }
        {
            let mut t = self.table.lock().unwrap();
            if epoch < t.epoch {
                return Err(Error::Stream(format!(
                    "stale table epoch {epoch} (current {})",
                    t.epoch
                )));
            }
            // An empty current table is the pre-bootstrap sentinel:
            // accept whatever installs first.
            if epoch == t.epoch && !t.owner.is_empty() {
                if t.owner == owner {
                    return Ok(());
                }
                return Err(Error::Stream(format!(
                    "conflicting table at epoch {epoch}"
                )));
            }
            *t = NodeTable { epoch, owner: owner.clone() };
        }
        let mut mine = Vec::new();
        let mut foreign = Vec::new();
        for (s, &o) in owner.iter().enumerate() {
            if o == self.node_id {
                mine.push(s as u32);
            } else {
                foreign.push(s as u32);
            }
        }
        self.svc.mark_foreign(&foreign, true);
        self.svc.mark_foreign(&mine, false);
        self.svc.metrics().cluster_epoch.set(epoch);
        Ok(())
    }

    /// Install a successor table locally, then push it to every peer.
    /// Push failures are tolerated: a lagging peer self-heals on the
    /// next heartbeat (its stale epoch triggers a re-push), and a dead
    /// one is on its way to failover.
    fn install_table(&self, next: NodeTable) -> Result<()> {
        let msg = Msg::Table {
            epoch: next.epoch,
            owner: next.owner.clone(),
        };
        self.apply_table(next.epoch, next.owner)?;
        for peer in self.peer_snapshot() {
            let _ = peer.client.rpc(&msg);
        }
        Ok(())
    }

    /// Escalate strays whose shards live on a peer ([`Service`] calls
    /// this through the forwarder hook). Delivered strays ride the
    /// peer's control plane (Replay), staying FIFO with any queued
    /// Adopt over there. Undeliverable strays come back to be parked.
    fn forward_strays(
        &self,
        strays: Vec<StraySample>,
    ) -> std::result::Result<usize, Vec<StraySample>> {
        let table = self.table.lock().unwrap().clone();
        let vs = table.owner.len() as u32;
        let mut per_owner: BTreeMap<u64, Vec<StraySample>> =
            BTreeMap::new();
        for stray in strays {
            let owner = table.owner_of(shard_of(stray.0.stream_id, vs));
            per_owner.entry(owner).or_default().push(stray);
        }
        let mut delivered = 0usize;
        let mut failed: Vec<StraySample> = Vec::new();
        for (owner, group) in per_owner {
            // A shard marked foreign but mapping to self is a transient
            // race with a table install: park, the next drain re-reads.
            let peer = match self.peer(owner) {
                Ok(p) if owner != self.node_id => p,
                _ => {
                    failed.extend(group);
                    continue;
                }
            };
            let samples: Vec<Sample> =
                group.iter().map(|(s, _)| s.clone()).collect();
            let n = samples.len();
            match peer.client.rpc(&Msg::Replay { samples }) {
                Ok(Msg::Ok) => delivered += n,
                _ => failed.extend(group),
            }
        }
        if failed.is_empty() {
            Ok(delivered)
        } else {
            Err(failed)
        }
    }

    /// One request → one reply. Control messages map straight onto the
    /// node core's protocol entry points.
    fn handle_msg(&self, msg: Msg) -> Msg {
        let m = self.svc.metrics();
        match msg {
            Msg::Hello { node_id, epoch } => {
                self.note_alive(node_id, epoch, None);
                Msg::HelloOk {
                    node_id: self.node_id,
                    epoch: self.epoch(),
                }
            }
            Msg::Heartbeat { node_id, epoch, load } => {
                m.heartbeats_rx.inc();
                self.note_alive(node_id, epoch, Some(load));
                record(EventKind::Heartbeat, node_id, 0, NO_WORKER);
                Msg::HelloOk {
                    node_id: self.node_id,
                    epoch: self.epoch(),
                }
            }
            Msg::Join { node_id, addr } => {
                match self.admit(node_id, addr) {
                    Ok(reply) => reply,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Leave { node_id } => {
                let owned = self
                    .table
                    .lock()
                    .unwrap()
                    .shards_of(node_id)
                    .len();
                if owned > 0 {
                    Msg::Denied {
                        reason: format!(
                            "node {node_id} still owns {owned} shards; \
                             migrate them away first"
                        ),
                    }
                } else if self.remove_peer(node_id) {
                    Msg::Ok
                } else {
                    Msg::Denied {
                        reason: format!("unknown cluster peer {node_id}"),
                    }
                }
            }
            Msg::Expect { shards } => {
                match self.svc.expect_shards(&shards) {
                    Ok(()) => Msg::Ok,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Seal { shards } => {
                match self.svc.seal_shards(&shards) {
                    Ok(records) => {
                        if !shards.is_empty() {
                            self.svc.mark_foreign(&shards, true);
                            let bytes: u64 = records
                                .iter()
                                .map(|r| r.len() as u64)
                                .sum();
                            m.bundle_bytes_tx.add(bytes);
                            record(
                                EventKind::BundleShip,
                                bytes,
                                shards.len() as u32,
                                NO_WORKER,
                            );
                        }
                        Msg::Bundle { records }
                    }
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Adopt { shards, records } => {
                let bytes: u64 =
                    records.iter().map(|r| r.len() as u64).sum();
                self.svc.mark_foreign(&shards, false);
                match self.svc.adopt_shards(&shards, records) {
                    Ok(()) => {
                        m.bundle_bytes_rx.add(bytes);
                        record(
                            EventKind::BundleShip,
                            bytes,
                            shards.len() as u32,
                            NO_WORKER,
                        );
                        Msg::Ok
                    }
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Replay { samples } => {
                match self.svc.replay_strays(samples) {
                    Ok(_) => Msg::Ok,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Samples { samples } => {
                match self.svc.submit_batch(samples) {
                    Ok(()) => Msg::Ok,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Table { epoch, owner } => {
                match self.apply_table(epoch, owner) {
                    Ok(()) => Msg::Ok,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Settle => match self.svc.reroute_strays() {
                Ok(_) => Msg::Ok,
                Err(e) => Msg::Denied { reason: e.to_string() },
            },
            Msg::Status => Msg::StatusText { text: self.status() },
            // Replies arriving as requests: protocol violation.
            other => Msg::Denied {
                reason: format!("unexpected {} request", other.label()),
            },
        }
    }

    /// Sponsor a joining node: install it into the roster, force a
    /// table re-broadcast at epoch+1 (unchanged ownership — the bump
    /// makes every member, joiner included, converge on a fresh
    /// epoch), gossip the join to the rest of the roster, and reply
    /// with the table plus the full member list so the joiner can
    /// dial everyone. Only a *newly* installed member is relayed, so
    /// the gossip visits each member once and terminates.
    fn admit(&self, id: u64, addr: String) -> Result<Msg> {
        if self.table.lock().unwrap().owner.is_empty() {
            return Err(Error::Stream(
                "not bootstrapped yet (still joining): cannot sponsor"
                    .into(),
            ));
        }
        let newly = self.add_peer(id, &addr, true)?;
        if newly {
            let next = self
                .table
                .lock()
                .unwrap()
                .with_owner(&[], self.node_id);
            // Best-effort: a member that misses the broadcast
            // self-heals on the next heartbeat's epoch re-push.
            let _ = self.install_table(next);
            let relay = Msg::Join { node_id: id, addr: addr.clone() };
            for p in self.peer_snapshot() {
                if p.id != id {
                    let _ = p.client.rpc(&relay);
                }
            }
        }
        let (epoch, owner) = {
            let t = self.table.lock().unwrap();
            (t.epoch, t.owner.clone())
        };
        let mut peers = vec![(self.node_id, self.bound.clone())];
        for p in self.peer_snapshot() {
            if p.id != id {
                peers.push((p.id, p.client.addr().to_string()));
            }
        }
        Ok(Msg::JoinOk { epoch, owner, peers })
    }

    fn status(&self) -> String {
        let table = self.table.lock().unwrap();
        let owned = table.shards_of(self.node_id).len();
        let m = self.svc.metrics();
        let mut out = format!(
            "node {} @ {}\nepoch {}\nshards {}/{} owned\n\
             workers {}\nsamples_in {}\nuptime {:.1}s\n",
            self.node_id,
            self.bound,
            table.epoch,
            owned,
            table.owner.len(),
            self.svc.workers(),
            m.samples_in.get(),
            self.started.elapsed().as_secs_f64(),
        );
        for peer in self.peer_snapshot() {
            let st = peer.state.lock().unwrap();
            out.push_str(&format!(
                "peer {} @ {} {} (epoch {}, owns {}, load {}/s)\n",
                peer.id,
                peer.client.addr(),
                if st.alive { "alive" } else { "unseen/dead" },
                st.epoch,
                table.shards_of(peer.id).len(),
                st.load,
            ));
        }
        let parked = self.ingest_park.lock().unwrap().len();
        if parked > 0 {
            out.push_str(&format!("ingest parked {parked}\n"));
        }
        if self.scale_hint.load(Ordering::Relaxed) {
            out.push_str(
                "scale hint: add a node (sustained pressure at max \
                 workers)\n",
            );
        }
        out
    }

    /// Am I the designated survivor for `dead`? Exactly one node may
    /// run a failover: the lowest-id member still alive. A lower-id
    /// peer we have *marked* dead gets one direct probe before we
    /// claim leadership — a one-sided link loss must not elect two
    /// leaders (and if both still do race, the epoch guard in
    /// [`Shared::failover`] settles it).
    fn failover_leader(&self, dead: u64) -> bool {
        for p in self.peer_snapshot() {
            if p.id == dead || p.id > self.node_id {
                continue;
            }
            if p.state.lock().unwrap().alive {
                return false;
            }
            let req = Msg::Hello {
                node_id: self.node_id,
                epoch: self.epoch(),
            };
            if let Ok(Msg::HelloOk { epoch, .. }) = p.client.rpc(&req) {
                self.note_alive(p.id, epoch, None);
                return false;
            }
        }
        true
    }

    /// Adopt every shard `dead` owned, recovering stream state from
    /// the shared checkpoint store. Returns how many shards moved —
    /// 0 when this node lost the claim race to another leader.
    fn failover(&self, dead: u64) -> Result<usize> {
        let _guard = self.move_lock.lock().unwrap();
        let (observed, shards, next) = {
            let t = self.table.lock().unwrap();
            let shards = t.shards_of(dead);
            let next = t.with_owner(&shards, self.node_id);
            (t.epoch, shards, next)
        };
        if shards.is_empty() {
            return Ok(0);
        }
        // Pull the dead node's published watermarks out of the shared
        // durable store; resuming streams restore from them. Without a
        // durable store this degrades to ownership-only adoption.
        let _ = self.svc.state_manager().recover();
        self.svc.expect_shards(&shards)?;
        // Compare-and-refuse: the claim only lands on the epoch it
        // was computed against. If a racing leader moved the table
        // while we recovered, `apply_table` refuses it (stale epoch,
        // or an equal-epoch conflict — two leaders name different
        // owners) and this node backs off idempotently.
        let install = if self.table.lock().unwrap().epoch != observed {
            Err(Error::Stream(format!(
                "table moved past epoch {observed} during recovery"
            )))
        } else {
            self.install_table(next)
        };
        if install.is_err() {
            // The adopt is not coming: cancel the workers' stashes so
            // outrun samples re-route to the winner instead of waiting
            // forever. The dead-mark stands either way.
            let _ = self.svc.unexpect_shards(&shards);
            self.svc.metrics().failover_races.inc();
            self.note_dead(dead);
            return Ok(0);
        }
        self.svc.adopt_shards(&shards, Vec::new())?;
        self.note_dead(dead);
        self.svc.metrics().failovers.inc();
        record(
            EventKind::Failover,
            dead,
            shards.len() as u32,
            NO_WORKER,
        );
        Ok(shards.len())
    }

    /// One heartbeat round over every peer. Successes refresh
    /// liveness (and re-push the table to lagging peers); a silence
    /// longer than the failover window declares the peer dead and —
    /// if automatic failover is on and this node is the designated
    /// survivor — adopts its shards.
    fn heartbeat_round(&self) {
        let m = self.svc.metrics();
        let load = self.my_load();
        for peer in self.peer_snapshot() {
            if self.stop.load(Ordering::Acquire) {
                return;
            }
            let req = Msg::Heartbeat {
                node_id: self.node_id,
                epoch: self.epoch(),
                load,
            };
            match peer.client.rpc(&req) {
                Ok(Msg::HelloOk { epoch, .. }) => {
                    m.heartbeats_tx.inc();
                    self.note_alive(peer.id, epoch, None);
                    record(EventKind::Heartbeat, peer.id, 0, NO_WORKER);
                    if epoch < self.epoch() {
                        // Lagging peer (missed a broadcast): re-push.
                        let t = self.table.lock().unwrap().clone();
                        let _ = peer.client.rpc(&Msg::Table {
                            epoch: t.epoch,
                            owner: t.owner,
                        });
                    }
                }
                _ => {
                    let (was_alive, basis) = {
                        let st = peer.state.lock().unwrap();
                        (st.alive, st.last_seen)
                    };
                    let dead_after = if self.failover_after.is_zero() {
                        // No auto failover: still mark dead after a few
                        // missed rounds so status/metrics tell the truth.
                        self.heartbeat_every * 3
                    } else {
                        self.failover_after
                    };
                    if basis.elapsed() < dead_after {
                        continue;
                    }
                    if was_alive {
                        self.note_dead(peer.id);
                    }
                    if !self.failover_after.is_zero()
                        && self.failover_leader(peer.id)
                        && !self
                            .table
                            .lock()
                            .unwrap()
                            .shards_of(peer.id)
                            .is_empty()
                    {
                        let _ = self.failover(peer.id);
                    }
                }
            }
        }
    }

    /// Close the current load window: per-shard deltas + the node
    /// rate it advertises in heartbeats. Runs once per heartbeat
    /// round, so "load" always means "the last heartbeat interval".
    fn sample_load(&self) {
        let sm = self.svc.shard_metrics();
        let mut b = self.balance.lock().unwrap();
        let dt = b.last_sample.elapsed().as_secs_f64();
        if dt <= 0.0 {
            return;
        }
        let deltas = b.window.delta(&sm);
        let total: u64 = deltas.iter().map(|d| d.samples).sum();
        b.rate = total as f64 / dt;
        b.deltas = deltas;
        b.dt = dt;
        b.last_sample = Instant::now();
    }

    /// This node's windowed ingest rate (samples/s, last window).
    fn my_load(&self) -> u64 {
        self.balance.lock().unwrap().rate.round() as u64
    }

    /// Load-driven cross-node rebalancing: if this node sustains more
    /// than `rebalance_threshold ×` the cluster-average ingest rate,
    /// shed its hottest shards to the coldest live peer. Hysteresis
    /// against ping-pong: at most one decision per `rebalance_every`
    /// window, the load window is rebaselined after every move (the
    /// post-move interval is never polluted by pre-move attribution —
    /// same discipline as the intra-node `maybe_rebalance`), the
    /// donor only sheds down to the average, and never below one
    /// owned shard. Returns how many shards moved.
    fn maybe_rebalance_cluster(&self) -> Result<usize> {
        if self.rebalance_every.is_zero() {
            return Ok(0);
        }
        let (my_rate, deltas, dt) = {
            let b = self.balance.lock().unwrap();
            if b.last_move.elapsed() < self.rebalance_every {
                return Ok(0);
            }
            (b.rate, b.deltas.clone(), b.dt)
        };
        if dt <= 0.0 {
            return Ok(0);
        }
        let peers: Vec<(u64, f64)> = self
            .peer_snapshot()
            .iter()
            .filter_map(|p| {
                let st = p.state.lock().unwrap();
                st.alive.then_some((p.id, st.load as f64))
            })
            .collect();
        if peers.is_empty() {
            return Ok(0);
        }
        let avg = (my_rate
            + peers.iter().map(|(_, l)| l).sum::<f64>())
            / (peers.len() + 1) as f64;
        if avg <= 0.0 || my_rate <= self.rebalance_threshold * avg {
            return Ok(0);
        }
        let (coldest, cold_load) = peers
            .iter()
            .copied()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap();
        if cold_load >= avg {
            // Everyone is hot: shuffling shards cannot help.
            return Ok(0);
        }
        let mine = self.table.lock().unwrap().shards_of(self.node_id);
        if mine.len() <= 1 {
            return Ok(0);
        }
        // Hottest-first candidates from the windowed per-shard view:
        // by rate, then by windowed p99 (of two equally busy shards,
        // shed the one hurting tail latency more).
        let mut cands: Vec<(u32, f64, u64)> = deltas
            .iter()
            .filter(|d| mine.contains(&d.shard))
            .map(|d| (d.shard, d.samples as f64 / dt, d.p99_ns))
            .collect();
        cands.sort_by(|a, b| {
            b.1.total_cmp(&a.1)
                .then(b.2.cmp(&a.2))
                .then(a.0.cmp(&b.0))
        });
        let mut donor = my_rate;
        let mut recip = cold_load;
        let mut moves: Vec<u32> = Vec::new();
        for (shard, rate, _) in cands {
            if rate <= 0.0 || donor <= avg {
                break;
            }
            if moves.len() + 1 >= mine.len() {
                break;
            }
            if donor - rate < recip + rate {
                // This shard alone would flip the imbalance; a cooler
                // one further down may still fit.
                continue;
            }
            donor -= rate;
            recip += rate;
            moves.push(shard);
        }
        if moves.is_empty() {
            return Ok(0);
        }
        self.migrate_to_peer(coldest, &moves)?;
        self.svc.metrics().node_rebalances.inc();
        record(
            EventKind::NodeRebalance,
            coldest,
            moves.len() as u32,
            NO_WORKER,
        );
        let sm = self.svc.shard_metrics();
        let mut b = self.balance.lock().unwrap();
        b.window.rebaseline(&sm);
        b.deltas.clear();
        b.rate = 0.0;
        b.last_sample = Instant::now();
        b.last_move = Instant::now();
        Ok(moves.len())
    }

    /// Move `shards` from this node to `peer`: the exact
    /// Expect → install → Seal → drain → Adopt sequence of the
    /// in-process rebalancer, with the destination endpoint behind the
    /// framed transport. Verdicts stay bit-identical to an unmigrated
    /// run — strays drained up to the barrier cross as Replay frames
    /// on the same serialized connection as the Adopt.
    fn migrate_to_peer(
        &self,
        peer: u64,
        shards: &[u32],
    ) -> Result<MigrationStats> {
        let _guard = self.move_lock.lock().unwrap();
        let (next, not_mine) = {
            let t = self.table.lock().unwrap();
            let not_mine: Vec<u32> = shards
                .iter()
                .copied()
                .filter(|&s| {
                    (s as usize) >= t.owner.len()
                        || t.owner_of(s) != self.node_id
                })
                .collect();
            (t.with_owner(shards, peer), not_mine)
        };
        if !not_mine.is_empty() {
            return Err(Error::Stream(format!(
                "cannot migrate shards {not_mine:?}: not owned by node {}",
                self.node_id
            )));
        }
        let t0 = Instant::now();
        let remote = RemoteLink::new(self.peer(peer)?.client.clone())
            .with_metrics(self.svc.metrics());
        let local = NodeLocal { svc: &self.svc };
        let stats = migrate_over(
            &local,
            &remote,
            shards,
            &mut || self.install_table(next.clone()),
            &mut || self.svc.reroute_strays().map(|_| ()),
        )?;
        let m = self.svc.metrics();
        m.migrations.inc();
        m.shards_moved.add(shards.len() as u64);
        m.streams_migrated.add(stats.streams);
        m.migration_time.record(t0.elapsed().as_nanos() as u64);
        record(
            EventKind::BundleShip,
            stats.bytes,
            shards.len() as u32,
            NO_WORKER,
        );
        Ok(stats)
    }

    /// Pull `shards` from `peer` onto this node (the mirror move:
    /// remote seal, local adopt). The drain step is a Settle frame —
    /// the remote re-routes its strays, which arrive here as Replay
    /// frames *before* this side's local Adopt is enqueued.
    fn pull_from_peer(
        &self,
        peer: u64,
        shards: &[u32],
    ) -> Result<MigrationStats> {
        let _guard = self.move_lock.lock().unwrap();
        let (next, not_theirs) = {
            let t = self.table.lock().unwrap();
            let not_theirs: Vec<u32> = shards
                .iter()
                .copied()
                .filter(|&s| {
                    (s as usize) >= t.owner.len()
                        || t.owner_of(s) != peer
                })
                .collect();
            (t.with_owner(shards, self.node_id), not_theirs)
        };
        if !not_theirs.is_empty() {
            return Err(Error::Stream(format!(
                "cannot pull shards {not_theirs:?}: not owned by peer \
                 {peer}"
            )));
        }
        let t0 = Instant::now();
        let client = self.peer(peer)?.client.clone();
        let remote = RemoteLink::new(client.clone())
            .with_metrics(self.svc.metrics());
        let local = NodeLocal { svc: &self.svc };
        let stats = migrate_over(
            &remote,
            &local,
            shards,
            &mut || self.install_table(next.clone()),
            &mut || match client.rpc(&Msg::Settle)? {
                Msg::Ok => Ok(()),
                Msg::Denied { reason } => Err(Error::Stream(format!(
                    "peer {peer} denied settle: {reason}"
                ))),
                other => Err(Error::Stream(format!(
                    "peer {peer}: unexpected {} reply to settle",
                    other.label()
                ))),
            },
        )?;
        let m = self.svc.metrics();
        m.migrations.inc();
        m.shards_moved.add(shards.len() as u64);
        m.streams_migrated.add(stats.streams);
        m.migration_time.record(t0.elapsed().as_nanos() as u64);
        Ok(stats)
    }

    /// Split a burst by node ownership under `table`.
    fn partition(
        table: &NodeTable,
        node_id: u64,
        samples: Vec<Sample>,
    ) -> (Vec<Sample>, BTreeMap<u64, Vec<Sample>>) {
        let vs = table.owner.len() as u32;
        let mut local: Vec<Sample> = Vec::new();
        let mut remote: BTreeMap<u64, Vec<Sample>> = BTreeMap::new();
        for s in samples {
            let owner = table.owner_of(shard_of(s.stream_id, vs));
            if owner == node_id {
                local.push(s);
            } else {
                remote.entry(owner).or_default().push(s);
            }
        }
        (local, remote)
    }

    /// Forward per-owner groups to their peers. Never errors:
    /// undeliverable samples come back (with the first failure's
    /// reason) and the caller decides between parking and reporting.
    fn forward_remote(
        &self,
        remote: BTreeMap<u64, Vec<Sample>>,
    ) -> (Vec<Sample>, Option<String>) {
        let mut failed: Vec<Sample> = Vec::new();
        let mut why: Option<String> = None;
        for (owner, group) in remote {
            let n = group.len() as u64;
            let msg = Msg::Samples { samples: group };
            let reply = match self.peer(owner) {
                Ok(peer) => peer.client.rpc(&msg),
                Err(e) => Err(e),
            };
            let reason = match reply {
                Ok(Msg::Ok) => {
                    self.svc.metrics().samples_forwarded.add(n);
                    continue;
                }
                Ok(Msg::Denied { reason }) => format!(
                    "peer {owner} refused {n} samples: {reason}"
                ),
                Ok(other) => format!(
                    "peer {owner}: unexpected {} reply to samples",
                    other.label()
                ),
                Err(e) => format!("peer {owner}: {e}"),
            };
            why.get_or_insert(reason);
            if let Msg::Samples { samples } = msg {
                failed.extend(samples);
            }
        }
        (failed, why)
    }

    /// Admit samples into the park buffer, all-or-nothing: a burst
    /// that does not fit leaves the buffer untouched and errors, so
    /// the caller's retry never half-delivers (duplicated retries are
    /// absorbed downstream by the per-stream watermark dedup).
    fn park_ingest(&self, samples: Vec<Sample>) -> Result<()> {
        let n = samples.len();
        let depth = {
            let mut q = self.ingest_park.lock().unwrap();
            if q.len() + n > self.ingest_cap {
                drop(q);
                self.svc.metrics().ingest_park_full.add(n as u64);
                return Err(Error::Stream(format!(
                    "ingest buffer full: {n} samples not absorbed \
                     (cap {})",
                    self.ingest_cap
                )));
            }
            q.extend(samples);
            q.len() as u64
        };
        let m = self.svc.metrics();
        m.ingest_parked.add(n as u64);
        m.ingest_park_depth.set(depth);
        record(EventKind::IngestPark, n as u64, depth as u32, NO_WORKER);
        Ok(())
    }

    /// Put already-admitted samples back after a failed drain. No cap
    /// check: they were inside the bound when admitted, and dropping
    /// them here would lose verdicts (admission is the only gate).
    /// Prepended, not appended — anything parked while the drain was
    /// out doing network I/O is *newer*, and per-stream replay order
    /// must survive the round-trip.
    fn repark_ingest(&self, samples: Vec<Sample>) {
        let mut q = self.ingest_park.lock().unwrap();
        for s in samples.into_iter().rev() {
            q.push_front(s);
        }
        let depth = q.len() as u64;
        drop(q);
        self.svc.metrics().ingest_park_depth.set(depth);
    }

    /// Replay the park buffer through the current table. Runs every
    /// heartbeat and at the front of every [`ClusterHandle`] submit
    /// (parked samples stay ahead of new ones); whatever is still
    /// undeliverable re-parks.
    fn drain_ingest_park(&self) {
        let _serial = self.drain_lock.lock().unwrap();
        let pending: Vec<Sample> = {
            let mut q = self.ingest_park.lock().unwrap();
            if q.is_empty() {
                return;
            }
            q.drain(..).collect()
        };
        self.svc.metrics().ingest_park_depth.set(0);
        let table = self.table.lock().unwrap().clone();
        if table.owner.is_empty() {
            self.repark_ingest(pending);
            return;
        }
        let (local, remote) =
            Self::partition(&table, self.node_id, pending);
        let (mut still, _) = self.forward_remote(remote);
        if !local.is_empty() {
            // Cold path: clone so a refused local enqueue re-parks
            // instead of losing the burst.
            let backup = local.clone();
            if self.svc.submit_batch(local).is_err() {
                still.extend(backup);
            }
        }
        if !still.is_empty() {
            self.repark_ingest(still);
        }
    }

    /// Cluster-aware burst submit (the [`ClusterHandle`] entry
    /// point): locally-owned samples take the lock-free local path,
    /// the rest go to their owners in one Samples frame per peer.
    /// With buffering on (`ingest_cap > 0`), undeliverable remote
    /// groups — and, mid-join, the whole burst — park locally instead
    /// of erroring; with it off this errors exactly like before.
    fn cluster_submit(&self, samples: Vec<Sample>) -> Result<()> {
        self.drain_ingest_park();
        let table = self.table.lock().unwrap().clone();
        if table.owner.is_empty() {
            // Mid-join: no table yet. Buffer the burst if we can.
            if self.ingest_cap > 0 {
                return self.park_ingest(samples);
            }
            return Err(Error::Stream(
                "no ownership table installed yet".into(),
            ));
        }
        let (local, remote) =
            Self::partition(&table, self.node_id, samples);
        let (failed, why) = self.forward_remote(remote);
        if !failed.is_empty() {
            if self.ingest_cap > 0 {
                self.park_ingest(failed)?;
            } else {
                return Err(Error::Stream(why.unwrap_or_else(|| {
                    "sample forwarding failed".into()
                })));
            }
        }
        if local.is_empty() {
            Ok(())
        } else {
            self.svc.submit_batch(local)
        }
    }

    /// Record the cluster-autoscale recommendation (the serve loop's
    /// pressure trigger calls this when local scaling is exhausted).
    fn set_scale_hint(&self, want: bool) {
        self.scale_hint.store(want, Ordering::Relaxed);
        self.svc.metrics().node_scale_hint.set(want as u64);
    }
}

/// A running cluster node: the transport listener + heartbeat loop
/// wrapped around a node core. Create with [`ClusterNode::start`],
/// stop with [`ClusterNode::shutdown`] (the [`Service`] itself is
/// finished separately by its owner).
pub struct ClusterNode {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    heartbeat: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ClusterNode {
    /// Bind the transport, install the deterministic epoch-0 table,
    /// hook the service's stray forwarder, and start the accept +
    /// heartbeat threads. `cfg.listen` must be set.
    pub fn start(
        svc: Arc<Service>,
        cfg: &ClusterConfig,
    ) -> Result<ClusterNode> {
        let listen = cfg.listen.as_deref().ok_or_else(|| {
            Error::Config("cluster.listen is required".into())
        })?;
        let listener = Listener::bind(&PeerAddr::parse(listen)?)?;
        let bound = listener.bound_addr();

        let mut peers = BTreeMap::new();
        let mut members = vec![cfg.node_id];
        for (id, addr) in cfg.parse_peers()? {
            members.push(id);
            peers.insert(
                id,
                Arc::new(Peer {
                    id,
                    client: Arc::new(RpcClient::new(PeerAddr::parse(
                        &addr,
                    )?)),
                    state: Mutex::new(PeerState {
                        alive: false,
                        // Member-install stamp: the full failover
                        // window starts now, not at process start.
                        last_seen: Instant::now(),
                        epoch: 0,
                        load: 0,
                    }),
                }),
            );
        }
        let virtual_shards = svc.table().virtual_shards();
        let shard_metrics = svc.shard_metrics();
        let shared = Arc::new(Shared {
            node_id: cfg.node_id,
            svc,
            table: Mutex::new(NodeTable { epoch: 0, owner: Vec::new() }),
            peers: RwLock::new(peers),
            heartbeat_every: Duration::from_millis(cfg.heartbeat_ms),
            failover_after: Duration::from_millis(cfg.failover_ms),
            rebalance_every: Duration::from_millis(cfg.rebalance_ms),
            rebalance_threshold: cfg.rebalance_threshold,
            balance: Mutex::new(BalanceState {
                window: {
                    let mut w =
                        ShardWindow::new(virtual_shards as usize);
                    w.rebaseline(&shard_metrics);
                    w
                },
                deltas: Vec::new(),
                dt: 0.0,
                rate: 0.0,
                last_sample: Instant::now(),
                last_move: Instant::now(),
            }),
            ingest_cap: cfg.ingest_buffer as usize,
            ingest_park: Mutex::new(VecDeque::new()),
            drain_lock: Mutex::new(()),
            scale_hint: AtomicBool::new(false),
            move_lock: Mutex::new(()),
            stop: AtomicBool::new(false),
            bound,
            started: Instant::now(),
        });
        if cfg.join.is_none() {
            // Epoch 0 through the same path every later table takes
            // (also seeds the foreign-shard set and the cluster_epoch
            // gauge). A joining node skips this: the empty table stays
            // the pre-bootstrap sentinel until JoinOk installs the
            // sponsor's table.
            let table =
                NodeTable::new_uniform(virtual_shards, &members);
            shared.apply_table(0, table.owner)?;
        }

        // Stray escalation: a Weak hook, so Service ⇄ cluster never
        // form an Arc cycle and the service stays individually owned.
        let weak: Weak<Shared> = Arc::downgrade(&shared);
        shared.svc.set_stray_forwarder(Some(Arc::new(
            move |strays: Vec<StraySample>| match weak.upgrade() {
                Some(sh) => sh.forward_strays(strays),
                None => Err(strays),
            },
        )));

        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name(format!("teda-cluster-accept-{}", shared.node_id))
                .spawn(move || {
                    while !shared.stop.load(Ordering::Acquire) {
                        match listener.try_accept() {
                            Ok(Some(mut conn)) => {
                                let sh = shared.clone();
                                let h = std::thread::Builder::new()
                                    .name("teda-cluster-conn".into())
                                    .spawn(move || {
                                        while let Ok(Some(msg)) =
                                            frame::read_msg_cancellable(
                                                &mut conn, &sh.stop,
                                            )
                                            .map_err(|_| {
                                                sh.svc
                                                    .metrics()
                                                    .frame_errors
                                                    .inc();
                                            })
                                        {
                                            let reply =
                                                sh.handle_msg(msg);
                                            if frame::write_msg(
                                                &mut conn, &reply,
                                            )
                                            .is_err()
                                            {
                                                break;
                                            }
                                        }
                                    })
                                    .expect("spawn conn handler");
                                conns.lock().unwrap().push(h);
                            }
                            Ok(None) => std::thread::sleep(ACCEPT_NAP),
                            Err(_) => {
                                shared.svc.metrics().frame_errors.inc()
                            }
                        }
                    }
                })
                .map_err(|e| Error::io("spawn cluster accept", e))?
        };
        // Unconditional (even with an empty static roster): members
        // may join later, and the loop also drains the ingest park
        // and drives the cross-node rebalancer.
        let heartbeat = {
            let sh = shared.clone();
            Some(
                std::thread::Builder::new()
                    .name(format!(
                        "teda-cluster-heartbeat-{}",
                        sh.node_id
                    ))
                    .spawn(move || {
                        while !sh.stop.load(Ordering::Acquire) {
                            sh.sample_load();
                            sh.heartbeat_round();
                            sh.drain_ingest_park();
                            let _ = sh.maybe_rebalance_cluster();
                            // Nap in short slices: prompt shutdown.
                            let mut left = sh.heartbeat_every;
                            while !left.is_zero()
                                && !sh.stop.load(Ordering::Acquire)
                            {
                                let nap = left.min(ACCEPT_NAP * 4);
                                std::thread::sleep(nap);
                                left = left.saturating_sub(nap);
                            }
                        }
                    })
                    .map_err(|e| {
                        Error::io("spawn cluster heartbeat", e)
                    })?,
            )
        };
        let node = ClusterNode {
            shared,
            accept: Some(accept),
            heartbeat,
            conns,
        };
        if let Some(sponsor) = cfg.join.as_deref() {
            if let Err(e) = node.join_via(sponsor) {
                let _ = node.shutdown();
                return Err(e);
            }
        }
        Ok(node)
    }

    /// Register with a live member at `sponsor`: send `Join`, install
    /// the roster and table its `JoinOk` carries, and Hello everyone.
    /// After this the node is routable (owns nothing yet); call
    /// [`ClusterNode::pull_share`] to take on a uniform share.
    fn join_via(&self, sponsor: &str) -> Result<()> {
        let client = RpcClient::new(PeerAddr::parse(sponsor)?);
        let req = Msg::Join {
            node_id: self.shared.node_id,
            addr: self.shared.bound.clone(),
        };
        match client.rpc(&req)? {
            Msg::JoinOk { epoch, owner, peers } => {
                for (id, addr) in peers {
                    if id != self.shared.node_id {
                        let _ = self.shared.add_peer(id, &addr, false);
                    }
                }
                match self.shared.apply_table(epoch, owner) {
                    Ok(()) => {}
                    // The sponsor's epoch-bump broadcast (or a later
                    // table) can beat the JoinOk reply here; newer
                    // already installed means the join landed.
                    Err(_) if self.shared.epoch() > epoch => {}
                    Err(e) => return Err(e),
                }
                self.hello_peers();
                Ok(())
            }
            Msg::Denied { reason } => Err(Error::Stream(format!(
                "join denied by {sponsor}: {reason}"
            ))),
            other => Err(Error::Stream(format!(
                "unexpected {} reply to join",
                other.label()
            ))),
        }
    }

    /// Pull this node's uniform share of shards from the current
    /// owners (called after a dynamic join): repeatedly take the
    /// highest shard from the biggest owner — never a donor's last
    /// shard — until this node holds `virtual_shards / members`.
    /// Every transfer is the ordinary seal → adopt migration, so
    /// in-flight streams survive bit-identically. Returns how many
    /// shards were pulled.
    pub fn pull_share(&self) -> Result<usize> {
        let table = self.shared.table.lock().unwrap().clone();
        if table.owner.is_empty() {
            return Err(Error::Stream(
                "no ownership table installed yet".into(),
            ));
        }
        let mut members = table.members();
        if !members.contains(&self.shared.node_id) {
            members.push(self.shared.node_id);
        }
        let share = table.owner.len() / members.len();
        let mut have = table.shards_of(self.shared.node_id).len();
        let mut per_owner: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for (s, &o) in table.owner.iter().enumerate() {
            if o != self.shared.node_id {
                per_owner.entry(o).or_default().push(s as u32);
            }
        }
        let mut plan: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        while have < share {
            let Some((&donor, shards)) = per_owner
                .iter_mut()
                .filter(|(_, v)| v.len() > 1)
                .max_by_key(|(&id, v)| {
                    (v.len(), std::cmp::Reverse(id))
                })
            else {
                break;
            };
            let s = shards.pop().expect("donor has > 1 shard");
            plan.entry(donor).or_default().push(s);
            have += 1;
        }
        let mut pulled = 0;
        for (owner, shards) in plan {
            self.shared.pull_from_peer(owner, &shards)?;
            pulled += shards.len();
        }
        Ok(pulled)
    }

    /// Leave the cluster cleanly: refuse while this node still owns
    /// shards (migrate them away first), otherwise announce `Leave`
    /// to every peer. Returns how many peers acknowledged.
    pub fn leave(&self) -> Result<usize> {
        let owned = self.owned_shards();
        if !owned.is_empty() {
            return Err(Error::Stream(format!(
                "cannot leave: node {} still owns {} shards \
                 (migrate them away first)",
                self.shared.node_id,
                owned.len()
            )));
        }
        let req = Msg::Leave { node_id: self.shared.node_id };
        let mut acked = 0;
        for p in self.shared.peer_snapshot() {
            if let Ok(Msg::Ok) = p.client.rpc(&req) {
                acked += 1;
            }
        }
        Ok(acked)
    }

    /// One cross-node rebalance decision right now (the heartbeat
    /// loop runs the same check on its own cadence). See
    /// [`Shared::maybe_rebalance_cluster`] for the policy.
    pub fn maybe_rebalance_cluster(&self) -> Result<usize> {
        self.shared.maybe_rebalance_cluster()
    }

    /// Record (or clear) the cluster-autoscale recommendation:
    /// sustained pressure with local worker scaling exhausted means
    /// the cluster wants another node. Surfaces as the
    /// `node_scale_hint` gauge and a line in [`ClusterNode::status`].
    pub fn set_scale_hint(&self, want: bool) {
        self.shared.set_scale_hint(want);
    }

    /// This node's id.
    pub fn node_id(&self) -> u64 {
        self.shared.node_id
    }

    /// The transport's actual bound address (resolves `:0` binds).
    pub fn bound_addr(&self) -> String {
        self.shared.bound.clone()
    }

    /// Current ownership table (copy).
    pub fn table(&self) -> NodeTable {
        self.shared.table.lock().unwrap().clone()
    }

    /// Current table epoch.
    pub fn epoch(&self) -> u64 {
        self.shared.epoch()
    }

    /// Shards this node currently owns.
    pub fn owned_shards(&self) -> Vec<u32> {
        self.shared
            .table
            .lock()
            .unwrap()
            .shards_of(self.shared.node_id)
    }

    /// Dial every peer with a Hello; returns how many answered. Useful
    /// at boot (populates liveness before the first heartbeat round)
    /// and harmless to repeat.
    pub fn hello_peers(&self) -> usize {
        let mut up = 0;
        for peer in self.shared.peer_snapshot() {
            let req = Msg::Hello {
                node_id: self.shared.node_id,
                epoch: self.shared.epoch(),
            };
            if let Ok(Msg::HelloOk { epoch, .. }) = peer.client.rpc(&req)
            {
                self.shared.note_alive(peer.id, epoch, None);
                up += 1;
            }
        }
        up
    }

    /// Human-readable status (the `teda-fpga cluster` subcommand's
    /// payload when pointed at this node).
    pub fn status(&self) -> String {
        self.shared.status()
    }

    /// Move `shards` from this node to `peer`: the exact
    /// Expect → install → Seal → drain → Adopt sequence of the
    /// in-process rebalancer, with the destination endpoint behind the
    /// framed transport. Verdicts stay bit-identical to an unmigrated
    /// run — strays drained up to the barrier cross as Replay frames
    /// on the same serialized connection as the Adopt.
    pub fn migrate_to_peer(
        &self,
        peer: u64,
        shards: &[u32],
    ) -> Result<MigrationStats> {
        self.shared.migrate_to_peer(peer, shards)
    }

    /// Pull `shards` from `peer` onto this node (the mirror move:
    /// remote seal, local adopt). The drain step is a Settle frame —
    /// the remote re-routes its strays, which arrive here as Replay
    /// frames *before* this side's local Adopt is enqueued.
    pub fn pull_from_peer(
        &self,
        peer: u64,
        shards: &[u32],
    ) -> Result<MigrationStats> {
        self.shared.pull_from_peer(peer, shards)
    }

    /// Manually fail over a (known-dead) peer: adopt every shard it
    /// owned, recovering state from the shared checkpoint store.
    /// Returns the number of shards adopted. The automatic path (the
    /// heartbeat monitor with `cluster.failover_ms > 0`) calls the
    /// same sequence.
    pub fn failover(&self, dead: u64) -> Result<usize> {
        self.shared.failover(dead)
    }

    /// A cloneable ingest handle that routes by *node* ownership:
    /// local samples go down the lock-free local path, foreign ones
    /// are forwarded to their owner in one Samples frame per peer.
    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { shared: self.shared.clone() }
    }

    /// Stop the control plane: halt heartbeats, stop accepting, join
    /// every connection handler, and unhook the stray forwarder. The
    /// node core keeps serving locally; its owner finishes it.
    pub fn shutdown(mut self) -> Result<()> {
        self.shared.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.heartbeat.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        self.shared.svc.set_stray_forwarder(None);
        Ok(())
    }
}

/// The local node as a [`Transport`] endpoint: the cluster-side twin
/// of [`super::transport::WorkerLink`], fanned out over every local
/// worker through the service's node-level entry points.
struct NodeLocal<'a> {
    svc: &'a Arc<Service>,
}

impl Transport for NodeLocal<'_> {
    fn kind(&self) -> String {
        "local node".into()
    }

    fn expect(&self, shards: &[u32]) -> Result<()> {
        self.svc.expect_shards(shards)
    }

    fn seal(&self, shards: &[u32]) -> Result<Vec<Vec<u8>>> {
        let records = self.svc.seal_shards(shards)?;
        self.svc.mark_foreign(shards, true);
        Ok(records)
    }

    fn barrier(&self) -> Result<()> {
        self.svc.seal_shards(&[]).map(|_| ())
    }

    fn adopt(&self, shards: &[u32], records: Vec<Vec<u8>>) -> Result<()> {
        self.svc.mark_foreign(shards, false);
        self.svc.adopt_shards(shards, records)
    }

    fn replay(
        &self,
        strays: Vec<StraySample>,
    ) -> std::result::Result<usize, Vec<StraySample>> {
        let samples: Vec<Sample> =
            strays.iter().map(|(s, _)| s.clone()).collect();
        match self.svc.replay_strays(samples) {
            Ok(n) => Ok(n),
            Err(_) => Err(strays),
        }
    }

    fn retire(&self) -> Result<()> {
        Ok(())
    }
}

/// Cloneable cluster-aware ingest front door.
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<Shared>,
}

impl ClusterHandle {
    /// Submit a burst: locally-owned samples take the lock-free local
    /// path, the rest are forwarded to their owning peers (one Samples
    /// frame per peer). With `cluster.ingest_buffer > 0`, a group
    /// that cannot be delivered right now (owner mid-failover, table
    /// mid-join) parks in the bounded local buffer and replays once
    /// the route heals — a burst during a failover window is absorbed,
    /// not lost. Errors when buffering is off and a forward fails, or
    /// when the buffer itself is full (all-or-nothing admission) —
    /// the caller decides whether to retry; duplicated retries are
    /// absorbed by the per-stream watermark dedup.
    pub fn submit_batch(&self, samples: Vec<Sample>) -> Result<()> {
        self.shared.cluster_submit(samples)
    }

    /// Samples currently parked in the failover-window ingest buffer.
    pub fn parked(&self) -> usize {
        self.shared.ingest_park.lock().unwrap().len()
    }

    /// Force one park-buffer replay right now (the heartbeat loop
    /// does this on its own cadence); returns how many samples remain
    /// parked afterwards.
    pub fn flush_parked(&self) -> usize {
        self.shared.drain_ingest_park();
        self.parked()
    }

    /// Submit one sample (see [`ClusterHandle::submit_batch`]).
    pub fn submit(&self, sample: Sample) -> Result<()> {
        self.submit_batch(vec![sample])
    }

    /// Node id of the shard owner a stream currently routes to.
    pub fn owner_of_stream(&self, stream_id: u64) -> u64 {
        let t = self.shared.table.lock().unwrap();
        t.owner_of(shard_of(stream_id, t.owner.len() as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_table_is_deterministic_and_covers_all_members() {
        let a = NodeTable::new_uniform(256, &[3, 1, 2]);
        let b = NodeTable::new_uniform(256, &[2, 3, 1]);
        assert_eq!(a, b, "member order must not matter");
        assert_eq!(a.epoch, 0);
        assert_eq!(a.members(), vec![1, 2, 3]);
        let n1 = a.shards_of(1).len();
        let n2 = a.shards_of(2).len();
        let n3 = a.shards_of(3).len();
        assert_eq!(n1 + n2 + n3, 256);
        assert!(n1.abs_diff(n2) <= 1 && n2.abs_diff(n3) <= 1);
    }

    #[test]
    fn with_owner_bumps_epoch_and_moves_only_named_shards() {
        let t = NodeTable::new_uniform(8, &[1, 2]);
        let moved = t.with_owner(&[0, 2], 2);
        assert_eq!(moved.epoch, 1);
        assert_eq!(moved.owner_of(0), 2);
        assert_eq!(moved.owner_of(2), 2);
        for s in [1u32, 3, 5, 7] {
            assert_eq!(moved.owner_of(s), t.owner_of(s), "shard {s}");
        }
        assert!(t.shards_of(9).is_empty(), "unknown member owns nothing");
    }

    #[test]
    fn single_member_table_owns_everything() {
        let t = NodeTable::new_uniform(16, &[7]);
        assert_eq!(t.shards_of(7).len(), 16);
        assert_eq!(t.members(), vec![7]);
    }
}

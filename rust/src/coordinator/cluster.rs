//! Cluster control plane: membership, heartbeats, node-level shard
//! ownership, and cross-process seal → adopt migration.
//!
//! Several `teda-fpga serve` processes — each one a full node core
//! ([`Service`]: workers, rings, engines, state manager) — serve one
//! logical shard map. The split of responsibilities:
//!
//! - **Node core** ([`Service`]): everything inside one process. Its
//!   node-level entry points (`expect_shards` / `seal_shards` /
//!   `adopt_shards` / `replay_strays` / `reroute_strays`) present the
//!   whole process as one [`Transport`]-shaped endpoint fanned out
//!   over the local workers.
//! - **Control plane** (this module): a static peer roster, a
//!   deterministic initial ownership table (every node computes the
//!   same round-robin [`NodeTable`] at epoch 0, so no handshake is
//!   needed to agree), heartbeat liveness, epoch-numbered table
//!   broadcasts, node → node migration driven by the *same*
//!   [`migrate_over`] sequence the in-process rebalancer uses, and
//!   failover: when a peer dies, the lowest-id survivor adopts its
//!   shards from the shared checkpoint store.
//! - **Transport** ([`super::transport`]): the length-prefixed,
//!   CRC-framed TCP/UDS protocol. Sealed bundles cross as unmodified
//!   persist-codec records.
//!
//! Ordering across processes leans on one property: all migration
//! traffic for one move flows over ONE serialized connection (the
//! peer's [`RpcClient`]), so the far side processes Table before Seal,
//! and stray Replays before the Adopt — exactly the FIFO the
//! in-process control plane guarantees.
//!
//! Failover contract: automatic failover (`cluster.failover_ms > 0`)
//! requires every node to share `checkpoint.dir` on a common
//! filesystem and run with `checkpoint.restore = true`. The survivor
//! re-reads the store ([`StateManager::recover`]), takes ownership of
//! the dead node's shards with an empty Adopt, and resuming streams
//! restore at their checkpointed watermarks — samples at or below a
//! watermark are deduplicated, so re-feeding a window of recent
//! samples converges on bit-identical verdicts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::service::Service;
use super::shard_map::shard_of;
use super::transport::frame::{self, Msg};
use super::transport::net::{Listener, PeerAddr, RemoteLink, RpcClient};
use super::transport::{
    migrate_over, MigrationStats, StraySample, Transport,
};
use crate::config::ClusterConfig;
use crate::obs::{record, EventKind, NO_WORKER};
use crate::stream::Sample;
use crate::{Error, Result};

/// How long the accept loop naps when no connection is pending.
const ACCEPT_NAP: Duration = Duration::from_millis(5);

/// Node-level shard ownership: `owner[shard]` is the node id serving
/// that virtual shard. Epoch-numbered like the worker-level
/// [`super::ShardTable`]; higher epoch wins, equal epochs are
/// idempotent duplicates.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeTable {
    /// Monotonic version; bumps on every ownership change.
    pub epoch: u64,
    /// Shard → owning node id, indexed by virtual shard.
    pub owner: Vec<u64>,
}

impl NodeTable {
    /// The deterministic epoch-0 table: shards round-robin over the
    /// sorted member ids. Every node of a roster computes the same
    /// table, so a cluster boots agreed without any exchange.
    pub fn new_uniform(virtual_shards: u32, members: &[u64]) -> NodeTable {
        assert!(!members.is_empty(), "a cluster has at least one node");
        let mut ids = members.to_vec();
        ids.sort_unstable();
        ids.dedup();
        let owner = (0..virtual_shards)
            .map(|s| ids[s as usize % ids.len()])
            .collect();
        NodeTable { epoch: 0, owner }
    }

    /// Shards owned by `node`, ascending.
    pub fn shards_of(&self, node: u64) -> Vec<u32> {
        self.owner
            .iter()
            .enumerate()
            .filter(|(_, &o)| o == node)
            .map(|(s, _)| s as u32)
            .collect()
    }

    /// Owner of one shard (panics on out-of-range shard).
    pub fn owner_of(&self, shard: u32) -> u64 {
        self.owner[shard as usize]
    }

    /// Successor table: `shards` reassigned to `node`, epoch bumped.
    pub fn with_owner(&self, shards: &[u32], node: u64) -> NodeTable {
        let mut owner = self.owner.clone();
        for &s in shards {
            owner[s as usize] = node;
        }
        NodeTable { epoch: self.epoch + 1, owner }
    }

    /// Distinct member ids present in the table, ascending.
    pub fn members(&self) -> Vec<u64> {
        let mut ids = self.owner.clone();
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

struct PeerState {
    alive: bool,
    last_seen: Option<Instant>,
    epoch: u64,
}

struct Peer {
    id: u64,
    client: Arc<RpcClient>,
    state: Mutex<PeerState>,
}

struct Shared {
    node_id: u64,
    svc: Arc<Service>,
    table: Mutex<NodeTable>,
    peers: BTreeMap<u64, Peer>,
    heartbeat_every: Duration,
    /// 0 = automatic failover off.
    failover_after: Duration,
    /// Serializes node-level moves and failovers against each other.
    move_lock: Mutex<()>,
    stop: AtomicBool,
    bound: String,
    started: Instant,
}

impl Shared {
    fn peer(&self, id: u64) -> Result<&Peer> {
        self.peers.get(&id).ok_or_else(|| {
            Error::Stream(format!("unknown cluster peer {id}"))
        })
    }

    fn epoch(&self) -> u64 {
        self.table.lock().unwrap().epoch
    }

    /// Liveness bookkeeping for any message proving `id` is up.
    fn note_alive(&self, id: u64, epoch: u64) {
        let Some(peer) = self.peers.get(&id) else { return };
        let mut st = peer.state.lock().unwrap();
        if !st.alive {
            self.svc.metrics().peer_connects.inc();
            record(EventKind::PeerConnect, id, 0, NO_WORKER);
        }
        st.alive = true;
        st.last_seen = Some(Instant::now());
        st.epoch = epoch;
        drop(st);
        self.refresh_peers_alive();
    }

    fn note_dead(&self, id: u64) {
        if let Some(peer) = self.peers.get(&id) {
            peer.state.lock().unwrap().alive = false;
            peer.client.disconnect();
        }
        self.refresh_peers_alive();
    }

    fn refresh_peers_alive(&self) {
        let alive = self
            .peers
            .values()
            .filter(|p| p.state.lock().unwrap().alive)
            .count();
        self.svc.metrics().peers_alive.set(alive as u64);
    }

    /// Adopt a (possibly remote) ownership table. Stale epochs are
    /// refused, the current epoch is an idempotent duplicate. The
    /// service's foreign-shard set tracks the table: shards owned
    /// elsewhere escalate their strays through the forwarder.
    fn apply_table(&self, epoch: u64, owner: Vec<u64>) -> Result<()> {
        let vs = self.svc.table().virtual_shards() as usize;
        if owner.len() != vs {
            return Err(Error::Stream(format!(
                "table for {} shards, this cluster serves {vs}",
                owner.len()
            )));
        }
        {
            let mut t = self.table.lock().unwrap();
            if epoch < t.epoch {
                return Err(Error::Stream(format!(
                    "stale table epoch {epoch} (current {})",
                    t.epoch
                )));
            }
            // An empty current table is the pre-bootstrap sentinel:
            // accept whatever installs first.
            if epoch == t.epoch && !t.owner.is_empty() {
                if t.owner == owner {
                    return Ok(());
                }
                return Err(Error::Stream(format!(
                    "conflicting table at epoch {epoch}"
                )));
            }
            *t = NodeTable { epoch, owner: owner.clone() };
        }
        let mut mine = Vec::new();
        let mut foreign = Vec::new();
        for (s, &o) in owner.iter().enumerate() {
            if o == self.node_id {
                mine.push(s as u32);
            } else {
                foreign.push(s as u32);
            }
        }
        self.svc.mark_foreign(&foreign, true);
        self.svc.mark_foreign(&mine, false);
        self.svc.metrics().cluster_epoch.set(epoch);
        Ok(())
    }

    /// Install a successor table locally, then push it to every peer.
    /// Push failures are tolerated: a lagging peer self-heals on the
    /// next heartbeat (its stale epoch triggers a re-push), and a dead
    /// one is on its way to failover.
    fn install_table(&self, next: NodeTable) -> Result<()> {
        let msg = Msg::Table {
            epoch: next.epoch,
            owner: next.owner.clone(),
        };
        self.apply_table(next.epoch, next.owner)?;
        for peer in self.peers.values() {
            let _ = peer.client.rpc(&msg);
        }
        Ok(())
    }

    /// Escalate strays whose shards live on a peer ([`Service`] calls
    /// this through the forwarder hook). Delivered strays ride the
    /// peer's control plane (Replay), staying FIFO with any queued
    /// Adopt over there. Undeliverable strays come back to be parked.
    fn forward_strays(
        &self,
        strays: Vec<StraySample>,
    ) -> std::result::Result<usize, Vec<StraySample>> {
        let table = self.table.lock().unwrap().clone();
        let vs = table.owner.len() as u32;
        let mut per_owner: BTreeMap<u64, Vec<StraySample>> =
            BTreeMap::new();
        for stray in strays {
            let owner = table.owner_of(shard_of(stray.0.stream_id, vs));
            per_owner.entry(owner).or_default().push(stray);
        }
        let mut delivered = 0usize;
        let mut failed: Vec<StraySample> = Vec::new();
        for (owner, group) in per_owner {
            // A shard marked foreign but mapping to self is a transient
            // race with a table install: park, the next drain re-reads.
            let peer = match self.peers.get(&owner) {
                Some(p) if owner != self.node_id => p,
                _ => {
                    failed.extend(group);
                    continue;
                }
            };
            let samples: Vec<Sample> =
                group.iter().map(|(s, _)| s.clone()).collect();
            let n = samples.len();
            match peer.client.rpc(&Msg::Replay { samples }) {
                Ok(Msg::Ok) => delivered += n,
                _ => failed.extend(group),
            }
        }
        if failed.is_empty() {
            Ok(delivered)
        } else {
            Err(failed)
        }
    }

    /// One request → one reply. Control messages map straight onto the
    /// node core's protocol entry points.
    fn handle_msg(&self, msg: Msg) -> Msg {
        let m = self.svc.metrics();
        match msg {
            Msg::Hello { node_id, epoch } => {
                self.note_alive(node_id, epoch);
                Msg::HelloOk {
                    node_id: self.node_id,
                    epoch: self.epoch(),
                }
            }
            Msg::Heartbeat { node_id, epoch } => {
                m.heartbeats_rx.inc();
                self.note_alive(node_id, epoch);
                record(EventKind::Heartbeat, node_id, 0, NO_WORKER);
                Msg::HelloOk {
                    node_id: self.node_id,
                    epoch: self.epoch(),
                }
            }
            Msg::Expect { shards } => {
                match self.svc.expect_shards(&shards) {
                    Ok(()) => Msg::Ok,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Seal { shards } => {
                match self.svc.seal_shards(&shards) {
                    Ok(records) => {
                        if !shards.is_empty() {
                            self.svc.mark_foreign(&shards, true);
                            let bytes: u64 = records
                                .iter()
                                .map(|r| r.len() as u64)
                                .sum();
                            m.bundle_bytes_tx.add(bytes);
                            record(
                                EventKind::BundleShip,
                                bytes,
                                shards.len() as u32,
                                NO_WORKER,
                            );
                        }
                        Msg::Bundle { records }
                    }
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Adopt { shards, records } => {
                let bytes: u64 =
                    records.iter().map(|r| r.len() as u64).sum();
                self.svc.mark_foreign(&shards, false);
                match self.svc.adopt_shards(&shards, records) {
                    Ok(()) => {
                        m.bundle_bytes_rx.add(bytes);
                        record(
                            EventKind::BundleShip,
                            bytes,
                            shards.len() as u32,
                            NO_WORKER,
                        );
                        Msg::Ok
                    }
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Replay { samples } => {
                match self.svc.replay_strays(samples) {
                    Ok(_) => Msg::Ok,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Samples { samples } => {
                match self.svc.submit_batch(samples) {
                    Ok(()) => Msg::Ok,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Table { epoch, owner } => {
                match self.apply_table(epoch, owner) {
                    Ok(()) => Msg::Ok,
                    Err(e) => Msg::Denied { reason: e.to_string() },
                }
            }
            Msg::Settle => match self.svc.reroute_strays() {
                Ok(_) => Msg::Ok,
                Err(e) => Msg::Denied { reason: e.to_string() },
            },
            Msg::Status => Msg::StatusText { text: self.status() },
            // Replies arriving as requests: protocol violation.
            other => Msg::Denied {
                reason: format!("unexpected {} request", other.label()),
            },
        }
    }

    fn status(&self) -> String {
        let table = self.table.lock().unwrap();
        let owned = table.shards_of(self.node_id).len();
        let m = self.svc.metrics();
        let mut out = format!(
            "node {} @ {}\nepoch {}\nshards {}/{} owned\n\
             workers {}\nsamples_in {}\nuptime {:.1}s\n",
            self.node_id,
            self.bound,
            table.epoch,
            owned,
            table.owner.len(),
            self.svc.workers(),
            m.samples_in.get(),
            self.started.elapsed().as_secs_f64(),
        );
        for peer in self.peers.values() {
            let st = peer.state.lock().unwrap();
            out.push_str(&format!(
                "peer {} @ {} {} (epoch {}, owns {})\n",
                peer.id,
                peer.client.addr(),
                if st.alive { "alive" } else { "unseen/dead" },
                st.epoch,
                table.shards_of(peer.id).len(),
            ));
        }
        out
    }

    /// Am I the designated survivor for `dead`? Exactly one node may
    /// run a failover: the lowest-id member still alive.
    fn failover_leader(&self, dead: u64) -> bool {
        self.peers.values().all(|p| {
            p.id == dead
                || p.id > self.node_id
                || !p.state.lock().unwrap().alive
        })
    }

    /// Adopt every shard `dead` owned, recovering stream state from
    /// the shared checkpoint store. Returns how many shards moved.
    fn failover(&self, dead: u64) -> Result<usize> {
        let _guard = self.move_lock.lock().unwrap();
        let (shards, next) = {
            let t = self.table.lock().unwrap();
            let shards = t.shards_of(dead);
            let next = t.with_owner(&shards, self.node_id);
            (shards, next)
        };
        if shards.is_empty() {
            return Ok(0);
        }
        // Pull the dead node's published watermarks out of the shared
        // durable store; resuming streams restore from them. Without a
        // durable store this degrades to ownership-only adoption.
        let _ = self.svc.state_manager().recover();
        self.svc.expect_shards(&shards)?;
        self.install_table(next)?;
        self.svc.adopt_shards(&shards, Vec::new())?;
        self.note_dead(dead);
        self.svc.metrics().failovers.inc();
        record(
            EventKind::Failover,
            dead,
            shards.len() as u32,
            NO_WORKER,
        );
        Ok(shards.len())
    }

    /// One heartbeat round over every peer. Successes refresh
    /// liveness (and re-push the table to lagging peers); a silence
    /// longer than the failover window declares the peer dead and —
    /// if automatic failover is on and this node is the designated
    /// survivor — adopts its shards.
    fn heartbeat_round(&self) {
        let m = self.svc.metrics();
        for peer in self.peers.values() {
            if self.stop.load(Ordering::Acquire) {
                return;
            }
            let req = Msg::Heartbeat {
                node_id: self.node_id,
                epoch: self.epoch(),
            };
            match peer.client.rpc(&req) {
                Ok(Msg::HelloOk { epoch, .. }) => {
                    m.heartbeats_tx.inc();
                    self.note_alive(peer.id, epoch);
                    record(EventKind::Heartbeat, peer.id, 0, NO_WORKER);
                    if epoch < self.epoch() {
                        // Lagging peer (missed a broadcast): re-push.
                        let t = self.table.lock().unwrap().clone();
                        let _ = peer.client.rpc(&Msg::Table {
                            epoch: t.epoch,
                            owner: t.owner,
                        });
                    }
                }
                _ => {
                    let (was_alive, basis) = {
                        let st = peer.state.lock().unwrap();
                        (st.alive, st.last_seen.unwrap_or(self.started))
                    };
                    let dead_after = if self.failover_after.is_zero() {
                        // No auto failover: still mark dead after a few
                        // missed rounds so status/metrics tell the truth.
                        self.heartbeat_every * 3
                    } else {
                        self.failover_after
                    };
                    if basis.elapsed() < dead_after {
                        continue;
                    }
                    if was_alive {
                        self.note_dead(peer.id);
                    }
                    if !self.failover_after.is_zero()
                        && self.failover_leader(peer.id)
                        && !self
                            .table
                            .lock()
                            .unwrap()
                            .shards_of(peer.id)
                            .is_empty()
                    {
                        let _ = self.failover(peer.id);
                    }
                }
            }
        }
    }
}

/// A running cluster node: the transport listener + heartbeat loop
/// wrapped around a node core. Create with [`ClusterNode::start`],
/// stop with [`ClusterNode::shutdown`] (the [`Service`] itself is
/// finished separately by its owner).
pub struct ClusterNode {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    heartbeat: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ClusterNode {
    /// Bind the transport, install the deterministic epoch-0 table,
    /// hook the service's stray forwarder, and start the accept +
    /// heartbeat threads. `cfg.listen` must be set.
    pub fn start(
        svc: Arc<Service>,
        cfg: &ClusterConfig,
    ) -> Result<ClusterNode> {
        let listen = cfg.listen.as_deref().ok_or_else(|| {
            Error::Config("cluster.listen is required".into())
        })?;
        let listener = Listener::bind(&PeerAddr::parse(listen)?)?;
        let bound = listener.bound_addr();

        let mut peers = BTreeMap::new();
        let mut members = vec![cfg.node_id];
        for (id, addr) in cfg.parse_peers()? {
            members.push(id);
            peers.insert(
                id,
                Peer {
                    id,
                    client: Arc::new(RpcClient::new(PeerAddr::parse(
                        &addr,
                    )?)),
                    state: Mutex::new(PeerState {
                        alive: false,
                        last_seen: None,
                        epoch: 0,
                    }),
                },
            );
        }
        let table = NodeTable::new_uniform(
            svc.table().virtual_shards(),
            &members,
        );
        let shared = Arc::new(Shared {
            node_id: cfg.node_id,
            svc,
            table: Mutex::new(NodeTable { epoch: 0, owner: Vec::new() }),
            peers,
            heartbeat_every: Duration::from_millis(cfg.heartbeat_ms),
            failover_after: Duration::from_millis(cfg.failover_ms),
            move_lock: Mutex::new(()),
            stop: AtomicBool::new(false),
            bound,
            started: Instant::now(),
        });
        // Epoch 0 through the same path every later table takes (also
        // seeds the foreign-shard set and the cluster_epoch gauge).
        shared.apply_table(0, table.owner)?;

        // Stray escalation: a Weak hook, so Service ⇄ cluster never
        // form an Arc cycle and the service stays individually owned.
        let weak: Weak<Shared> = Arc::downgrade(&shared);
        shared.svc.set_stray_forwarder(Some(Arc::new(
            move |strays: Vec<StraySample>| match weak.upgrade() {
                Some(sh) => sh.forward_strays(strays),
                None => Err(strays),
            },
        )));

        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name(format!("teda-cluster-accept-{}", shared.node_id))
                .spawn(move || {
                    while !shared.stop.load(Ordering::Acquire) {
                        match listener.try_accept() {
                            Ok(Some(mut conn)) => {
                                let sh = shared.clone();
                                let h = std::thread::Builder::new()
                                    .name("teda-cluster-conn".into())
                                    .spawn(move || {
                                        while let Ok(Some(msg)) =
                                            frame::read_msg_cancellable(
                                                &mut conn, &sh.stop,
                                            )
                                            .map_err(|_| {
                                                sh.svc
                                                    .metrics()
                                                    .frame_errors
                                                    .inc();
                                            })
                                        {
                                            let reply =
                                                sh.handle_msg(msg);
                                            if frame::write_msg(
                                                &mut conn, &reply,
                                            )
                                            .is_err()
                                            {
                                                break;
                                            }
                                        }
                                    })
                                    .expect("spawn conn handler");
                                conns.lock().unwrap().push(h);
                            }
                            Ok(None) => std::thread::sleep(ACCEPT_NAP),
                            Err(_) => {
                                shared.svc.metrics().frame_errors.inc()
                            }
                        }
                    }
                })
                .map_err(|e| Error::io("spawn cluster accept", e))?
        };
        let heartbeat = if shared.peers.is_empty() {
            None
        } else {
            let sh = shared.clone();
            Some(
                std::thread::Builder::new()
                    .name(format!(
                        "teda-cluster-heartbeat-{}",
                        sh.node_id
                    ))
                    .spawn(move || {
                        while !sh.stop.load(Ordering::Acquire) {
                            sh.heartbeat_round();
                            // Nap in short slices: prompt shutdown.
                            let mut left = sh.heartbeat_every;
                            while !left.is_zero()
                                && !sh.stop.load(Ordering::Acquire)
                            {
                                let nap = left.min(ACCEPT_NAP * 4);
                                std::thread::sleep(nap);
                                left = left.saturating_sub(nap);
                            }
                        }
                    })
                    .map_err(|e| {
                        Error::io("spawn cluster heartbeat", e)
                    })?,
            )
        };
        Ok(ClusterNode {
            shared,
            accept: Some(accept),
            heartbeat,
            conns,
        })
    }

    /// This node's id.
    pub fn node_id(&self) -> u64 {
        self.shared.node_id
    }

    /// The transport's actual bound address (resolves `:0` binds).
    pub fn bound_addr(&self) -> String {
        self.shared.bound.clone()
    }

    /// Current ownership table (copy).
    pub fn table(&self) -> NodeTable {
        self.shared.table.lock().unwrap().clone()
    }

    /// Current table epoch.
    pub fn epoch(&self) -> u64 {
        self.shared.epoch()
    }

    /// Shards this node currently owns.
    pub fn owned_shards(&self) -> Vec<u32> {
        self.shared
            .table
            .lock()
            .unwrap()
            .shards_of(self.shared.node_id)
    }

    /// Dial every peer with a Hello; returns how many answered. Useful
    /// at boot (populates liveness before the first heartbeat round)
    /// and harmless to repeat.
    pub fn hello_peers(&self) -> usize {
        let mut up = 0;
        for peer in self.shared.peers.values() {
            let req = Msg::Hello {
                node_id: self.shared.node_id,
                epoch: self.shared.epoch(),
            };
            if let Ok(Msg::HelloOk { epoch, .. }) = peer.client.rpc(&req)
            {
                self.shared.note_alive(peer.id, epoch);
                up += 1;
            }
        }
        up
    }

    /// Human-readable status (the `teda-fpga cluster` subcommand's
    /// payload when pointed at this node).
    pub fn status(&self) -> String {
        self.shared.status()
    }

    /// Move `shards` from this node to `peer`: the exact
    /// Expect → install → Seal → drain → Adopt sequence of the
    /// in-process rebalancer, with the destination endpoint behind the
    /// framed transport. Verdicts stay bit-identical to an unmigrated
    /// run — strays drained up to the barrier cross as Replay frames
    /// on the same serialized connection as the Adopt.
    pub fn migrate_to_peer(
        &self,
        peer: u64,
        shards: &[u32],
    ) -> Result<MigrationStats> {
        let sh = &self.shared;
        let _guard = sh.move_lock.lock().unwrap();
        let (next, not_mine) = {
            let t = sh.table.lock().unwrap();
            let not_mine: Vec<u32> = shards
                .iter()
                .copied()
                .filter(|&s| {
                    (s as usize) >= t.owner.len()
                        || t.owner_of(s) != sh.node_id
                })
                .collect();
            (t.with_owner(shards, peer), not_mine)
        };
        if !not_mine.is_empty() {
            return Err(Error::Stream(format!(
                "cannot migrate shards {not_mine:?}: not owned by node {}",
                sh.node_id
            )));
        }
        let t0 = Instant::now();
        let remote = RemoteLink::new(sh.peer(peer)?.client.clone())
            .with_metrics(sh.svc.metrics());
        let local = NodeLocal { svc: &sh.svc };
        let stats = migrate_over(
            &local,
            &remote,
            shards,
            &mut || sh.install_table(next.clone()),
            &mut || sh.svc.reroute_strays().map(|_| ()),
        )?;
        let m = sh.svc.metrics();
        m.migrations.inc();
        m.shards_moved.add(shards.len() as u64);
        m.streams_migrated.add(stats.streams);
        m.migration_time.record(t0.elapsed().as_nanos() as u64);
        record(
            EventKind::BundleShip,
            stats.bytes,
            shards.len() as u32,
            NO_WORKER,
        );
        Ok(stats)
    }

    /// Pull `shards` from `peer` onto this node (the mirror move:
    /// remote seal, local adopt). The drain step is a Settle frame —
    /// the remote re-routes its strays, which arrive here as Replay
    /// frames *before* this side's local Adopt is enqueued.
    pub fn pull_from_peer(
        &self,
        peer: u64,
        shards: &[u32],
    ) -> Result<MigrationStats> {
        let sh = &self.shared;
        let _guard = sh.move_lock.lock().unwrap();
        let (next, not_theirs) = {
            let t = sh.table.lock().unwrap();
            let not_theirs: Vec<u32> = shards
                .iter()
                .copied()
                .filter(|&s| {
                    (s as usize) >= t.owner.len()
                        || t.owner_of(s) != peer
                })
                .collect();
            (t.with_owner(shards, sh.node_id), not_theirs)
        };
        if !not_theirs.is_empty() {
            return Err(Error::Stream(format!(
                "cannot pull shards {not_theirs:?}: not owned by peer \
                 {peer}"
            )));
        }
        let t0 = Instant::now();
        let client = sh.peer(peer)?.client.clone();
        let remote = RemoteLink::new(client.clone())
            .with_metrics(sh.svc.metrics());
        let local = NodeLocal { svc: &sh.svc };
        let stats = migrate_over(
            &remote,
            &local,
            shards,
            &mut || sh.install_table(next.clone()),
            &mut || match client.rpc(&Msg::Settle)? {
                Msg::Ok => Ok(()),
                Msg::Denied { reason } => Err(Error::Stream(format!(
                    "peer {peer} denied settle: {reason}"
                ))),
                other => Err(Error::Stream(format!(
                    "peer {peer}: unexpected {} reply to settle",
                    other.label()
                ))),
            },
        )?;
        let m = sh.svc.metrics();
        m.migrations.inc();
        m.shards_moved.add(shards.len() as u64);
        m.streams_migrated.add(stats.streams);
        m.migration_time.record(t0.elapsed().as_nanos() as u64);
        Ok(stats)
    }

    /// Manually fail over a (known-dead) peer: adopt every shard it
    /// owned, recovering state from the shared checkpoint store.
    /// Returns the number of shards adopted. The automatic path (the
    /// heartbeat monitor with `cluster.failover_ms > 0`) calls the
    /// same sequence.
    pub fn failover(&self, dead: u64) -> Result<usize> {
        self.shared.failover(dead)
    }

    /// A cloneable ingest handle that routes by *node* ownership:
    /// local samples go down the lock-free local path, foreign ones
    /// are forwarded to their owner in one Samples frame per peer.
    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { shared: self.shared.clone() }
    }

    /// Stop the control plane: halt heartbeats, stop accepting, join
    /// every connection handler, and unhook the stray forwarder. The
    /// node core keeps serving locally; its owner finishes it.
    pub fn shutdown(mut self) -> Result<()> {
        self.shared.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.heartbeat.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        self.shared.svc.set_stray_forwarder(None);
        Ok(())
    }
}

/// The local node as a [`Transport`] endpoint: the cluster-side twin
/// of [`super::transport::WorkerLink`], fanned out over every local
/// worker through the service's node-level entry points.
struct NodeLocal<'a> {
    svc: &'a Arc<Service>,
}

impl Transport for NodeLocal<'_> {
    fn kind(&self) -> String {
        "local node".into()
    }

    fn expect(&self, shards: &[u32]) -> Result<()> {
        self.svc.expect_shards(shards)
    }

    fn seal(&self, shards: &[u32]) -> Result<Vec<Vec<u8>>> {
        let records = self.svc.seal_shards(shards)?;
        self.svc.mark_foreign(shards, true);
        Ok(records)
    }

    fn barrier(&self) -> Result<()> {
        self.svc.seal_shards(&[]).map(|_| ())
    }

    fn adopt(&self, shards: &[u32], records: Vec<Vec<u8>>) -> Result<()> {
        self.svc.mark_foreign(shards, false);
        self.svc.adopt_shards(shards, records)
    }

    fn replay(
        &self,
        strays: Vec<StraySample>,
    ) -> std::result::Result<usize, Vec<StraySample>> {
        let samples: Vec<Sample> =
            strays.iter().map(|(s, _)| s.clone()).collect();
        match self.svc.replay_strays(samples) {
            Ok(n) => Ok(n),
            Err(_) => Err(strays),
        }
    }

    fn retire(&self) -> Result<()> {
        Ok(())
    }
}

/// Cloneable cluster-aware ingest front door.
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<Shared>,
}

impl ClusterHandle {
    /// Submit a burst: locally-owned samples take the lock-free local
    /// path, the rest are forwarded to their owning peers (one Samples
    /// frame per peer). Errors if any forward is refused or a peer is
    /// unreachable — the caller decides whether to retry; duplicated
    /// retries are absorbed by the per-stream watermark dedup.
    pub fn submit_batch(&self, samples: Vec<Sample>) -> Result<()> {
        let sh = &self.shared;
        let (vs, table) = {
            let t = sh.table.lock().unwrap();
            (t.owner.len() as u32, t.clone())
        };
        let mut local: Vec<Sample> = Vec::new();
        let mut remote: BTreeMap<u64, Vec<Sample>> = BTreeMap::new();
        for s in samples {
            let owner = table.owner_of(shard_of(s.stream_id, vs));
            if owner == sh.node_id {
                local.push(s);
            } else {
                remote.entry(owner).or_default().push(s);
            }
        }
        if !local.is_empty() {
            sh.svc.submit_batch(local)?;
        }
        for (owner, group) in remote {
            let peer = sh.peer(owner)?;
            let n = group.len() as u64;
            match peer.client.rpc(&Msg::Samples { samples: group })? {
                Msg::Ok => {
                    sh.svc.metrics().samples_forwarded.add(n);
                }
                Msg::Denied { reason } => {
                    return Err(Error::Stream(format!(
                        "peer {owner} refused {n} samples: {reason}"
                    )))
                }
                other => {
                    return Err(Error::Stream(format!(
                        "peer {owner}: unexpected {} reply to samples",
                        other.label()
                    )))
                }
            }
        }
        Ok(())
    }

    /// Submit one sample (see [`ClusterHandle::submit_batch`]).
    pub fn submit(&self, sample: Sample) -> Result<()> {
        self.submit_batch(vec![sample])
    }

    /// Node id of the shard owner a stream currently routes to.
    pub fn owner_of_stream(&self, stream_id: u64) -> u64 {
        let t = self.shared.table.lock().unwrap();
        t.owner_of(shard_of(stream_id, t.owner.len() as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_table_is_deterministic_and_covers_all_members() {
        let a = NodeTable::new_uniform(256, &[3, 1, 2]);
        let b = NodeTable::new_uniform(256, &[2, 3, 1]);
        assert_eq!(a, b, "member order must not matter");
        assert_eq!(a.epoch, 0);
        assert_eq!(a.members(), vec![1, 2, 3]);
        let n1 = a.shards_of(1).len();
        let n2 = a.shards_of(2).len();
        let n3 = a.shards_of(3).len();
        assert_eq!(n1 + n2 + n3, 256);
        assert!(n1.abs_diff(n2) <= 1 && n2.abs_diff(n3) <= 1);
    }

    #[test]
    fn with_owner_bumps_epoch_and_moves_only_named_shards() {
        let t = NodeTable::new_uniform(8, &[1, 2]);
        let moved = t.with_owner(&[0, 2], 2);
        assert_eq!(moved.epoch, 1);
        assert_eq!(moved.owner_of(0), 2);
        assert_eq!(moved.owner_of(2), 2);
        for s in [1u32, 3, 5, 7] {
            assert_eq!(moved.owner_of(s), t.owner_of(s), "shard {s}");
        }
        assert!(t.shards_of(9).is_empty(), "unknown member owns nothing");
    }

    #[test]
    fn single_member_table_owns_everything() {
        let t = NodeTable::new_uniform(16, &[7]);
        assert_eq!(t.shards_of(7).len(), 16);
        assert_eq!(t.members(), vec![7]);
    }
}

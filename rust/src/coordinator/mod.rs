//! L3 coordinator — the streaming anomaly-detection service.
//!
//! Topology (vLLM-router-shaped, adapted to detection streams):
//!
//! ```text
//!                  ┌────────────┐   bounded queues    ┌──────────┐
//!  sources ──────▶ │   Router   │ ──────────────────▶ │ Worker 0 │──┐
//!  (submit)        │ fnv1a(sid) │ ──────────────────▶ │ Worker 1 │──┼─▶ results
//!                  └────────────┘        ...          └──────────┘  │   channel
//!                        │                                          │
//!                        └─ backpressure: send blocks when full ◀───┘
//! ```
//!
//! - **Router** ([`Router`]): stable hash of the stream id → worker
//!   index, so one stream's samples always land on the same worker and
//!   per-stream ordering is preserved end-to-end.
//! - **Workers** ([`Service`]): each owns one [`crate::engine::Engine`]
//!   (software / RTL / XLA per config) and processes its queue in
//!   arrival order. The XLA engine performs dynamic batching internally
//!   (S×T chunks); `min_ready` is the service's batching knob.
//! - **State manager** ([`StateManager`]): periodic per-stream,
//!   engine-agnostic [`crate::engine::Snapshot`] checkpoints — software
//!   counters, RTL register files, XLA carries, or whole ensembles with
//!   per-stream combiner weights — published every
//!   `checkpoint.interval` samples and restored on stream resume for
//!   recovery/migration (`checkpoint.restore`). With `checkpoint.dir`
//!   set, every publish is also written through to a durable
//!   [`crate::persist::FileStore`], and
//!   [`Service::start_from_store`] cold-starts a new process from the
//!   newest valid on-disk checkpoint per stream — failover survives
//!   full-process death. `checkpoint.evict_after` drops idle streams
//!   (engine state + checkpoints, memory and disk) so a long-running
//!   service does not accumulate finished streams forever.
//! - **Backpressure**: all queues are bounded; a full worker queue
//!   blocks the router (and ultimately the source), never drops.

mod router;
mod service;
mod state_mgr;

pub use router::Router;
pub use service::{Classified, Service, ServiceHandle};
pub use state_mgr::{StateCheckpoint, StateManager};

//! L3 coordinator — the streaming anomaly-detection service.
//!
//! Topology (vLLM-router-shaped, adapted to detection streams):
//!
//! ```text
//!                ┌───────────────┐   bounded queues    ┌──────────┐
//!  sources ────▶ │   ShardMap    │ ──────────────────▶ │ Worker 0 │──┐
//!  (submit)      │ sid→shard→wkr │ ──────────────────▶ │ Worker 1 │──┼─▶ results
//!                │  (epoch N)    │        ...          └──────────┘  │   channel
//!                └───────────────┘                            ▲      │
//!                        ▲            seal ─▶ snapshots ──────┘      │
//!                   rebalancer        (migration protocol)          ◀┘
//! ```
//!
//! - **Shard map** ([`ShardMap`] / [`ShardTable`]): stream ids hash to
//!   a fixed number of virtual shards ([`shard_of`]); an epoch-numbered
//!   shard → worker table — swapped atomically behind an `Arc` — maps
//!   shards to workers. One stream's samples always land on the shard's
//!   *current* worker, so per-stream ordering is preserved end-to-end,
//!   and the table can change while serving.
//! - **Workers** ([`Service`]): each owns one [`crate::engine::Engine`]
//!   (software / RTL / XLA / ensemble per config) and processes its
//!   queue in arrival order. The XLA engine performs dynamic batching
//!   internally (S×T chunks); `min_ready` is the service's batching
//!   knob. Worker loops are panic-guarded: a dying engine reports
//!   *which* worker failed (`worker_panics` metric) instead of taking
//!   the service down anonymously.
//! - **Rebalancer** ([`Service::migrate_shards`],
//!   [`Service::maybe_rebalance`], [`Service::scale_to`]): moves
//!   shards between workers live via a seal → adopt protocol — the old
//!   worker drains, snapshots every resident stream at its exact
//!   watermark ([`crate::engine::Snapshot`], encoded through the
//!   persist codec as the wire format), the new worker restores and
//!   replays any samples that outran their state through the inclusive-
//!   watermark dedup. Verdicts are bit-identical to an unmigrated run.
//!   `scale_to` adds or retires whole workers with the same protocol.
//! - **State manager** ([`StateManager`]): periodic per-stream,
//!   engine-agnostic snapshot checkpoints published every
//!   `checkpoint.interval` samples and restored on stream resume for
//!   recovery (`checkpoint.restore`); with `checkpoint.dir` set they
//!   are written through to a durable [`crate::persist::FileStore`]
//!   and [`Service::start_from_store`] cold-starts a new process from
//!   disk. Migration seals publish through the same path, so failover
//!   and rebalancing agree on watermarks.
//! - **Lock-free ingest** ([`ring`] / [`senders`]): the steady-state
//!   submit path takes **zero mutexes** — routing is one atomic load
//!   of the epoch-stamped [`ShardTable`] snapshot, the worker lookup is
//!   one atomic load of the matching epoch-stamped sender table, and
//!   the enqueue is an SPSC ring publish (two atomic ops) for the
//!   worker's claimant producer, with a bounded control channel for
//!   everyone else. Batched submission
//!   ([`Service::submit_batch`] / [`ServiceHandle::submit_batch`])
//!   amortizes all of it to one ring/channel operation per worker per
//!   burst.
//! - **Backpressure**: all queues are bounded; a full worker queue
//!   blocks the router (and ultimately the source), never drops.
//! - **Observability** ([`crate::obs`]): the coordinator journals its
//!   control-flow events (routing retries, ring stalls, seal/adopt,
//!   checkpoints, epoch swaps, panics) into the flight recorder, stamps
//!   every job at submit so verdict latency decomposes into
//!   queue-wait / engine / emit stage histograms, and feeds the
//!   rebalancer *windowed* per-shard deltas ([`crate::obs::ShardWindow`])
//!   instead of lifetime counters.

pub mod cluster;
pub mod ring;
pub mod senders;
mod service;
mod shard_map;
mod state_mgr;
pub mod transport;
pub(crate) mod worker;

pub use cluster::{ClusterHandle, ClusterNode, NodeTable};
pub use service::{
    scale_up_wanted, Classified, Service, ServiceHandle, StrayForwarder,
};
pub use shard_map::{
    shard_of, ShardMap, ShardTable, DEFAULT_VIRTUAL_SHARDS,
};
pub use state_mgr::{StateCheckpoint, StateManager};
pub use transport::{migrate_over, MigrationStats, Transport};

//! Migration transport abstraction: one seal → adopt code path for
//! in-process and cross-process shard moves.
//!
//! The migration protocol (ISSUE 5) always used the persist codec as
//! its wire format; this module makes the "wire" real. A
//! [`Transport`] endpoint is *one side* of a shard move — something
//! that can be told to expect shards, seal them into encoded
//! checkpoint records, rendezvous (barrier), adopt records, replay
//! strays, or retire. Two implementations:
//!
//! - [`WorkerLink`]: the zero-cost in-process endpoint — a thin shim
//!   over a worker's control channel, sending exactly the `Job`
//!   variants the pre-split coordinator sent. No serialization, no
//!   copies beyond the protocol's own.
//! - [`net::RemoteLink`]: a peer node reached over the length-prefixed,
//!   CRC-framed TCP/UDS protocol in [`frame`] — sealed bundles ship as
//!   the same codec records, just framed.
//!
//! [`migrate_over`] drives the protocol over any (src, dst) endpoint
//! pair, so `Service::migrate_shards` (worker → worker) and
//! `ClusterNode::migrate_to_peer` (node → node) are the same sequence
//! with different endpoints plugged in.

pub mod frame;
pub mod net;

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::senders::WorkerSlot;
use crate::coordinator::worker::{Job, SealBundle};
use crate::stream::{bounded, Sample};
use crate::{Error, Result};

/// A re-routable stray: a sample plus its original submit time.
pub type StraySample = (Sample, Instant);

/// One endpoint of a shard migration. Implementations must preserve
/// the protocol's ordering contract: messages sent through one
/// endpoint are processed in send order, and `barrier` returns only
/// after everything enqueued before it (data included) has been
/// processed or stray-forwarded by the far side.
pub trait Transport: Send + Sync {
    /// Human tag for logs/errors ("worker 3", "peer 127.0.0.1:7441").
    fn kind(&self) -> String;

    /// Step 1 (destination): samples for `shards` may now outrun their
    /// state — stash them until the adopt.
    fn expect(&self, shards: &[u32]) -> Result<()>;

    /// Step 2 (source): drain, snapshot-at-watermark, evict and disown
    /// `shards`; return the encoded checkpoint records.
    fn seal(&self, shards: &[u32]) -> Result<Vec<Vec<u8>>>;

    /// Rendezvous: returns once every message (and data sample)
    /// enqueued to this endpoint before the barrier has been processed
    /// or forwarded as a stray.
    fn barrier(&self) -> Result<()>;

    /// Step 3 (destination): restore `records`, take ownership of
    /// `shards`, replay the stash through the dedup window.
    fn adopt(&self, shards: &[u32], records: Vec<Vec<u8>>) -> Result<()>;

    /// Re-deliver strays to this endpoint on the control plane (FIFO
    /// with any queued Adopt). Returns how many were delivered, or
    /// hands every stray back on failure so the caller can park them.
    fn replay(
        &self,
        strays: Vec<StraySample>,
    ) -> std::result::Result<usize, Vec<StraySample>>;

    /// Scale-down farewell: flush and prepare to exit once the queue
    /// closes.
    fn retire(&self) -> Result<()>;
}

/// The in-process endpoint: one worker's control channel. This is the
/// pre-split protocol verbatim — same `Job`s, same error strings — so
/// `rebalance_e2e` and `ingest_stress` prove the refactor
/// behavior-preserving by running unmodified.
pub struct WorkerLink {
    widx: usize,
    slot: Arc<WorkerSlot<Job>>,
}

impl WorkerLink {
    pub(crate) fn new(widx: usize, slot: Arc<WorkerSlot<Job>>) -> Self {
        WorkerLink { widx, slot }
    }

    /// Cancel a pending `expect` (outside the [`Transport`] trait: only
    /// the in-process endpoint ever needs it — the cluster layer backs
    /// off a failover it lost, and the adopt the worker is stashing for
    /// is not coming).
    pub(crate) fn unexpect(&self, shards: &[u32]) -> Result<()> {
        self.slot
            .send_ctl(Job::Unexpect { shards: shards.to_vec() })
            .map_err(|_| Error::Stream(format!("worker {} gone", self.widx)))
    }
}

impl Transport for WorkerLink {
    fn kind(&self) -> String {
        format!("worker {}", self.widx)
    }

    fn expect(&self, shards: &[u32]) -> Result<()> {
        self.slot
            .send_ctl(Job::Expect { shards: shards.to_vec() })
            .map_err(|_| Error::Stream(format!("worker {} gone", self.widx)))
    }

    fn seal(&self, shards: &[u32]) -> Result<Vec<Vec<u8>>> {
        let (reply_tx, reply_rx) = bounded::<SealBundle>(1);
        self.slot
            .send_ctl(Job::Seal { shards: shards.to_vec(), reply: reply_tx })
            .map_err(|_| Error::Stream(format!("worker {} gone", self.widx)))?;
        let bundle = reply_rx.recv().map_err(|_| {
            Error::Stream(format!(
                "worker {} died mid-migration",
                self.widx
            ))
        })?;
        Ok(bundle.records)
    }

    fn barrier(&self) -> Result<()> {
        // An empty Seal is a pure rendezvous: the worker drains its
        // ring before answering, so "answered" spans both queue
        // planes.
        let (reply_tx, reply_rx) = bounded::<SealBundle>(1);
        self.slot
            .send_ctl(Job::Seal { shards: Vec::new(), reply: reply_tx })
            .map_err(|_| Error::Stream(format!("worker {} gone", self.widx)))?;
        reply_rx.recv().map(|_| ()).map_err(|_| {
            Error::Stream(format!(
                "worker {} died mid-migration",
                self.widx
            ))
        })
    }

    fn adopt(&self, shards: &[u32], records: Vec<Vec<u8>>) -> Result<()> {
        self.slot
            .send_ctl(Job::Adopt { shards: shards.to_vec(), records })
            .map_err(|_| Error::Stream(format!("worker {} gone", self.widx)))
    }

    fn replay(
        &self,
        strays: Vec<StraySample>,
    ) -> std::result::Result<usize, Vec<StraySample>> {
        let n = strays.len();
        match self.slot.send_ctl_reclaim(Job::Replay(strays)) {
            Ok(()) => Ok(n),
            Err(Job::Replay(back)) => Err(back),
            Err(_) => unreachable!("reclaim returns what was sent"),
        }
    }

    fn retire(&self) -> Result<()> {
        self.slot
            .send_ctl(Job::Retire)
            .map_err(|_| Error::Stream(format!("worker {} gone", self.widx)))
    }
}

/// What a completed migration moved (for metrics/logs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationStats {
    /// Streams whose state crossed the transport.
    pub streams: u64,
    /// Encoded checkpoint bytes that crossed the transport.
    pub bytes: u64,
}

/// Drive the seal → adopt protocol for one shard set over any endpoint
/// pair. `install` swaps the routing table *between* the destination's
/// Expect and the source's Seal (new submissions route to the
/// destination from that moment). `drain` re-routes every stray
/// surfaced up to the source barrier — it MUST deliver them to the
/// destination's control plane before this function sends the Adopt,
/// which [`Transport::replay`] guarantees.
///
/// Failure contract (inherited verbatim from the pre-split
/// `migrate_set`): once the table is installed, a source-side failure
/// must still deliver an Adopt with whatever records were salvaged, so
/// the destination takes ownership instead of stashing samples
/// forever. Unsealed state is lost exactly as a worker crash loses it;
/// resuming streams go through the normal checkpoint-restore path.
pub fn migrate_over(
    src: &dyn Transport,
    dst: &dyn Transport,
    shards: &[u32],
    install: &mut dyn FnMut() -> Result<()>,
    drain: &mut dyn FnMut() -> Result<()>,
) -> Result<MigrationStats> {
    dst.expect(shards)?;
    install()?;
    let seal = (|| -> Result<Vec<Vec<u8>>> {
        let records = src.seal(shards)?;
        // Barrier round: a submitter that routed under the old table
        // may have enqueued samples behind the Seal while the source
        // drained. When the barrier answers, every such sample has
        // been forwarded as a stray, so `drain` below catches them all
        // and the destination's stash replay can sort them back into
        // per-stream seq order.
        src.barrier()?;
        Ok(records)
    })();
    let (records, seal_err) = match seal {
        Ok(records) => (records, None),
        Err(e) => (Vec::new(), Some(e)),
    };
    let stats = MigrationStats {
        streams: records.len() as u64,
        bytes: records.iter().map(|r| r.len() as u64).sum(),
    };
    // Strays forwarded up to the barrier must precede the Adopt in the
    // destination's queue so the stash replay sees them.
    let drain_err = drain().err();
    dst.adopt(shards, records)?;
    if let Some(e) = seal_err.or(drain_err) {
        return Err(e);
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Scripted endpoint journaling every call, optionally failing the
    /// seal — the ordering contract checked without threads.
    struct Scripted {
        tag: &'static str,
        log: Arc<Mutex<Vec<String>>>,
        fail_seal: bool,
    }

    impl Scripted {
        fn new(
            tag: &'static str,
            log: Arc<Mutex<Vec<String>>>,
            fail_seal: bool,
        ) -> Self {
            Scripted { tag, log, fail_seal }
        }
        fn note(&self, what: String) {
            self.log.lock().unwrap().push(what);
        }
    }

    impl Transport for Scripted {
        fn kind(&self) -> String {
            self.tag.into()
        }
        fn expect(&self, shards: &[u32]) -> Result<()> {
            self.note(format!("{}:expect{:?}", self.tag, shards));
            Ok(())
        }
        fn seal(&self, shards: &[u32]) -> Result<Vec<Vec<u8>>> {
            self.note(format!("{}:seal{:?}", self.tag, shards));
            if self.fail_seal {
                return Err(Error::Stream("seal died".into()));
            }
            Ok(vec![vec![1, 2, 3], vec![4, 5]])
        }
        fn barrier(&self) -> Result<()> {
            self.note(format!("{}:barrier", self.tag));
            Ok(())
        }
        fn adopt(&self, shards: &[u32], records: Vec<Vec<u8>>) -> Result<()> {
            self.note(format!(
                "{}:adopt{:?}x{}",
                self.tag,
                shards,
                records.len()
            ));
            Ok(())
        }
        fn replay(
            &self,
            strays: Vec<StraySample>,
        ) -> std::result::Result<usize, Vec<StraySample>> {
            Ok(strays.len())
        }
        fn retire(&self) -> Result<()> {
            self.note(format!("{}:retire", self.tag));
            Ok(())
        }
    }

    #[test]
    fn migrate_over_runs_the_protocol_in_order() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let src = Scripted::new("src", log.clone(), false);
        let dst = Scripted::new("dst", log.clone(), false);
        let log2 = log.clone();
        let log3 = log.clone();
        let stats = migrate_over(
            &src,
            &dst,
            &[7, 9],
            &mut move || {
                log2.lock().unwrap().push("install".into());
                Ok(())
            },
            &mut move || {
                log3.lock().unwrap().push("drain".into());
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(stats, MigrationStats { streams: 2, bytes: 5 });
        assert_eq!(
            *log.lock().unwrap(),
            vec![
                "dst:expect[7, 9]",
                "install",
                "src:seal[7, 9]",
                "src:barrier",
                "drain",
                "dst:adopt[7, 9]x2",
            ]
        );
    }

    #[test]
    fn seal_failure_still_delivers_an_empty_adopt() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let src = Scripted::new("src", log.clone(), true);
        let dst = Scripted::new("dst", log.clone(), false);
        let err = migrate_over(
            &src,
            &dst,
            &[3],
            &mut || Ok(()),
            &mut || Ok(()),
        )
        .unwrap_err();
        assert!(err.to_string().contains("seal died"), "{err}");
        // The destination still took ownership (empty Adopt delivered).
        assert!(log
            .lock()
            .unwrap()
            .iter()
            .any(|l| l == "dst:adopt[3]x0"));
    }
}

//! The cluster wire format: length-prefixed, CRC-framed messages.
//!
//! Every frame is a 16-byte header followed by a payload:
//!
//! | offset | size | field                                   |
//! |--------|------|-----------------------------------------|
//! | 0      | 4    | magic `b"TEDW"`                         |
//! | 4      | 2    | version (LE, currently 1)               |
//! | 6      | 1    | message type                            |
//! | 7      | 1    | flags (reserved, 0)                     |
//! | 8      | 4    | payload length (LE)                     |
//! | 12     | 4    | frame check (LE), see below             |
//!
//! The frame check is `crc32(payload) XOR crc32(header[4..12])` — it
//! covers the version, type, flags, and length fields as well as the
//! payload, so a bit flip *anywhere* after the magic is caught (a
//! payload-only CRC would let a flipped type byte reinterpret a frame
//! as a different message). The XOR form avoids re-buffering the
//! payload behind the header just to checksum them together.
//!
//! Sealed shard bundles carry the *unmodified* persist-codec records
//! (`TEDACKPT` magic, own per-record CRC) as opaque byte strings — the
//! migration wire format is literally the checkpoint file format, so a
//! bundle that crosses the network is bit-identical to one adopted
//! in-process. Every decoder path is bounds-checked and
//! length-limited: corrupt or hostile input degrades to an error, not
//! a panic or an unbounded allocation (see `tests/transport_corruption.rs`).

use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::persist::codec::crc32;
use crate::stream::Sample;
use crate::{Error, Result};

/// Frame magic: "TEDA wire".
pub const MAGIC: [u8; 4] = *b"TEDW";
/// Wire protocol version.
pub const VERSION: u16 = 1;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 16;
/// Hard payload cap: reject anything larger *before* allocating. A
/// full 256-shard bundle of ensemble checkpoints is well under 1 MiB;
/// 64 MiB leaves headroom for giant ensembles without letting a
/// corrupt length prefix OOM the process.
pub const MAX_PAYLOAD: usize = 64 << 20;

/// Everything that crosses the cluster wire. Requests (node → node)
/// and replies share one enum so a connection handler is a single
/// match.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Join/identify: "I am `node_id`, my table epoch is `epoch`".
    Hello { node_id: u64, epoch: u64 },
    /// Liveness + epoch gossip. `load` is the sender's windowed ingest
    /// rate (samples/s) — every member learns every peer's load from
    /// the heartbeats it receives, which is what the cross-node
    /// rebalancer compares against its own.
    Heartbeat { node_id: u64, epoch: u64, load: u64 },
    /// Migration step 1: stash samples for these shards until Adopt.
    Expect { shards: Vec<u32> },
    /// Migration step 2: seal these shards, reply with a Bundle.
    /// An empty shard list is a pure barrier (rendezvous).
    Seal { shards: Vec<u32> },
    /// Migration step 3: restore the records, own the shards.
    Adopt { shards: Vec<u32>, records: Vec<Vec<u8>> },
    /// Stray re-delivery: samples routed here after a node-level move.
    /// Control-plane ordering: processed FIFO with Expect/Adopt on the
    /// same connection.
    Replay { samples: Vec<Sample> },
    /// Data-plane forwarding: samples this peer owns.
    Samples { samples: Vec<Sample> },
    /// Node-level shard ownership table push (epoch agreement).
    Table { epoch: u64, owner: Vec<u64> },
    /// Ask the remote to settle strays (run its re-route pass) — the
    /// pull-migration epilogue.
    Settle,
    /// Status probe (the `teda-fpga cluster` subcommand).
    Status,
    /// Dynamic membership: "admit me as `node_id`, reachable at
    /// `addr`". The receiver installs the joiner in its roster and
    /// answers with a [`Msg::JoinOk`] snapshot.
    Join { node_id: u64, addr: String },
    /// Dynamic membership: `node_id` is leaving the cluster; drop it
    /// from the roster (its shards must already have moved).
    Leave { node_id: u64 },
    /// Generic success reply.
    Ok,
    /// Refusal with a reason (unknown shards, stale epoch, …).
    Denied { reason: String },
    /// Seal reply: the encoded checkpoint records.
    Bundle { records: Vec<Vec<u8>> },
    /// Hello/Heartbeat reply: the responder's identity and epoch.
    HelloOk { node_id: u64, epoch: u64 },
    /// Status reply: human-readable node status.
    StatusText { text: String },
    /// Join reply: the sponsor's current table and full peer roster
    /// (id → dial address, the sponsor itself included), so the joiner
    /// can dial every member without any out-of-band configuration.
    JoinOk {
        epoch: u64,
        owner: Vec<u64>,
        peers: Vec<(u64, String)>,
    },
}

impl Msg {
    fn type_id(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Heartbeat { .. } => 2,
            Msg::Expect { .. } => 3,
            Msg::Seal { .. } => 4,
            Msg::Adopt { .. } => 5,
            Msg::Replay { .. } => 6,
            Msg::Samples { .. } => 7,
            Msg::Table { .. } => 8,
            Msg::Settle => 9,
            Msg::Status => 10,
            Msg::Join { .. } => 11,
            Msg::Leave { .. } => 12,
            Msg::Ok => 0x40,
            Msg::Denied { .. } => 0x41,
            Msg::Bundle { .. } => 0x42,
            Msg::HelloOk { .. } => 0x43,
            Msg::StatusText { .. } => 0x44,
            Msg::JoinOk { .. } => 0x45,
        }
    }

    /// Short label for logs and error messages.
    pub fn label(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Heartbeat { .. } => "heartbeat",
            Msg::Expect { .. } => "expect",
            Msg::Seal { .. } => "seal",
            Msg::Adopt { .. } => "adopt",
            Msg::Replay { .. } => "replay",
            Msg::Samples { .. } => "samples",
            Msg::Table { .. } => "table",
            Msg::Settle => "settle",
            Msg::Status => "status",
            Msg::Join { .. } => "join",
            Msg::Leave { .. } => "leave",
            Msg::Ok => "ok",
            Msg::Denied { .. } => "denied",
            Msg::Bundle { .. } => "bundle",
            Msg::HelloOk { .. } => "hello_ok",
            Msg::StatusText { .. } => "status_text",
            Msg::JoinOk { .. } => "join_ok",
        }
    }
}

fn err(what: impl Into<String>) -> Error {
    Error::Stream(format!("transport: {}", what.into()))
}

// ---- payload writer ----------------------------------------------------

struct W(Vec<u8>);

impl W {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.0.extend_from_slice(b);
    }
    fn shards(&mut self, shards: &[u32]) {
        self.u32(shards.len() as u32);
        for &s in shards {
            self.u32(s);
        }
    }
    fn records(&mut self, records: &[Vec<u8>]) {
        self.u32(records.len() as u32);
        for r in records {
            self.bytes(r);
        }
    }
    fn samples(&mut self, samples: &[Sample]) {
        self.u32(samples.len() as u32);
        for s in samples {
            self.u64(s.stream_id);
            self.u64(s.seq);
            self.u32(s.values.len() as u32);
            for &v in &s.values {
                self.f64(v);
            }
        }
    }
}

// ---- payload reader (bounds-checked) -----------------------------------

struct R<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> R<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            err("length overflow")
        })?;
        if end > self.buf.len() {
            return Err(err(format!(
                "payload truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// A count prefix for elements of at least `elem_size` bytes each:
    /// bounded by what the payload could physically hold, so a corrupt
    /// count cannot drive a huge allocation.
    fn count(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let remaining = self.buf.len() - self.pos;
        if n.saturating_mul(elem_size.max(1)) > remaining {
            return Err(err(format!(
                "count {n} x {elem_size}B exceeds remaining {remaining}B"
            )));
        }
        Ok(n)
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count(1)?;
        Ok(self.take(n)?.to_vec())
    }
    fn shards(&mut self) -> Result<Vec<u32>> {
        let n = self.count(4)?;
        (0..n).map(|_| self.u32()).collect()
    }
    fn records(&mut self) -> Result<Vec<Vec<u8>>> {
        let n = self.count(4)?;
        (0..n).map(|_| self.bytes()).collect()
    }
    fn samples(&mut self) -> Result<Vec<Sample>> {
        let n = self.count(20)?;
        (0..n)
            .map(|_| {
                let stream_id = self.u64()?;
                let seq = self.u64()?;
                let k = self.count(8)?;
                let values =
                    (0..k).map(|_| self.f64()).collect::<Result<Vec<_>>>()?;
                Ok(Sample { stream_id, seq, values })
            })
            .collect()
    }
    fn string(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?)
            .map_err(|_| err("string not UTF-8"))
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(err(format!(
                "{} trailing bytes after payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---- encode / decode ---------------------------------------------------

/// Encode one message into a complete frame (header + payload).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut w = W(Vec::new());
    match msg {
        Msg::Hello { node_id, epoch }
        | Msg::HelloOk { node_id, epoch } => {
            w.u64(*node_id);
            w.u64(*epoch);
        }
        Msg::Heartbeat { node_id, epoch, load } => {
            w.u64(*node_id);
            w.u64(*epoch);
            w.u64(*load);
        }
        Msg::Expect { shards } | Msg::Seal { shards } => w.shards(shards),
        Msg::Adopt { shards, records } => {
            w.shards(shards);
            w.records(records);
        }
        Msg::Replay { samples } | Msg::Samples { samples } => {
            w.samples(samples)
        }
        Msg::Table { epoch, owner } => {
            w.u64(*epoch);
            w.u32(owner.len() as u32);
            for &o in owner {
                w.u64(o);
            }
        }
        Msg::Settle | Msg::Status | Msg::Ok => {}
        Msg::Join { node_id, addr } => {
            w.u64(*node_id);
            w.bytes(addr.as_bytes());
        }
        Msg::Leave { node_id } => w.u64(*node_id),
        Msg::Denied { reason } => w.bytes(reason.as_bytes()),
        Msg::Bundle { records } => w.records(records),
        Msg::StatusText { text } => w.bytes(text.as_bytes()),
        Msg::JoinOk { epoch, owner, peers } => {
            w.u64(*epoch);
            w.u32(owner.len() as u32);
            for &o in owner {
                w.u64(o);
            }
            w.u32(peers.len() as u32);
            for (id, addr) in peers {
                w.u64(*id);
                w.bytes(addr.as_bytes());
            }
        }
    }
    let payload = w.0;
    debug_assert!(payload.len() <= MAX_PAYLOAD);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(msg.type_id());
    out.push(0); // flags
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    let check = crc32(&payload) ^ crc32(&out[4..12]);
    out.extend_from_slice(&check.to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Validate a frame header. Returns (type_id, payload_len, crc) where
/// `crc` is the expected `crc32(payload)` — the header half of the
/// frame check is already folded out of the stored field here, so a
/// corrupted type/flags/length byte surfaces as a CRC mismatch.
fn check_header(header: &[u8; HEADER_LEN]) -> Result<(u8, usize, u32)> {
    if header[..4] != MAGIC {
        return Err(err("bad magic (not a TEDW frame)"));
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != VERSION {
        return Err(err(format!(
            "unsupported version {version} (expected {VERSION})"
        )));
    }
    let type_id = header[6];
    let len =
        u32::from_le_bytes([header[8], header[9], header[10], header[11]])
            as usize;
    if len > MAX_PAYLOAD {
        return Err(err(format!(
            "payload length {len} exceeds cap {MAX_PAYLOAD}"
        )));
    }
    let stored = u32::from_le_bytes([
        header[12], header[13], header[14], header[15],
    ]);
    Ok((type_id, len, stored ^ crc32(&header[4..12])))
}

fn decode_payload(type_id: u8, payload: &[u8]) -> Result<Msg> {
    let mut r = R { buf: payload, pos: 0 };
    let msg = match type_id {
        1 => Msg::Hello { node_id: r.u64()?, epoch: r.u64()? },
        2 => Msg::Heartbeat {
            node_id: r.u64()?,
            epoch: r.u64()?,
            load: r.u64()?,
        },
        3 => Msg::Expect { shards: r.shards()? },
        4 => Msg::Seal { shards: r.shards()? },
        5 => Msg::Adopt { shards: r.shards()?, records: r.records()? },
        6 => Msg::Replay { samples: r.samples()? },
        7 => Msg::Samples { samples: r.samples()? },
        8 => {
            let epoch = r.u64()?;
            let n = r.count(8)?;
            let owner =
                (0..n).map(|_| r.u64()).collect::<Result<Vec<_>>>()?;
            Msg::Table { epoch, owner }
        }
        9 => Msg::Settle,
        10 => Msg::Status,
        11 => Msg::Join { node_id: r.u64()?, addr: r.string()? },
        12 => Msg::Leave { node_id: r.u64()? },
        0x40 => Msg::Ok,
        0x41 => Msg::Denied { reason: r.string()? },
        0x42 => Msg::Bundle { records: r.records()? },
        0x43 => Msg::HelloOk { node_id: r.u64()?, epoch: r.u64()? },
        0x44 => Msg::StatusText { text: r.string()? },
        0x45 => {
            let epoch = r.u64()?;
            let n = r.count(8)?;
            let owner =
                (0..n).map(|_| r.u64()).collect::<Result<Vec<_>>>()?;
            // Each roster entry is at least an id (8B) + an address
            // length prefix (4B).
            let np = r.count(12)?;
            let peers = (0..np)
                .map(|_| Ok((r.u64()?, r.string()?)))
                .collect::<Result<Vec<_>>>()?;
            Msg::JoinOk { epoch, owner, peers }
        }
        other => return Err(err(format!("unknown message type {other}"))),
    };
    r.done()?;
    Ok(msg)
}

/// Decode one complete frame from a byte slice (must be exact).
pub fn decode(frame: &[u8]) -> Result<Msg> {
    if frame.len() < HEADER_LEN {
        return Err(err(format!(
            "frame too short: {} bytes, header needs {HEADER_LEN}",
            frame.len()
        )));
    }
    let header: [u8; HEADER_LEN] = frame[..HEADER_LEN].try_into().unwrap();
    let (type_id, len, crc) = check_header(&header)?;
    let payload = &frame[HEADER_LEN..];
    if payload.len() != len {
        return Err(err(format!(
            "payload length mismatch: header says {len}, have {}",
            payload.len()
        )));
    }
    if crc32(payload) != crc {
        return Err(err("payload CRC mismatch"));
    }
    decode_payload(type_id, payload)
}

/// Write one framed message to a stream.
pub fn write_msg<Wr: Write>(w: &mut Wr, msg: &Msg) -> Result<()> {
    let frame = encode(msg);
    w.write_all(&frame)
        .and_then(|_| w.flush())
        .map_err(|e| Error::io(format!("send {}", msg.label()), e))
}

/// Read one framed message from a stream. An EOF *before any header
/// byte* is a clean disconnect (`Ok(None)`); an EOF mid-frame is an
/// error (the peer died mid-send).
pub fn read_msg<Rd: Read>(r: &mut Rd) -> Result<Option<Msg>> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(err(format!(
                    "disconnected mid-header ({got}/{HEADER_LEN} bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::io("read frame header", e)),
        }
    }
    let (type_id, len, crc) = check_header(&header)?;
    let mut payload = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match r.read(&mut payload[got..]) {
            Ok(0) => {
                return Err(err(format!(
                    "disconnected mid-payload ({got}/{len} bytes)"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::io("read frame payload", e)),
        }
    }
    if crc32(&payload) != crc {
        return Err(err("payload CRC mismatch"));
    }
    decode_payload(type_id, &payload).map(Some)
}

/// [`read_msg`] for server-side connection handlers: the stream must
/// have a read timeout set; every timeout tick re-checks `stop` so a
/// handler thread parked on an idle connection still joins promptly at
/// shutdown. Returns `Ok(None)` on clean disconnect OR stop.
pub fn read_msg_cancellable<Rd: Read>(
    r: &mut Rd,
    stop: &AtomicBool,
) -> Result<Option<Msg>> {
    let mut buf: Vec<u8> = Vec::with_capacity(HEADER_LEN);
    let mut need = HEADER_LEN;
    let mut header: Option<(u8, usize, u32)> = None;
    let mut chunk = [0u8; 64 << 10];
    loop {
        // Completeness checks come *before* the next read, so a
        // zero-payload frame never triggers a zero-byte read (which
        // would be indistinguishable from a disconnect).
        if header.is_none() && buf.len() >= HEADER_LEN {
            let h: [u8; HEADER_LEN] =
                buf[..HEADER_LEN].try_into().unwrap();
            let parsed = check_header(&h)?;
            buf.drain(..HEADER_LEN);
            need = parsed.1;
            header = Some(parsed);
        }
        if let Some((type_id, len, crc)) = header {
            if buf.len() >= len {
                if crc32(&buf[..len]) != crc {
                    return Err(err("payload CRC mismatch"));
                }
                return decode_payload(type_id, &buf[..len]).map(Some);
            }
        }
        if stop.load(Ordering::Acquire) {
            return Ok(None);
        }
        let want = (need - buf.len()).min(chunk.len());
        match r.read(&mut chunk[..want]) {
            Ok(0) if buf.is_empty() && header.is_none() => return Ok(None),
            Ok(0) => {
                return Err(err(format!(
                    "disconnected mid-frame ({}/{need} bytes)",
                    buf.len()
                )))
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::io("read frame", e)),
        }
    }
}

/// Round-trip helper: send a request, read one reply (blocking, with
/// whatever timeout the stream carries converted into an error).
pub fn roundtrip<S: Read + Write>(s: &mut S, msg: &Msg) -> Result<Msg> {
    write_msg(s, msg)?;
    match read_msg(s)? {
        Some(reply) => Ok(reply),
        None => Err(err(format!(
            "peer disconnected awaiting reply to {}",
            msg.label()
        ))),
    }
}

/// Suggested per-connection read timeout: long enough for a seal of a
/// full node to complete, short enough that stop-flag checks stay
/// responsive in [`read_msg_cancellable`].
pub const READ_TIMEOUT: Duration = Duration::from_millis(50);

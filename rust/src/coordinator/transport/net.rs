//! Dependency-free TCP/UDS plumbing for the cluster transport: peer
//! addresses, framed connections, a non-blocking listener (the
//! `obs/server.rs` idiom), and a lazy reconnecting RPC client with a
//! [`Transport`] implementation on top.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::frame::{self, Msg};
use super::{StraySample, Transport};
use crate::metrics::ServiceMetrics;
use crate::{Error, Result};

/// How long a connect may take before the peer counts as unreachable.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// Client-side reply timeout: generous because a Seal reply waits for
/// the remote to drain its whole backlog first.
pub const RPC_TIMEOUT: Duration = Duration::from_secs(30);

/// A peer endpoint: `host:port` TCP, or `unix:/path` on Unix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PeerAddr {
    Tcp(String),
    #[cfg(unix)]
    Unix(std::path::PathBuf),
}

impl PeerAddr {
    /// Parse `"host:port"` or `"unix:/path/to.sock"`.
    pub fn parse(s: &str) -> Result<PeerAddr> {
        let s = s.trim();
        if let Some(path) = s.strip_prefix("unix:") {
            #[cfg(unix)]
            return Ok(PeerAddr::Unix(path.into()));
            #[cfg(not(unix))]
            return Err(Error::Config(format!(
                "unix socket address {path:?} unsupported on this platform"
            )));
        }
        if s.is_empty() || !s.contains(':') {
            return Err(Error::Config(format!(
                "bad peer address {s:?} (want host:port or unix:/path)"
            )));
        }
        Ok(PeerAddr::Tcp(s.to_string()))
    }
}

impl std::fmt::Display for PeerAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PeerAddr::Tcp(a) => write!(f, "{a}"),
            #[cfg(unix)]
            PeerAddr::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// One framed stream, TCP or UDS.
pub enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    /// Connect with [`CONNECT_TIMEOUT`] and set [`RPC_TIMEOUT`] reads.
    pub fn connect(addr: &PeerAddr) -> Result<Conn> {
        let conn = match addr {
            PeerAddr::Tcp(a) => {
                let mut last: Option<std::io::Error> = None;
                let addrs = a.to_socket_addrs().map_err(|e| {
                    Error::io(format!("resolve {a}"), e)
                })?;
                let mut stream = None;
                for sa in addrs {
                    match TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT) {
                        Ok(s) => {
                            stream = Some(s);
                            break;
                        }
                        Err(e) => last = Some(e),
                    }
                }
                let stream = stream.ok_or_else(|| {
                    Error::io(
                        format!("connect {a}"),
                        last.unwrap_or_else(|| {
                            std::io::Error::new(
                                std::io::ErrorKind::AddrNotAvailable,
                                "no addresses resolved",
                            )
                        }),
                    )
                })?;
                let _ = stream.set_nodelay(true);
                Conn::Tcp(stream)
            }
            #[cfg(unix)]
            PeerAddr::Unix(p) => Conn::Unix(
                UnixStream::connect(p).map_err(|e| {
                    Error::io(format!("connect unix:{}", p.display()), e)
                })?,
            ),
        };
        conn.set_read_timeout(Some(RPC_TIMEOUT))?;
        Ok(conn)
    }

    pub fn set_read_timeout(&self, t: Option<Duration>) -> Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(t),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(t),
        }
        .map_err(|e| Error::io("set read timeout", e))
    }

    /// Peer description for logs.
    pub fn peer_desc(&self) -> String {
        match self {
            Conn::Tcp(s) => s
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "tcp:?".into()),
            #[cfg(unix)]
            Conn::Unix(_) => "unix".into(),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// A non-blocking accept socket (the `obs::server` idiom: the owner
/// polls `try_accept` in a loop with a stop flag and a short nap).
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    pub fn bind(addr: &PeerAddr) -> Result<Listener> {
        match addr {
            PeerAddr::Tcp(a) => {
                let l = TcpListener::bind(a)
                    .map_err(|e| Error::io(format!("bind {a}"), e))?;
                l.set_nonblocking(true)
                    .map_err(|e| Error::io("set_nonblocking", e))?;
                Ok(Listener::Tcp(l))
            }
            #[cfg(unix)]
            PeerAddr::Unix(p) => {
                // A dead previous instance leaves the socket file
                // behind; binding over it is the expected restart path.
                let _ = std::fs::remove_file(p);
                let l = UnixListener::bind(p).map_err(|e| {
                    Error::io(format!("bind unix:{}", p.display()), e)
                })?;
                l.set_nonblocking(true)
                    .map_err(|e| Error::io("set_nonblocking", e))?;
                Ok(Listener::Unix(l))
            }
        }
    }

    /// Accept one pending connection, if any. Accepted connections are
    /// switched to blocking mode with the short cancellable read
    /// timeout ([`frame::READ_TIMEOUT`]) for handler loops.
    pub fn try_accept(&self) -> Result<Option<Conn>> {
        let accepted = match self {
            Listener::Tcp(l) => match l.accept() {
                Ok((s, _)) => {
                    let _ = s.set_nodelay(true);
                    Some(Conn::Tcp(s))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                Err(e) => return Err(Error::io("accept", e)),
            },
            #[cfg(unix)]
            Listener::Unix(l) => match l.accept() {
                Ok((s, _)) => Some(Conn::Unix(s)),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => None,
                Err(e) => return Err(Error::io("accept", e)),
            },
        };
        if let Some(conn) = accepted {
            match &conn {
                Conn::Tcp(s) => {
                    s.set_nonblocking(false)
                        .map_err(|e| Error::io("set blocking", e))?;
                }
                #[cfg(unix)]
                Conn::Unix(s) => {
                    s.set_nonblocking(false)
                        .map_err(|e| Error::io("set blocking", e))?;
                }
            }
            conn.set_read_timeout(Some(frame::READ_TIMEOUT))?;
            return Ok(Some(conn));
        }
        Ok(None)
    }

    /// The actual bound address (resolves `:0` test binds), in the
    /// same `host:port` / `unix:/path` form [`PeerAddr::parse`]
    /// accepts, so a node can advertise it to joiners verbatim.
    pub fn bound_addr(&self) -> String {
        match self {
            Listener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "tcp:?".into()),
            #[cfg(unix)]
            Listener::Unix(l) => l
                .local_addr()
                .ok()
                .and_then(|a| {
                    a.as_pathname()
                        .map(|p| format!("unix:{}", p.display()))
                })
                .unwrap_or_else(|| "unix:?".into()),
        }
    }
}

/// Serialized request/reply client over one lazily-(re)connected
/// framed stream. Connection state is a cache: any I/O failure drops
/// it, and (for idempotent requests) one transparent reconnect+retry
/// covers the common "peer restarted / idle conn reaped" case.
pub struct RpcClient {
    addr: PeerAddr,
    conn: Mutex<Option<Conn>>,
}

impl RpcClient {
    pub fn new(addr: PeerAddr) -> Self {
        RpcClient { addr, conn: Mutex::new(None) }
    }

    pub fn addr(&self) -> &PeerAddr {
        &self.addr
    }

    /// Is a connection currently cached? (Does not probe the peer.)
    pub fn is_connected(&self) -> bool {
        self.conn.lock().unwrap().is_some()
    }

    /// Drop the cached connection (the peer is known dead).
    pub fn disconnect(&self) {
        *self.conn.lock().unwrap() = None;
    }

    fn attempt(&self, msg: &Msg) -> Result<Msg> {
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(Conn::connect(&self.addr)?);
        }
        let conn = guard.as_mut().unwrap();
        match frame::roundtrip(conn, msg) {
            Ok(reply) => Ok(reply),
            Err(e) => {
                // Any failure poisons the stream (a half-read frame
                // would desync every later reply): drop it.
                *guard = None;
                Err(e)
            }
        }
    }

    /// Send one request, return the reply. Retries ONCE on a cached-
    /// connection failure — safe only for idempotent requests (Expect,
    /// Adopt, Replay, Samples, Hello, Heartbeat, Table, Status, Join,
    /// Leave: all are absorbed by the restore/dedup/roster-install
    /// machinery if duplicated).
    pub fn rpc(&self, msg: &Msg) -> Result<Msg> {
        let had_conn = self.is_connected();
        match self.attempt(msg) {
            Ok(reply) => Ok(reply),
            Err(first) => {
                if had_conn {
                    // The cached stream may simply have gone stale;
                    // one fresh-connection retry.
                    self.attempt(msg).map_err(|_| first)
                } else {
                    Err(first)
                }
            }
        }
    }

    /// Send one request with NO retry. Required for Seal: a Seal that
    /// executed but lost its reply has already disowned the shards —
    /// retrying would return an empty bundle and silently drop the
    /// sealed state.
    pub fn rpc_no_retry(&self, msg: &Msg) -> Result<Msg> {
        self.attempt(msg)
    }
}

fn expect_ok(reply: Msg, what: &str, peer: &PeerAddr) -> Result<()> {
    match reply {
        Msg::Ok => Ok(()),
        Msg::Denied { reason } => Err(Error::Stream(format!(
            "peer {peer} denied {what}: {reason}"
        ))),
        other => Err(Error::Stream(format!(
            "peer {peer}: unexpected {} reply to {what}",
            other.label()
        ))),
    }
}

/// The cross-process [`Transport`] endpoint: a peer node reached
/// through an [`RpcClient`]. Sealed bundles and strays cross the wire
/// framed by [`frame`]; byte counters land in the service metrics when
/// provided.
pub struct RemoteLink {
    client: Arc<RpcClient>,
    metrics: Option<Arc<ServiceMetrics>>,
}

impl RemoteLink {
    pub fn new(client: Arc<RpcClient>) -> Self {
        RemoteLink { client, metrics: None }
    }

    pub fn with_metrics(mut self, metrics: Arc<ServiceMetrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }
}

impl Transport for RemoteLink {
    fn kind(&self) -> String {
        format!("peer {}", self.client.addr())
    }

    fn expect(&self, shards: &[u32]) -> Result<()> {
        let reply =
            self.client.rpc(&Msg::Expect { shards: shards.to_vec() })?;
        expect_ok(reply, "expect", self.client.addr())
    }

    fn seal(&self, shards: &[u32]) -> Result<Vec<Vec<u8>>> {
        // No retry: see RpcClient::rpc_no_retry.
        let reply = self
            .client
            .rpc_no_retry(&Msg::Seal { shards: shards.to_vec() })?;
        match reply {
            Msg::Bundle { records } => {
                if let Some(m) = &self.metrics {
                    let bytes: u64 =
                        records.iter().map(|r| r.len() as u64).sum();
                    m.bundle_bytes_rx.add(bytes);
                }
                Ok(records)
            }
            Msg::Denied { reason } => Err(Error::Stream(format!(
                "peer {} denied seal: {reason}",
                self.client.addr()
            ))),
            other => Err(Error::Stream(format!(
                "peer {}: unexpected {} reply to seal",
                self.client.addr(),
                other.label()
            ))),
        }
    }

    fn barrier(&self) -> Result<()> {
        // An empty Seal is a pure rendezvous on the remote too: the
        // node barriers every local worker before replying.
        let reply =
            self.client.rpc_no_retry(&Msg::Seal { shards: Vec::new() })?;
        match reply {
            Msg::Bundle { .. } | Msg::Ok => Ok(()),
            Msg::Denied { reason } => Err(Error::Stream(format!(
                "peer {} denied barrier: {reason}",
                self.client.addr()
            ))),
            other => Err(Error::Stream(format!(
                "peer {}: unexpected {} reply to barrier",
                self.client.addr(),
                other.label()
            ))),
        }
    }

    fn adopt(&self, shards: &[u32], records: Vec<Vec<u8>>) -> Result<()> {
        if let Some(m) = &self.metrics {
            let bytes: u64 = records.iter().map(|r| r.len() as u64).sum();
            m.bundle_bytes_tx.add(bytes);
        }
        let reply = self
            .client
            .rpc(&Msg::Adopt { shards: shards.to_vec(), records })?;
        expect_ok(reply, "adopt", self.client.addr())
    }

    fn replay(
        &self,
        strays: Vec<StraySample>,
    ) -> std::result::Result<usize, Vec<StraySample>> {
        // Submit times cannot cross the process boundary (Instants are
        // process-local); the receiver re-stamps on arrival, so
        // cross-node re-routes measure their remaining latency only.
        let samples: Vec<_> =
            strays.iter().map(|(s, _)| s.clone()).collect();
        let n = samples.len();
        match self.client.rpc(&Msg::Replay { samples }) {
            Ok(Msg::Ok) => Ok(n),
            _ => Err(strays),
        }
    }

    fn retire(&self) -> Result<()> {
        // Nodes are not retired through the migration transport; the
        // control plane kills them whole. Nothing to send.
        Ok(())
    }
}

//! The node core: worker threads + versioned shard map + result
//! collection, with live shard migration and runtime worker scaling.
//!
//! Post-split (ISSUE 8) this file is the *single-node* service only:
//! the worker loop lives in [`crate::coordinator::worker`], migration
//! and control traffic flow through the
//! [`crate::coordinator::transport::Transport`] trait (the in-process
//! [`WorkerLink`] here; a framed TCP/UDS link cross-process), and
//! multi-node membership/failover lives in
//! [`crate::coordinator::cluster`]. The node-level entry points the
//! cluster layer drives — [`Service::expect_shards`],
//! [`Service::seal_shards`], [`Service::adopt_shards`],
//! [`Service::replay_strays`], [`Service::reroute_strays`] — are thin
//! per-worker fan-outs of the same protocol messages.

use std::collections::{BTreeMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::{EngineKind, ServiceConfig};
use crate::coordinator::ring::{thread_token, PushOutcome};
use crate::coordinator::senders::{SenderRegistry, WorkerSlot};
use crate::coordinator::transport::{
    migrate_over, StraySample, Transport, WorkerLink,
};
use crate::coordinator::worker::{spawn_worker, Job, Stray, WorkerHandle};
use crate::coordinator::{ShardMap, ShardTable, StateManager};
use crate::metrics::{EnsembleMetrics, ServiceMetrics, ShardMetrics};
use crate::obs::recorder::{record, EventKind, NO_WORKER};
use crate::obs::window::{MetricsWindow, ShardWindow};
use crate::persist::{codec, CheckpointStore, FileStore};
use crate::stream::{Receiver, Sample, Sender};
use crate::{Error, Result};

pub use crate::coordinator::worker::Classified;

/// Escalation hook for strays whose shard left this *node*: the
/// cluster layer installs a closure that ships them to the owning peer
/// (a Replay frame on the owner's control connection). Returns how
/// many were delivered, or hands the strays back to be parked and
/// retried.
pub type StrayForwarder = Arc<
    dyn Fn(Vec<StraySample>) -> std::result::Result<usize, Vec<StraySample>>
        + Send
        + Sync,
>;

/// Hard cap on the parked-stray list. Parked strays exist to survive
/// transient re-route failures; against a *permanently* undeliverable
/// destination the list would otherwise grow without bound. 64k
/// strays is minutes of worst-case stray traffic — far beyond any
/// transient — so overflow means the destination is gone for good.
const PARKED_CAP: usize = 64 * 1024;

/// A running service instance.
pub struct Service {
    cfg: ServiceConfig,
    /// Versioned stream → shard → worker routing, shared with every
    /// submit handle; migrations install successor tables (epoch + 1).
    shard_map: Arc<ShardMap>,
    /// Worker ingress slots (SPSC data ring + control channel per
    /// worker), index-aligned with the shard table and published
    /// lock-free through the epoch-versioned registry. Shared (not
    /// cloned) with every [`ServiceHandle`] so scaling is visible to
    /// all submitters immediately.
    senders: Arc<SenderRegistry<Job>>,
    workers: Mutex<Vec<Option<WorkerHandle>>>,
    /// Verdicts travel in bursts (one Vec per processed job) to keep
    /// channel synchronization off the per-sample path.
    results_rx: Receiver<Vec<Classified>>,
    /// Kept so `scale_to` can hand the results channel to new workers;
    /// dropped at stop so the drain observes closure.
    res_tx: Sender<Vec<Classified>>,
    /// Mis-routed samples forwarded by workers for re-routing.
    stray_rx: Receiver<Stray>,
    stray_tx: Sender<Stray>,
    metrics: Arc<ServiceMetrics>,
    shard_metrics: Arc<ShardMetrics>,
    /// Per-member counters, present when the engine is an ensemble.
    ensemble_metrics: Option<Arc<EnsembleMetrics>>,
    state_mgr: Arc<StateManager>,
    /// Strays that could not be re-routed (their worker's queue was
    /// closed mid-drain); retried on every subsequent drain. Bounded
    /// by [`PARKED_CAP`] — a permanently dead destination must not
    /// grow this without bound (overflow is counted in
    /// `stray_park_drops`, never silent).
    parked: Mutex<Vec<Stray>>,
    /// Serializes migrate / scale / rebalance operations.
    rebalance_lock: Mutex<()>,
    /// Per-shard windowed activity (sample deltas + windowed p99) since
    /// the last `maybe_rebalance` check — the rebalancer acts on recent
    /// load, not lifetime totals.
    shard_window: Mutex<ShardWindow>,
    /// Shards owned by a *peer node*, not this process. Local workers
    /// never own them; strays routed to them are escalated through
    /// `forwarder` instead of re-delivered locally (re-delivery would
    /// ping-pong forever: the local table still maps every virtual
    /// shard to some local worker).
    foreign: Mutex<HashSet<u32>>,
    /// Cluster-installed stray escalation (None when single-node).
    forwarder: Mutex<Option<StrayForwarder>>,
}

/// Cheap clonable submit-side handle. Shares the live shard map and
/// sender registry, so routing follows migrations and worker scaling.
pub struct ServiceHandle {
    shard_map: Arc<ShardMap>,
    senders: Arc<SenderRegistry<Job>>,
    metrics: Arc<ServiceMetrics>,
}

impl Clone for ServiceHandle {
    fn clone(&self) -> Self {
        ServiceHandle {
            shard_map: self.shard_map.clone(),
            senders: self.senders.clone(),
            metrics: self.metrics.clone(),
        }
    }
}

impl ServiceHandle {
    /// Submit one sample (blocks under backpressure).
    pub fn submit(&self, sample: Sample) -> Result<()> {
        submit_inner(
            &self.shard_map,
            &self.senders,
            &self.metrics,
            sample,
            Instant::now(),
            true,
        )
    }

    /// Submit a burst of samples through the shared batched core: one
    /// ring/channel operation per routed worker per burst (identical
    /// semantics to [`Service::submit_batch`]).
    pub fn submit_batch(&self, samples: Vec<Sample>) -> Result<()> {
        submit_batch_inner(
            &self.shard_map,
            &self.senders,
            &self.metrics,
            samples,
        )
    }
}

/// Zero-mutex data-plane enqueue (the steady-state hot path): SPSC
/// ring publish when this thread holds the worker's ring claim —
/// claiming on first contact — and the bounded control channel
/// otherwise. A full ring is the counted backpressure path: stay on
/// the ring (switching queues mid-stream would reorder) and spin-yield
/// until a slot frees or the ring closes. Hands the job back on
/// closure so the caller can retry under a fresh route instead of
/// losing samples.
fn enqueue_data(
    slot: &WorkerSlot<Job>,
    metrics: &ServiceMetrics,
    w: usize,
    job: Job,
) -> std::result::Result<(), Job> {
    // Flight-recorder discipline (the hot-path contract, see
    // `crate::obs`): the per-sample fast path records NOTHING; the
    // batched path records one event per worker burst; anomalies
    // (ring-full stalls) record unconditionally.
    let (trace, n) = match &job {
        Job::Batch(batch, _) => (true, batch.len() as u64),
        _ => (false, 1),
    };
    let job = match slot.try_push(thread_token(), job) {
        PushOutcome::Pushed => {
            if trace {
                record(EventKind::RingPush, n, 0, w as u32);
            }
            return Ok(());
        }
        PushOutcome::Full(job) => {
            metrics.ring_full_events.inc();
            metrics.backpressure_events.inc();
            record(EventKind::RingFull, n, 0, w as u32);
            let mut job = job;
            loop {
                // The consumer cannot be parked while its ring is
                // full, but ring the doorbell anyway: it is one load
                // and closes the tiny pre-park race window for free.
                slot.notify();
                std::thread::yield_now();
                match slot.try_push(thread_token(), job) {
                    PushOutcome::Pushed => {
                        if trace {
                            record(EventKind::RingPush, n, 0, w as u32);
                        }
                        return Ok(());
                    }
                    PushOutcome::Full(back) => job = back,
                    PushOutcome::Closed(back)
                    | PushOutcome::NoClaim(back) => break back,
                }
            }
        }
        PushOutcome::Closed(job) | PushOutcome::NoClaim(job) => job,
    };
    // Control-channel plane: producers without the ring claim, and the
    // closed-ring fallback. Blocking when full (counted), value back
    // on closure.
    if slot.ctl_is_full() {
        metrics.backpressure_events.inc();
    }
    let sent = slot.send_ctl_reclaim(job);
    if sent.is_ok() && trace {
        record(EventKind::CtlPush, n, 0, w as u32);
    }
    sent
}

/// Shared single-sample submit path: route via the current shard table
/// (one atomic load), enqueue via [`enqueue_data`]. When the routed
/// worker's queues are closed the route is retried under a fresh
/// table — a resize in flight — and only reported as an error when a
/// repeat attempt under an unchanged epoch fails again (a genuinely
/// dead worker).
fn submit_inner(
    shard_map: &ShardMap,
    senders: &SenderRegistry<Job>,
    metrics: &ServiceMetrics,
    sample: Sample,
    t0: Instant,
    count_in: bool,
) -> Result<()> {
    let mut sample = sample;
    let mut failed_at: Option<u64> = None;
    loop {
        let table = shard_map.load();
        let slots = senders.load();
        if slots.is_empty() {
            return Err(Error::Stream("service stopped".into()));
        }
        if slots.epoch() != table.epoch() {
            // The install window between a shard-table swap and its
            // sender-table restamp. Proceeding is safe (worst case a
            // stray, which re-routing handles); count the miss.
            metrics.route_epoch_misses.inc();
        }
        let epoch = table.epoch();
        let (w, shard) = table.route(sample.stream_id);
        let enq = match slots.get(w) {
            Some(slot) => {
                enqueue_data(slot, metrics, w, Job::Sample(sample, t0))
            }
            // The table routed to a worker the registry no longer
            // has: a shrink landed between the two loads. Retry.
            None => Err(Job::Sample(sample, t0)),
        };
        match enq {
            Ok(()) => {
                if count_in {
                    metrics.samples_in.inc();
                }
                return Ok(());
            }
            Err(Job::Sample(back, _)) => {
                if failed_at == Some(epoch)
                    && epoch == shard_map.load().epoch()
                {
                    return Err(Error::Stream("worker queue closed".into()));
                }
                // Off the fast path already (a resize in flight):
                // journal the retried route for the postmortem trail.
                record(EventKind::Route, back.stream_id, shard, w as u32);
                failed_at = Some(epoch);
                sample = back;
                std::thread::yield_now();
            }
            Err(_) => unreachable!("submit_inner only enqueues Sample"),
        }
    }
}

/// The shared batched submit core (ISSUE 6 tentpole, part 4): group a
/// burst by routed worker under ONE routing snapshot, then perform one
/// ring/channel operation per worker — routing and wakeup costs
/// amortize across the burst. Falls back to per-sample submission
/// (which retries under fresh routes) for any group whose worker
/// closed underneath us.
fn submit_batch_inner(
    shard_map: &ShardMap,
    senders: &SenderRegistry<Job>,
    metrics: &ServiceMetrics,
    samples: Vec<Sample>,
) -> Result<()> {
    if samples.is_empty() {
        return Ok(());
    }
    let now = Instant::now();
    let table = shard_map.load();
    let slots = senders.load();
    if slots.is_empty() {
        return Err(Error::Stream("service stopped".into()));
    }
    if slots.epoch() != table.epoch() {
        metrics.route_epoch_misses.inc();
    }
    let mut per_worker: Vec<Vec<Sample>> =
        (0..table.workers()).map(|_| Vec::new()).collect();
    for s in samples {
        per_worker[table.route(s.stream_id).0].push(s);
    }
    for (w, batch) in per_worker.into_iter().enumerate() {
        if batch.is_empty() {
            continue;
        }
        // Count per delivered batch, not once at the end: a mid-loop
        // failure (dead worker) must not leave already-delivered
        // samples uncounted (verdicts_out would exceed samples_in
        // exactly when the counters matter most).
        let delivered = batch.len() as u64;
        metrics.batch_sizes.record(delivered);
        record(EventKind::Submit, delivered, 0, w as u32);
        let enq = match slots.get(w) {
            Some(slot) => {
                enqueue_data(slot, metrics, w, Job::Batch(batch, now))
            }
            None => Err(Job::Batch(batch, now)),
        };
        match enq {
            Ok(()) => metrics.samples_in.add(delivered),
            Err(Job::Batch(batch, t0)) => {
                // Routed against a table that resized under us: fall
                // back to per-sample routing with fresh snapshots
                // (each sample counts itself in).
                for s in batch {
                    submit_inner(shard_map, senders, metrics, s, t0, true)?;
                }
            }
            Err(_) => unreachable!("batch core only enqueues Batch"),
        }
    }
    Ok(())
}

impl Service {
    /// Start workers per the config, with a fresh checkpoint store.
    /// When `checkpoint.dir` is configured, a durable [`FileStore`] is
    /// opened there and every published checkpoint is written through
    /// (but nothing is loaded back — cold starts are fresh; use
    /// [`Service::start_from_store`] to recover).
    ///
    /// Directory lifecycle is the operator's: a fresh start against a
    /// directory holding an older run's checkpoints appends to that
    /// history, and a later recovery picks the highest watermark per
    /// stream across both. That is correct when stream sequence
    /// numbers are globally consistent (the system's contract); to
    /// deliberately abandon a history, point at a new directory or
    /// clear the old one first.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let state_mgr = match &cfg.checkpoint_dir {
            Some(dir) => {
                let store = FileStore::open(dir, cfg.checkpoint_keep)?;
                Arc::new(StateManager::with_store(Arc::new(store)))
            }
            None => Arc::new(StateManager::new()),
        };
        Self::start_with_state(cfg, state_mgr)
    }

    /// Cold-start from a durable checkpoint store — the full-process-
    /// death recovery path: the newest *valid* checkpoint of every
    /// stream in the store is loaded (corrupt/truncated tails are
    /// skipped in favour of earlier records), then workers start
    /// against the recovered [`StateManager`] with write-through to
    /// the same store. Enable `checkpoint.restore` so resuming streams
    /// actually adopt the recovered snapshots.
    pub fn start_from_store(
        cfg: ServiceConfig,
        store: Arc<dyn CheckpointStore>,
    ) -> Result<Service> {
        let state_mgr = Arc::new(StateManager::with_store(store));
        state_mgr.recover()?;
        Self::start_with_state(cfg, state_mgr)
    }

    /// Start workers against an existing checkpoint store — the
    /// failover path: a resurrected service inherits the dead
    /// instance's [`StateManager`] and, with
    /// `checkpoint.restore = true`, restores each stream's latest
    /// snapshot the moment the stream resumes.
    pub fn start_with_state(
        cfg: ServiceConfig,
        state_mgr: Arc<StateManager>,
    ) -> Result<Service> {
        cfg.validate()?;
        let metrics = ServiceMetrics::new();
        let shard_metrics = ShardMetrics::new(cfg.sharding.virtual_shards);
        // Ensemble runs get one shared per-member counter bundle: every
        // worker shard's EnsembleEngine adds into the same atomics.
        let ensemble_metrics = (cfg.engine == EngineKind::Ensemble)
            .then(|| EnsembleMetrics::new(cfg.ensemble.labels()));
        let table =
            ShardTable::new_uniform(cfg.sharding.virtual_shards, cfg.workers);
        // Results flow on an unbounded channel: a worker must never
        // block on its own consumer (the submitter only drains results
        // after submission, so a bounded results path could deadlock the
        // whole pipeline: worker→results full→worker stalls→queues
        // fill→submit blocks). Strays are unbounded for the same
        // reason.
        let (res_tx, res_rx) = crate::stream::unbounded::<Vec<Classified>>();
        let (stray_tx, stray_rx) = crate::stream::unbounded::<Stray>();

        let mut slots = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for widx in 0..cfg.workers {
            let (slot, rx) = WorkerSlot::with_capacity(cfg.queue_capacity);
            slots.push(slot.clone());
            workers.push(Some(spawn_worker(
                widx,
                &cfg,
                table.shards_on(widx).into_iter().collect(),
                slot,
                rx,
                res_tx.clone(),
                stray_tx.clone(),
                metrics.clone(),
                shard_metrics.clone(),
                ensemble_metrics.clone(),
                state_mgr.clone(),
            )?));
        }
        metrics.epoch.set(table.epoch());
        metrics.workers_active.set(cfg.workers as u64);
        let epoch = table.epoch();
        let shard_window =
            ShardWindow::new(cfg.sharding.virtual_shards as usize);
        Ok(Service {
            cfg,
            shard_map: Arc::new(ShardMap::new(table)),
            senders: Arc::new(SenderRegistry::new(slots, epoch)),
            workers: Mutex::new(workers),
            results_rx: res_rx,
            res_tx,
            stray_rx,
            stray_tx,
            metrics,
            shard_metrics,
            ensemble_metrics,
            state_mgr,
            parked: Mutex::new(Vec::new()),
            rebalance_lock: Mutex::new(()),
            shard_window: Mutex::new(shard_window),
            foreign: Mutex::new(HashSet::new()),
            forwarder: Mutex::new(None),
        })
    }

    /// Service configuration.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Shared metrics.
    pub fn metrics(&self) -> Arc<ServiceMetrics> {
        self.metrics.clone()
    }

    /// Shared per-shard load stats.
    pub fn shard_metrics(&self) -> Arc<ShardMetrics> {
        self.shard_metrics.clone()
    }

    /// Shared per-member ensemble counters (ensemble engine only).
    pub fn ensemble_metrics(&self) -> Option<Arc<EnsembleMetrics>> {
        self.ensemble_metrics.clone()
    }

    /// A fresh rolling delta window over this service's metrics
    /// registry (baseline = now). Tick it periodically for
    /// rates-per-interval and windowed stage p99s — the signals the
    /// serve loop prints and autoscaling policies consume.
    pub fn metrics_window(&self) -> MetricsWindow {
        MetricsWindow::new(&self.metrics)
    }

    /// Racy per-worker data-ring occupancy (diagnostics: is
    /// backpressure building, and on which worker?).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.senders
            .load()
            .slots()
            .iter()
            .map(|s| s.queue_depth())
            .collect()
    }

    /// Shared state manager (checkpoints).
    pub fn state_manager(&self) -> Arc<StateManager> {
        self.state_mgr.clone()
    }

    /// The live shard map (diagnostics / external rebalancers).
    pub fn shard_map(&self) -> Arc<ShardMap> {
        self.shard_map.clone()
    }

    /// Consistent snapshot of the current shard → worker table.
    pub fn table(&self) -> Arc<ShardTable> {
        self.shard_map.snapshot()
    }

    /// Live worker count.
    pub fn workers(&self) -> usize {
        self.senders.load().len()
    }

    /// Submit one sample, blocking when the worker queue is full
    /// (backpressure; the block is counted in metrics).
    pub fn submit(&self, sample: Sample) -> Result<()> {
        submit_inner(
            &self.shard_map,
            &self.senders,
            &self.metrics,
            sample,
            Instant::now(),
            true,
        )
    }

    /// Submit a burst of samples: routed per stream, but enqueued as one
    /// job per worker — one ring/channel synchronization per burst per
    /// worker instead of one per sample (the L3 hot-path optimization;
    /// EXPERIMENTS.md §Perf).
    pub fn submit_batch(&self, samples: Vec<Sample>) -> Result<()> {
        submit_batch_inner(
            &self.shard_map,
            &self.senders,
            &self.metrics,
            samples,
        )
    }

    /// Clonable submit-side handle for multi-threaded sources.
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle {
            shard_map: self.shard_map.clone(),
            senders: self.senders.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Drain any verdicts already available without blocking (also
    /// re-routes any stray samples forwarded during migrations —
    /// unless a migration is running right now, in which case stray
    /// handling is left to the migration's own ordered drain: pulling
    /// a stray out from under the seal → drain → adopt sequence could
    /// re-deliver it after the Adopt and lose its verdict to the
    /// watermark guard).
    pub fn poll_results(&self) -> Vec<Classified> {
        if let Ok(_guard) = self.rebalance_lock.try_lock() {
            let _ = self.drain_strays();
        }
        let mut out = Vec::new();
        while let Ok(Some(burst)) = self.results_rx.try_recv() {
            out.extend(burst);
        }
        out
    }

    /// Re-route every stray sample currently queued (samples that
    /// reached a worker after it sealed their shard), plus any strays
    /// parked by an earlier failed drain. Returns how many were
    /// re-routed. Resubmitted strays cannot stray again: the current
    /// table routes them to the worker whose Adopt for the shard is
    /// already queued ahead of them. On a re-route failure (a dead
    /// worker's queue) the affected samples are parked — not lost —
    /// and retried on the next drain.
    ///
    /// MUST only run while `rebalance_lock` is held (all callers:
    /// migrate_set/scale_to under the lock, stop's quiesce takes it,
    /// poll_results try-locks it) — a concurrent drain could steal a
    /// stray from under a migration's ordered stray-before-Adopt
    /// sequence and re-deliver it too late.
    fn drain_strays(&self) -> Result<usize> {
        let mut pending: Vec<Stray> =
            std::mem::take(&mut *self.parked.lock().unwrap());
        // Strays resubmitted here were parked by an earlier failed
        // drain — count the re-attempts (satellite f).
        self.metrics.parked_retries.add(pending.len() as u64);
        while let Ok(Some(stray)) = self.stray_rx.try_recv() {
            pending.push(stray);
        }
        if pending.is_empty() {
            return Ok(0);
        }
        // Batched re-delivery: group by routed worker under one
        // routing snapshot and hand each worker ONE Job::Replay on its
        // control channel — Replay must ride the control plane to stay
        // FIFO with the migration control traffic (the Adopt already
        // queued ahead of it is what guarantees a resubmitted stray
        // cannot stray again). Original submit Instants travel with
        // each stray; samples_in was counted at the original submit.
        let table = self.shard_map.load();
        let slots = self.senders.load();
        // Node-level partition first: strays whose shard now lives on
        // a peer node leave through the cluster's forwarder (a Replay
        // frame to the owner) — local re-delivery would loop forever.
        let mut remote: Vec<Stray> = Vec::new();
        let mut per_worker: BTreeMap<usize, Vec<Stray>> = BTreeMap::new();
        {
            let foreign = self.foreign.lock().unwrap();
            for stray in pending {
                let (w, shard) = table.route(stray.0.stream_id);
                if foreign.contains(&shard) {
                    remote.push(stray);
                } else {
                    per_worker.entry(w).or_default().push(stray);
                }
            }
        }
        let mut n = 0;
        let mut failed: Vec<Stray> = Vec::new();
        if !remote.is_empty() {
            let fwd = self.forwarder.lock().unwrap().clone();
            match fwd {
                Some(forward) => match forward(remote) {
                    Ok(k) => n += k,
                    Err(back) => failed.extend(back),
                },
                // No cluster layer yet foreign shards marked: park
                // until the forwarder is installed (bootstrap window).
                None => failed.extend(remote),
            }
        }
        for (w, strays) in per_worker {
            let count = strays.len();
            let undelivered = match slots.get(w) {
                Some(slot) => match slot.send_ctl_reclaim(Job::Replay(strays)) {
                    Ok(()) => None,
                    Err(Job::Replay(back)) => Some(back),
                    Err(_) => unreachable!("reclaim returns what was sent"),
                },
                None => Some(strays),
            };
            match undelivered {
                None => n += count,
                Some(back) => failed.extend(back),
            }
        }
        if !failed.is_empty() {
            let n_failed = failed.len();
            self.park_strays(failed);
            return Err(Error::Stream(format!(
                "{n_failed} strays re-parked: target worker queue closed"
            )));
        }
        Ok(n)
    }

    /// Park undeliverable strays, bounded by [`PARKED_CAP`]. The list
    /// keeps its oldest entries (they lead the replay order); overflow
    /// — the newest arrivals — is dropped, counted in
    /// `stray_park_drops`, and journaled so an operator can see the
    /// loss in the flight recorder instead of in an OOM.
    fn park_strays(&self, strays: Vec<Stray>) {
        let n = strays.len();
        let dropped = {
            let mut parked = self.parked.lock().unwrap();
            let room = PARKED_CAP.saturating_sub(parked.len());
            if n <= room {
                parked.extend(strays);
                0
            } else {
                parked.extend(strays.into_iter().take(room));
                n - room
            }
        };
        if dropped > 0 {
            self.metrics.stray_park_drops.add(dropped as u64);
            record(EventKind::StrayDrop, dropped as u64, 0, NO_WORKER);
        }
    }

    /// Settle all in-flight routing: rendezvous with every worker (an
    /// empty Seal answers only after the worker has processed its whole
    /// backlog, forwarding any strays), then re-route the strays; loop
    /// until a full round surfaces none. After this, no sample is
    /// parked in the stray channel — which is what lets `finish` flush
    /// without losing late-rerouted verdicts.
    fn quiesce(&self) -> Result<()> {
        loop {
            let slots = self.senders.snapshot();
            for (w, slot) in slots.slots().iter().enumerate() {
                // A dead worker's queue fails the barrier; its own
                // error is reported at join, so just skip the
                // rendezvous. (The barrier drains the worker's ring
                // before answering, so it still means "backlog
                // processed" across both queue planes.)
                let _ = WorkerLink::new(w, slot.clone()).barrier();
            }
            if self.drain_strays()? == 0 {
                return Ok(());
            }
        }
    }

    /// Move virtual shards to explicit target workers, live. Each
    /// (current-owner → target) group runs the full seal → adopt
    /// protocol; verdicts for streams of the moved shards continue
    /// bit-identically on the new worker.
    pub fn migrate_shards(&self, moves: &[(u32, usize)]) -> Result<()> {
        let _guard = self.rebalance_lock.lock().unwrap();
        let workers = self.workers();
        let table = self.shard_map.snapshot();
        for &(shard, to) in moves {
            if shard >= table.virtual_shards() {
                return Err(Error::Stream(format!(
                    "no shard {shard} (virtual_shards = {})",
                    table.virtual_shards()
                )));
            }
            if to >= workers {
                return Err(Error::Stream(format!(
                    "no worker {to} ({workers} live)"
                )));
            }
        }
        self.migrate_grouped(&table, moves, workers)
    }

    /// Check per-shard load since the last check and, when the hottest
    /// worker exceeds `imbalance_threshold ×` the mean, migrate its
    /// hottest shards to the coolest worker. Returns the moves made
    /// (empty when balanced). Call this periodically from the serving
    /// loop (`sharding.rebalance_interval` is the suggested cadence).
    pub fn maybe_rebalance(&self) -> Result<Vec<(u32, usize)>> {
        let _guard = self.rebalance_lock.lock().unwrap();
        // Windowed per-shard activity since the last check: sample
        // deltas drive the balance decision exactly as before, and the
        // windowed p99 breaks ties between equally-loaded shards (move
        // the one whose tail is hurting).
        let delta: Vec<crate::obs::ShardDelta> =
            self.shard_window.lock().unwrap().delta(&self.shard_metrics);
        let table = self.shard_map.snapshot();
        let workers = table.workers();
        if workers < 2 {
            return Ok(Vec::new());
        }
        let mut load = vec![0u64; workers];
        for d in &delta {
            load[table.worker_of(d.shard)] += d.samples;
        }
        let total: u64 = load.iter().sum();
        if total == 0 {
            return Ok(Vec::new());
        }
        let avg = total as f64 / workers as f64;
        let donor = (0..workers).max_by_key(|&w| (load[w], w)).unwrap();
        if (load[donor] as f64) <= avg * self.cfg.sharding.imbalance_threshold
        {
            return Ok(Vec::new());
        }
        let recipient = (0..workers)
            .min_by_key(|&w| (load[w], w))
            .unwrap();
        if donor == recipient {
            return Ok(Vec::new());
        }
        // Donor's shards, hottest first — by windowed volume, then by
        // windowed p99 (between equally-loaded shards, prefer moving
        // the one with the worse tail), then by shard id for
        // determinism; move while it narrows the gap, always leaving
        // the donor at least one shard.
        let mut donor_shards: Vec<(u32, u64, u64)> = table
            .shards_on(donor)
            .into_iter()
            .map(|s| {
                let d = &delta[s as usize];
                (s, d.samples, d.p99_ns)
            })
            .collect();
        donor_shards.sort_by(|a, b| {
            b.1.cmp(&a.1).then(b.2.cmp(&a.2)).then(a.0.cmp(&b.0))
        });
        let mut donor_load = load[donor];
        let mut recip_load = load[recipient];
        let mut moves: Vec<(u32, usize)> = Vec::new();
        for (shard, l, _p99) in &donor_shards {
            if *l == 0 || moves.len() + 1 >= donor_shards.len() {
                break;
            }
            if donor_load - l < recip_load + l {
                // Moving this shard would just swap who is overloaded.
                continue;
            }
            moves.push((*shard, recipient));
            donor_load -= l;
            recip_load += l;
            if (donor_load as f64) <= avg {
                break;
            }
        }
        if moves.is_empty() {
            return Ok(Vec::new());
        }
        let shards: Vec<u32> = moves.iter().map(|&(s, _)| s).collect();
        self.migrate_set(donor, recipient, &shards, workers)?;
        Ok(moves)
    }

    /// Resize the worker pool live. Growing spawns workers
    /// `cur..n` and migrates a minimal, balanced set of shards onto
    /// them; shrinking migrates every shard off workers `n..cur`, sends
    /// them `Retire`, and joins their threads. Stream verdicts continue
    /// bit-identically across either direction.
    pub fn scale_to(&self, n: usize) -> Result<()> {
        if n == 0 {
            return Err(Error::Config("cannot scale to 0 workers".into()));
        }
        let _guard = self.rebalance_lock.lock().unwrap();
        let cur = self.workers();
        if n == cur {
            return Ok(());
        }
        let result = if n > cur {
            self.grow_to(cur, n)
        } else {
            self.shrink_to(cur, n)
        };
        // Track the registry even when a resize fails midway (a dead
        // worker aborting one migration group): the gauge must agree
        // with `workers()` and the installed table, not with the
        // intended target.
        self.metrics.workers_active.set(self.workers() as u64);
        result
    }

    /// Scale-up half of [`Service::scale_to`] (rebalance lock held).
    fn grow_to(&self, cur: usize, n: usize) -> Result<()> {
        // Register the new workers BEFORE any table routes to them.
        for widx in cur..n {
            let (slot, rx) =
                WorkerSlot::with_capacity(self.cfg.queue_capacity);
            let handle = spawn_worker(
                widx,
                &self.cfg,
                HashSet::new(),
                slot.clone(),
                rx,
                self.res_tx.clone(),
                self.stray_tx.clone(),
                self.metrics.clone(),
                self.shard_metrics.clone(),
                self.ensemble_metrics.clone(),
                self.state_mgr.clone(),
            )?;
            self.senders.push(slot);
            self.workers.lock().unwrap().push(Some(handle));
        }
        let table = self.shard_map.snapshot();
        let moves = table.rebalance_moves(n);
        if moves.is_empty() {
            self.install(table.with_workers(n)?)
        } else {
            self.migrate_grouped(&table, &moves, n)
        }
    }

    /// Scale-down half of [`Service::scale_to`] (rebalance lock held).
    fn shrink_to(&self, cur: usize, n: usize) -> Result<()> {
        // Empty the retiring workers first (targets all < n).
        let table = self.shard_map.snapshot();
        let moves = table.rebalance_moves(n);
        self.migrate_grouped(&table, &moves, cur)?;
        self.install(self.shard_map.snapshot().with_workers(n)?)?;
        // Late strays routed under pre-shrink tables may still sit
        // queued — re-route them before the retired queues close.
        self.drain_strays()?;
        let retired = self.senders.truncate(n, self.shard_map.epoch());
        for (i, slot) in retired.iter().enumerate() {
            let _ = WorkerLink::new(n + i, slot.clone()).retire();
            // Explicit close: Senders retained by old tables would
            // otherwise keep the queue open forever. Retire is already
            // buffered — the worker still receives it, then sees the
            // closure.
            slot.close();
        }
        let tail: Vec<Option<WorkerHandle>> =
            self.workers.lock().unwrap().split_off(n);
        for (i, handle) in tail.into_iter().enumerate() {
            let Some(handle) = handle else { continue };
            match handle.join() {
                Ok(result) => result?,
                Err(_) => {
                    return Err(Error::Stream(format!(
                        "worker {} died at retirement",
                        n + i
                    )))
                }
            }
        }
        Ok(())
    }

    fn install(&self, table: ShardTable) -> Result<()> {
        let installed = self.shard_map.install(table)?;
        self.metrics.epoch.set(installed.epoch());
        // Sender-cache invalidation: stamp the sender table with the
        // routing epoch so submitters stop counting
        // `route_epoch_misses` once the pair agrees again.
        self.senders.restamp(installed.epoch());
        Ok(())
    }

    /// Run one migration per (from, to) group of a move list computed
    /// against `table`.
    fn migrate_grouped(
        &self,
        table: &ShardTable,
        moves: &[(u32, usize)],
        workers: usize,
    ) -> Result<()> {
        let mut groups: BTreeMap<(usize, usize), Vec<u32>> = BTreeMap::new();
        for &(shard, to) in moves {
            let from = table.worker_of(shard);
            if from != to {
                groups.entry((from, to)).or_default().push(shard);
            }
        }
        for ((from, to), shards) in groups {
            self.migrate_set(from, to, &shards, workers)?;
        }
        Ok(())
    }

    /// The migration protocol for one shard set, `from` → `to`:
    ///
    /// 1. `Expect` to the new worker — samples for these shards that
    ///    outrun their state get stashed, not misprocessed.
    /// 2. Install the successor table (epoch + 1): new submissions now
    ///    route to the new worker.
    /// 3. `Seal` to the old worker: it finishes everything already
    ///    queued (drain), snapshots every resident stream of the shards
    ///    at its exact watermark, evicts them, disowns the shards, and
    ///    replies with the codec-encoded bundle. Samples that raced in
    ///    behind the seal are forwarded as strays and re-routed here,
    ///    landing in the new worker's queue *before* the Adopt.
    /// 4. `Adopt` to the new worker: restore each stream, take
    ///    ownership, replay the stash in (stream, seq) order through
    ///    the inclusive-watermark dedup — verdicts are bit-identical
    ///    to an unmigrated run.
    fn migrate_set(
        &self,
        from: usize,
        to: usize,
        shards: &[u32],
        workers: usize,
    ) -> Result<()> {
        if shards.is_empty() || from == to {
            return Ok(());
        }
        let t0 = Instant::now();
        let slots = self.senders.snapshot();
        let (src, dst) = match (slots.get(from), slots.get(to)) {
            (Some(f), Some(t)) => (
                WorkerLink::new(from, f.clone()),
                WorkerLink::new(to, t.clone()),
            ),
            _ => {
                return Err(Error::Stream(format!(
                    "migration {from} → {to} names a dead worker"
                )))
            }
        };
        let table = self.shard_map.snapshot();
        let moves: Vec<(u32, usize)> =
            shards.iter().map(|&s| (s, to)).collect();
        // The protocol itself (Expect → install → Seal+barrier → stray
        // drain → Adopt, with the Adopt-always-delivered failure
        // contract) lives in `migrate_over`, shared verbatim with the
        // cluster layer's node → node moves.
        let stats = migrate_over(
            &src,
            &dst,
            shards,
            &mut || self.install(table.with_moves(&moves, workers)?),
            &mut || self.drain_strays().map(|_| ()),
        )?;
        self.metrics.migrations.inc();
        self.metrics.shards_moved.add(shards.len() as u64);
        self.metrics.streams_migrated.add(stats.streams);
        self.metrics
            .migration_time
            .record(t0.elapsed().as_nanos() as u64);
        // Re-baseline the rebalancer's load window: the seal drain just
        // attributed the donor's queued backlog to shards that now map
        // to the new owner — without a fresh snapshot the next
        // `maybe_rebalance` would read that backlog as load on the new
        // worker and ping-pong the shard straight back.
        self.shard_window.lock().unwrap().rebaseline(&self.shard_metrics);
        Ok(())
    }

    // ---- node-level protocol entry points (the cluster layer's view
    // of this process: one Transport-shaped surface fanned out over
    // the local workers) -------------------------------------------

    /// Mark shards as owned by a peer node (`foreign = true`) or
    /// returned home (`false`). Foreign shards still map to a local
    /// worker in the *local* table — the workers just never own them —
    /// so strays for them are escalated through the forwarder instead
    /// of re-delivered locally.
    pub fn mark_foreign(&self, shards: &[u32], foreign: bool) {
        let mut set = self.foreign.lock().unwrap();
        for &s in shards {
            if foreign {
                set.insert(s);
            } else {
                set.remove(&s);
            }
        }
    }

    /// Shards currently marked foreign (sorted, for status output).
    pub fn foreign_shards(&self) -> Vec<u32> {
        let mut v: Vec<u32> =
            self.foreign.lock().unwrap().iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// Install (or remove) the cluster's stray escalation hook.
    pub fn set_stray_forwarder(&self, f: Option<StrayForwarder>) {
        *self.forwarder.lock().unwrap() = f;
    }

    /// Node-level Expect: tell the local owner-to-be of each shard to
    /// stash outrunning samples until the state arrives.
    pub fn expect_shards(&self, shards: &[u32]) -> Result<()> {
        let _guard = self.rebalance_lock.lock().unwrap();
        let slots = self.senders.snapshot();
        let table = self.shard_map.snapshot();
        let mut by_worker: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        for &s in shards {
            if s >= table.virtual_shards() {
                return Err(Error::Stream(format!(
                    "no shard {s} (virtual_shards = {})",
                    table.virtual_shards()
                )));
            }
            by_worker.entry(table.worker_of(s)).or_default().push(s);
        }
        for (w, group) in by_worker {
            match slots.get(w) {
                Some(slot) => {
                    WorkerLink::new(w, slot.clone()).expect(&group)?
                }
                None => {
                    return Err(Error::Stream(format!("worker {w} gone")))
                }
            }
        }
        Ok(())
    }

    /// Node-level Unexpect: cancel a pending [`Self::expect_shards`]
    /// whose Adopt is not coming (the cluster layer lost a failover
    /// race to a peer with a newer table). The workers drop the
    /// pending marks and re-route anything they stashed while waiting.
    pub fn unexpect_shards(&self, shards: &[u32]) -> Result<()> {
        let _guard = self.rebalance_lock.lock().unwrap();
        let slots = self.senders.snapshot();
        let table = self.shard_map.snapshot();
        let mut by_worker: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        for &s in shards {
            if s >= table.virtual_shards() {
                return Err(Error::Stream(format!(
                    "no shard {s} (virtual_shards = {})",
                    table.virtual_shards()
                )));
            }
            by_worker.entry(table.worker_of(s)).or_default().push(s);
        }
        for (w, group) in by_worker {
            match slots.get(w) {
                Some(slot) => {
                    WorkerLink::new(w, slot.clone()).unexpect(&group)?
                }
                None => {
                    return Err(Error::Stream(format!("worker {w} gone")))
                }
            }
        }
        Ok(())
    }

    /// Node-level Seal: snapshot-at-watermark, evict and disown every
    /// stream of `shards` across all local workers; returns the
    /// concatenated encoded checkpoint records (the wire bundle). An
    /// empty shard list is a pure barrier — rendezvous with every
    /// worker, exactly like the in-process migration's barrier round.
    /// The caller (cluster layer) is responsible for marking the
    /// shards foreign afterwards.
    pub fn seal_shards(&self, shards: &[u32]) -> Result<Vec<Vec<u8>>> {
        let _guard = self.rebalance_lock.lock().unwrap();
        let slots = self.senders.snapshot();
        if shards.is_empty() {
            for (w, slot) in slots.slots().iter().enumerate() {
                // Dead workers report their own error at join.
                let _ = WorkerLink::new(w, slot.clone()).barrier();
            }
            return Ok(Vec::new());
        }
        let table = self.shard_map.snapshot();
        let mut by_owner: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        for &s in shards {
            if s >= table.virtual_shards() {
                return Err(Error::Stream(format!(
                    "no shard {s} (virtual_shards = {})",
                    table.virtual_shards()
                )));
            }
            by_owner.entry(table.worker_of(s)).or_default().push(s);
        }
        let mut records = Vec::new();
        for (w, group) in by_owner {
            let link = match slots.get(w) {
                Some(slot) => WorkerLink::new(w, slot.clone()),
                None => {
                    return Err(Error::Stream(format!("worker {w} gone")))
                }
            };
            records.extend(link.seal(&group)?);
            // Per-owner barrier: samples enqueued behind the seal are
            // stray-forwarded before we report the bundle complete.
            link.barrier()?;
        }
        Ok(records)
    }

    /// Node-level Adopt: restore `records` into the local workers that
    /// own their shards (per the local table) and take ownership of
    /// `shards`. Records are routed by the stream id embedded in each
    /// persist-codec record; a record outside the adopted shard set is
    /// a protocol violation and is refused whole.
    pub fn adopt_shards(
        &self,
        shards: &[u32],
        records: Vec<Vec<u8>>,
    ) -> Result<()> {
        let _guard = self.rebalance_lock.lock().unwrap();
        let slots = self.senders.snapshot();
        let table = self.shard_map.snapshot();
        let shard_set: HashSet<u32> = shards.iter().copied().collect();
        let mut by_worker: BTreeMap<usize, (Vec<u32>, Vec<Vec<u8>>)> =
            BTreeMap::new();
        for &s in shards {
            if s >= table.virtual_shards() {
                return Err(Error::Stream(format!(
                    "no shard {s} (virtual_shards = {})",
                    table.virtual_shards()
                )));
            }
            by_worker.entry(table.worker_of(s)).or_default().0.push(s);
        }
        for rec in records {
            let sid = codec::record_stream_id(&rec)?;
            let (w, shard) = table.route(sid);
            if !shard_set.contains(&shard) {
                return Err(Error::Stream(format!(
                    "adopt record for stream {sid} (shard {shard}) \
                     outside the adopted shard set"
                )));
            }
            by_worker.entry(w).or_default().1.push(rec);
        }
        for (w, (group, recs)) in by_worker {
            match slots.get(w) {
                Some(slot) => {
                    WorkerLink::new(w, slot.clone()).adopt(&group, recs)?
                }
                None => {
                    return Err(Error::Stream(format!("worker {w} gone")))
                }
            }
        }
        Ok(())
    }

    /// Deliver strays that arrived from a peer node (their shard moved
    /// here). Samples are re-stamped on arrival — Instants cannot
    /// cross the process boundary — and ride the control plane so they
    /// stay FIFO with any queued Adopt. Undeliverable strays are
    /// parked, never dropped.
    pub fn replay_strays(&self, samples: Vec<Sample>) -> Result<usize> {
        if samples.is_empty() {
            return Ok(0);
        }
        let now = Instant::now();
        let table = self.shard_map.load();
        let slots = self.senders.load();
        let mut per_worker: BTreeMap<usize, Vec<Stray>> = BTreeMap::new();
        for s in samples {
            let (w, _shard) = table.route(s.stream_id);
            per_worker.entry(w).or_default().push((s, now));
        }
        let mut n = 0;
        let mut failed: Vec<Stray> = Vec::new();
        for (w, strays) in per_worker {
            let count = strays.len();
            let undelivered = match slots.get(w) {
                Some(slot) => {
                    match WorkerLink::new(w, slot.clone()).replay(strays) {
                        Ok(_) => None,
                        Err(back) => Some(back),
                    }
                }
                None => Some(strays),
            };
            match undelivered {
                None => n += count,
                Some(back) => failed.extend(back),
            }
        }
        if !failed.is_empty() {
            self.park_strays(failed);
        }
        Ok(n)
    }

    /// Public stray settlement: re-route (or escalate to peers) every
    /// stray currently queued. The cluster layer calls this as the
    /// pull-migration epilogue (a Settle frame) and periodically from
    /// its heartbeat loop.
    pub fn reroute_strays(&self) -> Result<usize> {
        let _guard = self.rebalance_lock.lock().unwrap();
        self.drain_strays()
    }

    /// Finish: flush engines, stop workers, and return every remaining
    /// verdict (in addition to whatever `poll_results` already handed out).
    pub fn finish(self) -> Result<Vec<Classified>> {
        self.stop(|| Job::Flush, true)
    }

    /// Crash simulation: stop every worker WITHOUT flushing, abandoning
    /// in-flight engine state exactly as a killed process would, and
    /// return only the verdicts that had already been emitted. The
    /// shared [`StateManager`] (and whatever checkpoints it holds)
    /// survives — pass it to [`Service::start_with_state`] to failover.
    pub fn abort(self) -> Result<Vec<Classified>> {
        self.stop(|| Job::Abort, false)
    }

    /// Shared shutdown sequence: re-route strays (flush path), send
    /// `last_job` to every worker, close the queues, drain the results
    /// channel, join the workers. A worker that died reports *which*
    /// worker and why (its panic message), not a bare join error.
    fn stop(
        self,
        last_job: impl Fn() -> Job,
        reroute_strays: bool,
    ) -> Result<Vec<Classified>> {
        // A failed quiesce (a dead worker) must not abort the
        // shutdown: keep going so the workers are joined and the
        // dead one's own, more precise error can surface instead.
        // The rebalance lock serializes the final stray drain against
        // any in-flight migration (drain_strays' contract).
        let quiesce_err = if reroute_strays {
            let _guard = self.rebalance_lock.lock().unwrap();
            self.quiesce().err()
        } else {
            None
        };
        let slots = self.senders.snapshot();
        for slot in slots.slots() {
            // A dead worker's queue is already closed; its error
            // surfaces at join below.
            let _ = slot.send_ctl(last_job());
        }
        // Empty the shared registry first so ServiceHandles observe
        // "service stopped", then close every queue explicitly —
        // retained tables hold Sender clones, so drop alone would
        // never close them.
        self.senders.clear();
        for slot in slots.slots() {
            slot.close();
        }
        drop(self.res_tx); // collectors see closure once workers finish
        let mut out = Vec::new();
        while let Ok(burst) = self.results_rx.recv() {
            out.extend(burst);
        }
        let mut first_err: Option<Error> = None;
        for (widx, handle) in
            self.workers.lock().unwrap().drain(..).enumerate()
        {
            let Some(handle) = handle else { continue };
            let result = match handle.join() {
                Ok(r) => r,
                Err(_) => Err(Error::Stream(format!(
                    "worker {widx} died: unreported panic"
                ))),
            };
            if let Err(e) = result {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        match first_err.or(quiesce_err) {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }
}

/// Should the serve loop add a worker *now*? Keyed off the live
/// signals the observability plane exposes (ROADMAP item 2, first
/// half): any data ring ≥ 3/4 full, any backpressure events in the
/// last window, or a windowed queue-wait p99 over the SLO. Pure
/// function of the sampled signals so the policy is unit-testable
/// without threads; the serve loop samples
/// [`Service::queue_depths`] + a [`MetricsWindow`] tick and acts on
/// the verdict.
pub fn scale_up_wanted(
    depths: &[usize],
    capacity: usize,
    backpressure_delta: u64,
    queue_wait_p99_ns: u64,
    slo_ns: u64,
) -> bool {
    let ring_hot = capacity > 0
        && depths
            .iter()
            .any(|&d| d.saturating_mul(4) >= capacity.saturating_mul(3));
    ring_hot
        || backpressure_delta > 0
        || (slo_ns > 0 && queue_wait_p99_ns > slo_ns)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn base_cfg(engine: EngineKind, workers: usize) -> ServiceConfig {
        ServiceConfig {
            engine,
            workers,
            n_features: 2,
            queue_capacity: 64,
            ..Default::default()
        }
    }

    #[test]
    fn software_service_classifies_everything() {
        let svc = Service::start(base_cfg(EngineKind::Software, 3)).unwrap();
        let mut rng = crate::util::prng::SplitMix64::new(1);
        for seq in 0..200u64 {
            for sid in 0..6u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![rng.next_f64(), rng.next_f64()],
                })
                .unwrap();
            }
        }
        let metrics = svc.metrics();
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 1200);
        assert_eq!(metrics.samples_in.get(), 1200);
        assert_eq!(metrics.verdicts_out.get(), 1200);
    }

    #[test]
    fn per_stream_order_is_preserved() {
        let svc = Service::start(base_cfg(EngineKind::Software, 4)).unwrap();
        for seq in 0..300u64 {
            for sid in 0..8u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.1, 0.2],
                })
                .unwrap();
            }
        }
        let out = svc.finish().unwrap();
        let mut last_seq: HashMap<u64, u64> = HashMap::new();
        for c in &out {
            let v = &c.verdict;
            if let Some(&prev) = last_seq.get(&v.stream_id) {
                assert!(v.seq > prev, "stream {} reordered", v.stream_id);
            }
            last_seq.insert(v.stream_id, v.seq);
        }
        assert_eq!(last_seq.len(), 8);
    }

    #[test]
    fn checkpointing_publishes_states() {
        let mut cfg = base_cfg(EngineKind::Software, 2);
        cfg.checkpoint_every = 50;
        let svc = Service::start(cfg).unwrap();
        let mgr = svc.state_manager();
        for seq in 0..120u64 {
            for sid in 0..4u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.5, 0.5],
                })
                .unwrap();
            }
        }
        svc.finish().unwrap();
        assert_eq!(mgr.len(), 4);
        let cp = mgr.latest(2).unwrap();
        assert_eq!(cp.seq, 99); // checkpoint at seq 49 then 99
        let crate::engine::Snapshot::Software(snap) = cp.snapshot else {
            panic!("software engine must publish software snapshots")
        };
        assert_eq!(snap.state.k, 100);
    }

    #[test]
    fn rtl_and_ensemble_engines_checkpoint_too() {
        // Checkpointing is engine-agnostic now — every backend
        // publishes, not just the software engine.
        for kind in [EngineKind::Rtl, EngineKind::Ensemble] {
            let mut cfg = base_cfg(kind, 2);
            cfg.checkpoint_every = 20;
            let svc = Service::start(cfg).unwrap();
            let mgr = svc.state_manager();
            for seq in 0..40u64 {
                for sid in 0..3u64 {
                    svc.submit(Sample {
                        stream_id: sid,
                        seq,
                        values: vec![0.3, 0.7],
                    })
                    .unwrap();
                }
            }
            svc.finish().unwrap();
            assert_eq!(mgr.len(), 3, "engine {kind}");
            let cp = mgr.latest(1).unwrap();
            assert_eq!(cp.seq, 39);
            assert_eq!(cp.snapshot.kind(), kind.to_string());
        }
    }

    #[test]
    fn abort_skips_flush_and_keeps_checkpoints() {
        let mut cfg = base_cfg(EngineKind::Rtl, 2);
        cfg.checkpoint_every = 10;
        let svc = Service::start(cfg).unwrap();
        let mgr = svc.state_manager();
        for seq in 0..10u64 {
            svc.submit(Sample { stream_id: 0, seq, values: vec![0.1, 0.2] })
                .unwrap();
        }
        let out = svc.abort().unwrap();
        // RTL latency = 2: the two in-flight verdicts died with the
        // worker instead of being flushed out.
        assert_eq!(out.len(), 8);
        assert_eq!(mgr.latest(0).unwrap().seq, 9);
    }

    #[test]
    fn ensemble_service_classifies_everything_with_member_metrics() {
        let cfg = base_cfg(EngineKind::Ensemble, 3); // default trio roster
        let n_members = cfg.ensemble.members.len();
        let svc = Service::start(cfg).unwrap();
        let em = svc.ensemble_metrics().expect("ensemble metrics");
        assert_eq!(em.members.len(), n_members);
        let mut rng = crate::util::prng::SplitMix64::new(9);
        for seq in 0..150u64 {
            for sid in 0..6u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![rng.next_f64(), rng.next_f64()],
                })
                .unwrap();
            }
        }
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 900);
        assert_eq!(em.fused_verdicts.get(), 900);
        for m in &em.members {
            assert_eq!(m.votes.get(), 900);
        }
    }

    #[test]
    fn non_ensemble_service_has_no_ensemble_metrics() {
        let svc = Service::start(base_cfg(EngineKind::Software, 1)).unwrap();
        assert!(svc.ensemble_metrics().is_none());
        svc.finish().unwrap();
    }

    #[test]
    fn idle_streams_are_evicted_everywhere_and_restart_fresh() {
        // Single worker so the eviction tick is deterministic. Stream 0
        // goes idle while stream 1 keeps flowing; after `evict_after`
        // idle ticks, stream 0's state must vanish from the engine, the
        // StateManager AND the durable store — and its id re-appearing
        // must start a fresh stream (k = 1), not resurrect stale state.
        let store = Arc::new(crate::persist::MemoryStore::new());
        let mut cfg = base_cfg(EngineKind::Software, 1);
        cfg.checkpoint_every = 10;
        cfg.restore_on_resume = true;
        cfg.evict_after = 40;
        let svc = Service::start_from_store(cfg, store.clone()).unwrap();
        let mgr = svc.state_manager();
        let metrics = svc.metrics();
        for seq in 0..20u64 {
            svc.submit(Sample { stream_id: 0, seq, values: vec![0.1, 0.2] })
                .unwrap();
        }
        for seq in 0..100u64 {
            svc.submit(Sample { stream_id: 1, seq, values: vec![0.3, 0.4] })
                .unwrap();
        }
        // Stream 0 re-appears mid-sequence AFTER its eviction: with no
        // checkpoint left to restore, it must restart at k = 1.
        svc.submit(Sample { stream_id: 0, seq: 50, values: vec![0.1, 0.2] })
            .unwrap();
        let out = svc.finish().unwrap();
        assert_eq!(metrics.stream_evictions.get(), 1);
        assert!(mgr.latest(0).is_none(), "in-memory checkpoint evicted");
        assert_eq!(store.records_for(0), 0, "durable checkpoints evicted");
        assert!(mgr.latest(1).is_some(), "live stream untouched");
        let reborn = out
            .iter()
            .find(|c| c.verdict.stream_id == 0 && c.verdict.seq == 50)
            .expect("re-appearing stream classified");
        assert_eq!(reborn.verdict.k, 1, "evicted stream must start fresh");
    }

    #[test]
    fn eviction_disabled_by_default() {
        let mut cfg = base_cfg(EngineKind::Software, 1);
        cfg.checkpoint_every = 10;
        let svc = Service::start(cfg).unwrap();
        let mgr = svc.state_manager();
        let metrics = svc.metrics();
        for seq in 0..10u64 {
            svc.submit(Sample { stream_id: 0, seq, values: vec![0.1, 0.2] })
                .unwrap();
        }
        for seq in 0..500u64 {
            svc.submit(Sample { stream_id: 1, seq, values: vec![0.3, 0.4] })
                .unwrap();
        }
        svc.finish().unwrap();
        assert_eq!(metrics.stream_evictions.get(), 0);
        assert!(mgr.latest(0).is_some());
    }

    #[test]
    fn start_from_store_recovers_checkpoints() {
        let store = Arc::new(crate::persist::MemoryStore::new());
        let mut cfg = base_cfg(EngineKind::Software, 2);
        cfg.checkpoint_every = 10;
        cfg.restore_on_resume = true;
        // Incarnation 1 publishes durably, then is dropped entirely.
        {
            let svc =
                Service::start_from_store(cfg.clone(), store.clone())
                    .unwrap();
            for seq in 0..20u64 {
                for sid in 0..3u64 {
                    svc.submit(Sample {
                        stream_id: sid,
                        seq,
                        values: vec![0.2, 0.8],
                    })
                    .unwrap();
                }
            }
            svc.abort().unwrap();
        }
        // Incarnation 2 recovers all three streams from the store.
        let svc = Service::start_from_store(cfg, store).unwrap();
        let mgr = svc.state_manager();
        assert_eq!(mgr.len(), 3);
        for sid in 0..3u64 {
            assert_eq!(mgr.latest(sid).unwrap().seq, 19);
        }
        svc.finish().unwrap();
    }

    #[test]
    fn rtl_service_matches_sample_count() {
        let svc = Service::start(base_cfg(EngineKind::Rtl, 2)).unwrap();
        for seq in 0..50u64 {
            for sid in 0..3u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![seq as f64 * 0.01, 0.3],
                })
                .unwrap();
            }
        }
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 150);
    }

    // ----------------------------------------- elastic sharding units

    #[test]
    fn migrate_shards_moves_streams_and_bumps_epoch() {
        let svc = Service::start(base_cfg(EngineKind::Software, 2)).unwrap();
        let metrics = svc.metrics();
        for seq in 0..30u64 {
            for sid in 0..6u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.4, 0.6],
                })
                .unwrap();
            }
        }
        // Move everything worker 0 owns to worker 1.
        let table = svc.table();
        let moves: Vec<(u32, usize)> =
            table.shards_on(0).into_iter().map(|s| (s, 1)).collect();
        svc.migrate_shards(&moves).unwrap();
        assert!(svc.table().epoch() > 0);
        assert!(svc.table().shards_on(0).is_empty());
        assert_eq!(metrics.migrations.get(), 1);
        assert_eq!(metrics.epoch.get(), svc.table().epoch());
        // Streams keep flowing — and continue their sequence (k != 1).
        for seq in 30..40u64 {
            for sid in 0..6u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.4, 0.6],
                })
                .unwrap();
            }
        }
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 240);
        for c in &out {
            assert_eq!(c.verdict.k, c.verdict.seq + 1, "migration restarted a stream");
        }
    }

    #[test]
    fn scale_up_and_down_keeps_every_verdict() {
        let svc = Service::start(base_cfg(EngineKind::Software, 2)).unwrap();
        let submit_range = |from: u64, to: u64| {
            for seq in from..to {
                for sid in 0..8u64 {
                    svc.submit(Sample {
                        stream_id: sid,
                        seq,
                        values: vec![0.2, 0.9],
                    })
                    .unwrap();
                }
            }
        };
        submit_range(0, 40);
        svc.scale_to(5).unwrap();
        assert_eq!(svc.workers(), 5);
        assert_eq!(svc.table().workers(), 5);
        submit_range(40, 80);
        svc.scale_to(1).unwrap();
        assert_eq!(svc.workers(), 1);
        assert!(svc.table().shards_on(0).len() as u32 == svc.table().virtual_shards());
        submit_range(80, 120);
        let metrics = svc.metrics();
        assert_eq!(metrics.workers_active.get(), 1);
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 8 * 120);
        for c in &out {
            assert_eq!(c.verdict.k, c.verdict.seq + 1);
        }
    }

    #[test]
    fn scale_to_same_size_is_a_noop_and_zero_is_rejected() {
        let svc = Service::start(base_cfg(EngineKind::Software, 2)).unwrap();
        svc.scale_to(2).unwrap();
        assert_eq!(svc.table().epoch(), 0, "no-op must not bump the epoch");
        assert!(svc.scale_to(0).is_err());
        svc.finish().unwrap();
    }

    #[test]
    fn maybe_rebalance_moves_hot_shards_off_the_hot_worker() {
        // All load on the shards of one stream → one worker is hot.
        // virtual_shards kept small so donor shard lists stay readable.
        let mut cfg = base_cfg(EngineKind::Software, 2);
        cfg.sharding.virtual_shards = 8;
        let svc = Service::start(cfg).unwrap();
        // Find streams landing on DISTINCT worker-0 shards and hammer
        // them — load split across several shards is what the greedy
        // mover can actually act on (a single monolithic hot shard is
        // correctly left alone: moving it would just move the hotspot).
        let table = svc.table();
        let mut seen_shards = HashSet::new();
        let hot_sids: Vec<u64> = (0..256u64)
            .filter(|&sid| {
                let (w, shard) = table.route(sid);
                w == 0 && seen_shards.insert(shard)
            })
            .take(3)
            .collect();
        assert!(hot_sids.len() >= 2, "need ≥ 2 hot shards on worker 0");
        for seq in 0..100u64 {
            for &sid in &hot_sids {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.1, 0.5],
                })
                .unwrap();
            }
        }
        let moves = svc.maybe_rebalance().unwrap();
        assert!(!moves.is_empty(), "skewed load must trigger moves");
        for &(_, to) in &moves {
            assert_eq!(to, 1, "moves target the cool worker");
        }
        // Balanced load afterwards → second check does nothing.
        assert!(svc.maybe_rebalance().unwrap().is_empty());
        svc.finish().unwrap();
    }

    #[test]
    fn stage_histograms_and_recorder_cover_the_batched_path() {
        crate::obs::recorder().set_enabled(true);
        let svc = Service::start(base_cfg(EngineKind::Software, 2)).unwrap();
        let metrics = svc.metrics();
        let mut window = svc.metrics_window();
        let batch: Vec<Sample> = (0..4u64)
            .flat_map(|sid| {
                (0..50u64).map(move |seq| Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.1, 0.2],
                })
            })
            .collect();
        svc.submit_batch(batch).unwrap();
        assert_eq!(svc.queue_depths().len(), 2, "one depth per worker");
        // Move every worker-0 shard so Seal/Adopt land in the journal.
        let shards0 = svc.table().shards_on(0);
        let moves: Vec<(u32, usize)> =
            shards0.iter().map(|&s| (s, 1)).collect();
        svc.migrate_shards(&moves).unwrap();
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 200);

        // Every verdict decomposes: all three stage histograms saw the
        // burst (queue-wait per burst, engine per burst, emit per
        // burst — counts are per job, not per sample).
        assert!(metrics.queue_wait.count() > 0, "queue_wait recorded");
        assert!(metrics.engine_time.count() > 0, "engine_time recorded");
        assert!(metrics.emit_time.count() > 0, "emit_time recorded");
        let report = window.tick(&metrics);
        assert_eq!(report.delta("samples_in"), 200);
        assert_eq!(report.delta("verdicts_out"), 200);
        assert!(report.p99("latency") > 0);

        // The flight recorder journaled the batched path and the
        // migration protocol.
        let dump = crate::obs::recorder().dump(4096);
        let kinds: HashSet<crate::obs::EventKind> =
            dump.iter().map(|t| t.event.kind).collect();
        use crate::obs::EventKind::*;
        for want in [Submit, Dequeue, Seal, Adopt, EpochSwap] {
            assert!(kinds.contains(&want), "recorder missing {want:?}");
        }
        // Seal/Adopt events carry shard counts (the dump is global and
        // tests share the process, so assert presence, not identity).
        assert!(
            dump.iter()
                .any(|t| t.event.kind == Seal && t.event.shard > 0),
            "a non-empty Seal event is journaled"
        );
    }

    #[test]
    fn out_of_order_duplicates_are_dropped_not_ingested() {
        // The watermark guard: a sample at or below a stream's last
        // ingested seq (duplicate or pathologically late stray) must
        // be dropped, not folded into the order-dependent recurrence.
        let svc = Service::start(base_cfg(EngineKind::Software, 1)).unwrap();
        let metrics = svc.metrics();
        for seq in 0..5u64 {
            svc.submit(Sample { stream_id: 0, seq, values: vec![0.1, 0.2] })
                .unwrap();
        }
        // Replay seq 2 out of order.
        svc.submit(Sample { stream_id: 0, seq: 2, values: vec![9.9, 9.9] })
            .unwrap();
        svc.submit(Sample { stream_id: 0, seq: 5, values: vec![0.1, 0.2] })
            .unwrap();
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 6, "duplicate must not produce a verdict");
        assert_eq!(metrics.stale_drops.get(), 1);
        for c in &out {
            assert_eq!(c.verdict.k, c.verdict.seq + 1, "state uncorrupted");
        }
    }

    #[test]
    fn worker_panic_is_counted_and_named_at_drain() {
        // A malformed sample (wrong feature dimension) panics the TEDA
        // recurrence inside worker 0. The guard must count it, keep the
        // process alive, and report the worker index at finish.
        let svc = Service::start(base_cfg(EngineKind::Software, 1)).unwrap();
        let metrics = svc.metrics();
        svc.submit(Sample { stream_id: 0, seq: 0, values: vec![0.5] })
            .unwrap();
        let err = svc.finish().expect_err("panicked worker must surface");
        let msg = err.to_string();
        assert!(msg.contains("worker 0 panicked"), "got: {msg}");
        assert_eq!(metrics.worker_panics.get(), 1);
    }

    #[test]
    fn handle_follows_scaling() {
        // A handle cloned before a resize must keep routing correctly
        // afterwards (shared registry, not a point-in-time copy).
        let svc = Service::start(base_cfg(EngineKind::Software, 2)).unwrap();
        let handle = svc.handle();
        for seq in 0..10u64 {
            for sid in 0..4u64 {
                handle
                    .submit(Sample {
                        stream_id: sid,
                        seq,
                        values: vec![0.3, 0.3],
                    })
                    .unwrap();
            }
        }
        svc.scale_to(4).unwrap();
        for seq in 10..20u64 {
            for sid in 0..4u64 {
                handle
                    .submit(Sample {
                        stream_id: sid,
                        seq,
                        values: vec![0.3, 0.3],
                    })
                    .unwrap();
            }
        }
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 80);
        for c in &out {
            assert_eq!(c.verdict.k, c.verdict.seq + 1);
        }
    }

    #[test]
    fn handle_submit_batch_counts_and_delivers() {
        let svc = Service::start(base_cfg(EngineKind::Software, 3)).unwrap();
        let handle = svc.handle();
        let metrics = svc.metrics();
        for seq in 0..50u64 {
            let burst: Vec<Sample> = (0..8u64)
                .map(|sid| Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.2, 0.7],
                })
                .collect();
            handle.submit_batch(burst).unwrap();
        }
        handle.submit_batch(Vec::new()).unwrap(); // empty burst is a no-op
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 400);
        assert_eq!(metrics.samples_in.get(), 400);
        assert!(metrics.batch_sizes.count() > 0);
        for c in &out {
            assert_eq!(c.verdict.k, c.verdict.seq + 1);
        }
    }

    #[test]
    fn stale_sender_table_is_detected_and_counted() {
        // White-box: install a successor routing table WITHOUT the
        // restamp that Service::install performs, recreating the
        // (normally microseconds-wide) window where the sender table
        // lags the shard table. Submits must count the miss and still
        // deliver; the restamp ends the miss-counting.
        let svc = Service::start(base_cfg(EngineKind::Software, 2)).unwrap();
        let metrics = svc.metrics();
        let identity = svc.table().with_moves(&[], 2).unwrap(); // epoch + 1
        svc.shard_map.install(identity).unwrap();
        svc.submit(Sample { stream_id: 7, seq: 0, values: vec![0.1, 0.9] })
            .unwrap();
        assert!(metrics.route_epoch_misses.get() >= 1);
        svc.senders.restamp(svc.shard_map.epoch());
        let before = metrics.route_epoch_misses.get();
        svc.submit(Sample { stream_id: 7, seq: 1, values: vec![0.1, 0.9] })
            .unwrap();
        assert_eq!(metrics.route_epoch_misses.get(), before);
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 2, "misses must not lose samples");
    }
}

//! The service: worker threads + router + result collection.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::{EngineKind, ServiceConfig};
use crate::coordinator::{Router, StateCheckpoint, StateManager};
use crate::engine::{Engine, EngineVerdict, RtlEngine, SoftwareEngine, XlaEngine};
use crate::ensemble::EnsembleEngine;
use crate::metrics::{EnsembleMetrics, ServiceMetrics};
use crate::persist::{CheckpointStore, FileStore};
use crate::runtime::XlaRuntime;
use crate::stream::{bounded, Receiver, Sample, Sender};
use crate::{Error, Result};

/// A verdict annotated with its end-to-end latency.
#[derive(Debug, Clone, PartialEq)]
pub struct Classified {
    pub verdict: EngineVerdict,
    /// submit → verdict wall time in ns.
    pub latency_ns: u64,
}

enum Job {
    Sample(Sample, Instant),
    /// Amortizes channel synchronization: one lock per burst instead of
    /// one per sample (see EXPERIMENTS.md §Perf).
    Batch(Vec<Sample>, Instant),
    /// Force pending batches out (end of input).
    Flush,
    /// Die immediately WITHOUT flushing — crash simulation for failover
    /// testing and fast teardown. In-flight engine state is abandoned
    /// exactly as a killed worker would abandon it.
    Abort,
}

/// A running service instance.
pub struct Service {
    cfg: ServiceConfig,
    router: Router,
    senders: Vec<Sender<Job>>,
    workers: Vec<JoinHandle<Result<()>>>,
    /// Verdicts travel in bursts (one Vec per processed job) to keep
    /// channel synchronization off the per-sample path.
    results_rx: Receiver<Vec<Classified>>,
    metrics: Arc<ServiceMetrics>,
    /// Per-member counters, present when the engine is an ensemble.
    ensemble_metrics: Option<Arc<EnsembleMetrics>>,
    state_mgr: Arc<StateManager>,
}

/// Cheap clonable submit-side handle.
pub struct ServiceHandle {
    router: Router,
    senders: Vec<Sender<Job>>,
    metrics: Arc<ServiceMetrics>,
}

impl Clone for ServiceHandle {
    fn clone(&self) -> Self {
        ServiceHandle {
            router: self.router.clone(),
            senders: self.senders.clone(),
            metrics: self.metrics.clone(),
        }
    }
}

impl ServiceHandle {
    /// Submit one sample (blocks under backpressure).
    pub fn submit(&self, sample: Sample) -> Result<()> {
        submit_inner(&self.router, &self.senders, &self.metrics, sample)
    }
}

/// Shared submit path: non-blocking fast path, blocking (counted)
/// backpressure path when the worker queue is full.
fn submit_inner(
    router: &Router,
    senders: &[Sender<Job>],
    metrics: &ServiceMetrics,
    sample: Sample,
) -> Result<()> {
    let w = router.route(sample.stream_id);
    let job = Job::Sample(sample, Instant::now());
    match senders[w].try_send(job) {
        Ok(None) => {
            metrics.samples_in.inc();
            Ok(())
        }
        Ok(Some(job)) => {
            metrics.backpressure_events.inc();
            senders[w]
                .send(job)
                .map_err(|_| Error::Stream("worker queue closed".into()))?;
            metrics.samples_in.inc();
            Ok(())
        }
        Err(_) => Err(Error::Stream("worker queue closed".into())),
    }
}

/// Worker-side checkpoint/eviction knobs, lifted from [`ServiceConfig`].
#[derive(Clone, Copy)]
struct CheckpointPolicy {
    /// Publish a snapshot every N samples per stream (0 = off).
    every: u64,
    /// Restore the newest checkpoint when a stream resumes mid-sequence.
    restore_on_resume: bool,
    /// Evict a stream idle for N worker-processed samples (0 = never).
    evict_after: u64,
}

impl CheckpointPolicy {
    fn from_cfg(cfg: &ServiceConfig) -> Self {
        CheckpointPolicy {
            every: cfg.checkpoint_every,
            restore_on_resume: cfg.restore_on_resume,
            evict_after: cfg.evict_after,
        }
    }
}

impl Service {
    /// Start workers per the config, with a fresh checkpoint store.
    /// When `checkpoint.dir` is configured, a durable [`FileStore`] is
    /// opened there and every published checkpoint is written through
    /// (but nothing is loaded back — cold starts are fresh; use
    /// [`Service::start_from_store`] to recover).
    ///
    /// Directory lifecycle is the operator's: a fresh start against a
    /// directory holding an older run's checkpoints appends to that
    /// history, and a later recovery picks the highest watermark per
    /// stream across both. That is correct when stream sequence
    /// numbers are globally consistent (the system's contract); to
    /// deliberately abandon a history, point at a new directory or
    /// clear the old one first.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let state_mgr = match &cfg.checkpoint_dir {
            Some(dir) => {
                let store = FileStore::open(dir, cfg.checkpoint_keep)?;
                Arc::new(StateManager::with_store(Arc::new(store)))
            }
            None => Arc::new(StateManager::new()),
        };
        Self::start_with_state(cfg, state_mgr)
    }

    /// Cold-start from a durable checkpoint store — the full-process-
    /// death recovery path: the newest *valid* checkpoint of every
    /// stream in the store is loaded (corrupt/truncated tails are
    /// skipped in favour of earlier records), then workers start
    /// against the recovered [`StateManager`] with write-through to
    /// the same store. Enable `checkpoint.restore` so resuming streams
    /// actually adopt the recovered snapshots.
    pub fn start_from_store(
        cfg: ServiceConfig,
        store: Arc<dyn CheckpointStore>,
    ) -> Result<Service> {
        let state_mgr = Arc::new(StateManager::with_store(store));
        state_mgr.recover()?;
        Self::start_with_state(cfg, state_mgr)
    }

    /// Start workers against an existing checkpoint store — the
    /// failover path: a resurrected service inherits the dead
    /// instance's [`StateManager`] and, with
    /// `checkpoint.restore = true`, restores each stream's latest
    /// snapshot the moment the stream resumes.
    pub fn start_with_state(
        cfg: ServiceConfig,
        state_mgr: Arc<StateManager>,
    ) -> Result<Service> {
        cfg.validate()?;
        let metrics = ServiceMetrics::new();
        // Ensemble runs get one shared per-member counter bundle: every
        // worker shard's EnsembleEngine adds into the same atomics.
        let ensemble_metrics = (cfg.engine == EngineKind::Ensemble)
            .then(|| EnsembleMetrics::new(cfg.ensemble.labels()));
        let router = Router::new(cfg.workers);
        // Results flow on an unbounded channel: a worker must never
        // block on its own consumer (the submitter only drains results
        // after submission, so a bounded results path could deadlock the
        // whole pipeline: worker→results full→worker stalls→queues
        // fill→submit blocks).
        let (res_tx, res_rx) = crate::stream::unbounded::<Vec<Classified>>();

        // PJRT handles are not Send (the xla crate wraps an Rc), so each
        // worker constructs its own engine — including its own PJRT
        // runtime — inside its thread.
        let mut senders = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for widx in 0..cfg.workers {
            let (tx, rx) = bounded::<Job>(cfg.queue_capacity);
            senders.push(tx);
            let res_tx = res_tx.clone();
            let metrics = metrics.clone();
            let ens_metrics = ensemble_metrics.clone();
            let state_mgr = state_mgr.clone();
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("teda-worker-{widx}"))
                    .spawn(move || {
                        let mut engine: Box<dyn Engine> = match cfg.engine {
                            EngineKind::Software => Box::new(
                                SoftwareEngine::new(cfg.n_features, cfg.m),
                            ),
                            EngineKind::Rtl => Box::new(RtlEngine::new(
                                cfg.n_features,
                                cfg.m,
                            )),
                            EngineKind::Xla => {
                                let rt = XlaRuntime::new(&cfg.artifact_dir)?;
                                Box::new(
                                    XlaEngine::new(
                                        &rt,
                                        cfg.n_features,
                                        cfg.batch_max_streams * cfg.chunk_t,
                                    )?
                                    // Wait for a full batch of stream
                                    // chunks before dispatching: padding
                                    // lanes cost as much as real ones
                                    // (27× per-sample difference — see
                                    // the `batcher` bench); stragglers
                                    // are handled by Flush.
                                    .with_min_ready(cfg.batch_max_streams),
                                )
                            }
                            EngineKind::Ensemble => {
                                let mut eng = EnsembleEngine::new(
                                    &cfg.ensemble,
                                    cfg.n_features,
                                )?;
                                if let Some(em) = ens_metrics {
                                    eng = eng.with_metrics(em);
                                }
                                Box::new(eng)
                            }
                        };
                        worker_loop(
                            rx,
                            engine.as_mut(),
                            res_tx,
                            metrics,
                            state_mgr,
                            CheckpointPolicy::from_cfg(&cfg),
                        )
                    })
                    .map_err(|e| Error::io("spawn worker", e))?,
            );
        }
        drop(res_tx); // collectors see closure once workers finish
        Ok(Service {
            cfg,
            router,
            senders,
            workers,
            results_rx: res_rx,
            metrics,
            ensemble_metrics,
            state_mgr,
        })
    }

    /// Service configuration.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Shared metrics.
    pub fn metrics(&self) -> Arc<ServiceMetrics> {
        self.metrics.clone()
    }

    /// Shared per-member ensemble counters (ensemble engine only).
    pub fn ensemble_metrics(&self) -> Option<Arc<EnsembleMetrics>> {
        self.ensemble_metrics.clone()
    }

    /// Shared state manager (checkpoints).
    pub fn state_manager(&self) -> Arc<StateManager> {
        self.state_mgr.clone()
    }

    /// Submit one sample, blocking when the worker queue is full
    /// (backpressure; the block is counted in metrics).
    pub fn submit(&self, sample: Sample) -> Result<()> {
        submit_inner(&self.router, &self.senders, &self.metrics, sample)
    }

    /// Submit a burst of samples: routed per stream, but enqueued as one
    /// job per worker — one channel synchronization per burst per worker
    /// instead of one per sample (the L3 hot-path optimization;
    /// EXPERIMENTS.md §Perf).
    pub fn submit_batch(&self, samples: Vec<Sample>) -> Result<()> {
        let now = Instant::now();
        let n = samples.len() as u64;
        let mut per_worker: Vec<Vec<Sample>> =
            (0..self.senders.len()).map(|_| Vec::new()).collect();
        for s in samples {
            per_worker[self.router.route(s.stream_id)].push(s);
        }
        for (w, batch) in per_worker.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            match self.senders[w].try_send(Job::Batch(batch, now)) {
                Ok(None) => {}
                Ok(Some(job)) => {
                    self.metrics.backpressure_events.inc();
                    self.senders[w].send(job).map_err(|_| {
                        Error::Stream("worker queue closed".into())
                    })?;
                }
                Err(_) => {
                    return Err(Error::Stream("worker queue closed".into()))
                }
            }
        }
        self.metrics.samples_in.add(n);
        Ok(())
    }

    /// Clonable submit-side handle for multi-threaded sources.
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle {
            router: self.router.clone(),
            senders: self.senders.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Drain any verdicts already available without blocking.
    pub fn poll_results(&self) -> Vec<Classified> {
        let mut out = Vec::new();
        while let Ok(Some(burst)) = self.results_rx.try_recv() {
            out.extend(burst);
        }
        out
    }

    /// Finish: flush engines, stop workers, and return every remaining
    /// verdict (in addition to whatever `poll_results` already handed out).
    pub fn finish(self) -> Result<Vec<Classified>> {
        self.stop(|| Job::Flush, "flush")
    }

    /// Crash simulation: stop every worker WITHOUT flushing, abandoning
    /// in-flight engine state exactly as a killed process would, and
    /// return only the verdicts that had already been emitted. The
    /// shared [`StateManager`] (and whatever checkpoints it holds)
    /// survives — pass it to [`Service::start_with_state`] to failover.
    pub fn abort(self) -> Result<Vec<Classified>> {
        self.stop(|| Job::Abort, "abort")
    }

    /// Shared shutdown sequence: send `last_job` to every worker, close
    /// the queues, drain the results channel, join the workers.
    fn stop(
        self,
        last_job: impl Fn() -> Job,
        what: &str,
    ) -> Result<Vec<Classified>> {
        for tx in &self.senders {
            tx.send(last_job()).map_err(|_| {
                Error::Stream(format!("worker gone at {what}"))
            })?;
        }
        drop(self.senders); // workers exit after draining queues
        let mut out = Vec::new();
        while let Ok(burst) = self.results_rx.recv() {
            out.extend(burst);
        }
        for w in self.workers {
            w.join()
                .map_err(|_| Error::Stream("worker panicked".into()))??;
        }
        Ok(out)
    }
}

/// Drop every stream idle for ≥ `evict_after` worker samples: engine
/// state, in-memory checkpoint, durable checkpoints, and the worker's
/// bookkeeping go together, so a re-appearing stream id starts fresh
/// instead of resurrecting stale state. Scans once per `evict_after`
/// ticks to keep the hot path O(1).
#[allow(clippy::too_many_arguments)]
fn evict_idle_streams(
    engine: &mut dyn Engine,
    state_mgr: &StateManager,
    metrics: &ServiceMetrics,
    evict_after: u64,
    tick: u64,
    last_seen: &mut HashMap<u64, u64>,
    seen: &mut HashSet<u64>,
    restored_at: &mut HashMap<u64, u64>,
    inflight: &mut HashMap<(u64, u64), Instant>,
) {
    if evict_after == 0 || tick == 0 || tick % evict_after != 0 {
        return;
    }
    let idle: Vec<u64> = last_seen
        .iter()
        .filter(|(_, &at)| tick - at >= evict_after)
        .map(|(&sid, _)| sid)
        .collect();
    for sid in idle {
        engine.evict(sid);
        state_mgr.evict(sid);
        seen.remove(&sid);
        restored_at.remove(&sid);
        last_seen.remove(&sid);
        // The engine discarded the stream's in-flight verdicts; their
        // latency records would otherwise leak forever.
        inflight.retain(|(s, _), _| *s != sid);
        metrics.stream_evictions.inc();
    }
}

fn worker_loop(
    rx: Receiver<Job>,
    engine: &mut dyn Engine,
    res_tx: Sender<Vec<Classified>>,
    metrics: Arc<ServiceMetrics>,
    state_mgr: Arc<StateManager>,
    policy: CheckpointPolicy,
) -> Result<()> {
    // submit-time of every in-flight sample, for latency accounting.
    let mut inflight: HashMap<(u64, u64), Instant> = HashMap::new();
    // Streams this worker has fed to its engine (restore-on-resume runs
    // once, before a stream's first sample).
    let mut seen: HashSet<u64> = HashSet::new();
    // Watermark each stream was restored at: re-fed samples at or below
    // it are already folded into the snapshot and must be dropped, so an
    // upstream that replays from the watermark *inclusively* stays
    // exactly-once instead of double-counting (or, worse, restarting).
    let mut restored_at: HashMap<u64, u64> = HashMap::new();
    // Idle-stream eviction bookkeeping: samples processed by this
    // worker, and the tick each stream last appeared at.
    let mut tick: u64 = 0;
    let mut last_seen: HashMap<u64, u64> = HashMap::new();
    // One burst send per engine call: metrics are batched too (counter
    // adds are cheap but the channel lock is not).
    let emit = |verdicts: Vec<EngineVerdict>,
                inflight: &mut HashMap<(u64, u64), Instant>|
     -> Result<()> {
        if verdicts.is_empty() {
            return Ok(());
        }
        let mut burst = Vec::with_capacity(verdicts.len());
        let mut outliers = 0u64;
        for v in verdicts {
            // Verdicts without a submit record (re-emitted in-flight
            // work after a restore) report 0 but are NOT recorded into
            // the histogram — fabricated 0 ns entries would drag every
            // post-failover quantile toward zero.
            let latency_ns = match inflight.remove(&(v.stream_id, v.seq)) {
                Some(t) => {
                    let ns = t.elapsed().as_nanos() as u64;
                    metrics.latency.record(ns);
                    ns
                }
                None => 0,
            };
            if v.outlier {
                outliers += 1;
            }
            burst.push(Classified { verdict: v, latency_ns });
        }
        metrics.verdicts_out.add(burst.len() as u64);
        metrics.outliers.add(outliers);
        res_tx
            .send(burst)
            .map_err(|_| Error::Stream("results channel closed".into()))?;
        Ok(())
    };

    // One sample through the engine: restore-on-resume before its first
    // sample of a stream, replay-window dedup, ingest, then periodic
    // engine-agnostic checkpointing — identical on the single-sample
    // and batch paths.
    let process = |engine: &mut dyn Engine,
                   sample: Sample,
                   t0: Instant,
                   inflight: &mut HashMap<(u64, u64), Instant>,
                   seen: &mut HashSet<u64>,
                   restored_at: &mut HashMap<u64, u64>,
                   tick: u64,
                   last_seen: &mut HashMap<u64, u64>,
                   out: &mut Vec<EngineVerdict>|
     -> Result<()> {
        let (sid, seq) = (sample.stream_id, sample.seq);
        last_seen.insert(sid, tick);
        if seen.insert(sid) && policy.restore_on_resume && seq > 0 {
            // First sample of a mid-stream resume: adopt the newest
            // checkpoint. The upstream replays at-least-once from the
            // watermark (inclusively or after it); either way the
            // watermark filter below keeps processing exactly-once.
            if let Some(cp) = state_mgr.latest(sid) {
                engine.restore(sid, cp.snapshot)?;
                metrics.stream_restores.inc();
                restored_at.insert(sid, cp.seq);
            }
        }
        if let Some(&wm) = restored_at.get(&sid) {
            if seq <= wm {
                // Already folded into the restored snapshot: dropping it
                // (instead of re-ingesting) is what keeps the detector
                // state exactly-once under an inclusive replay window.
                metrics.replay_skipped.inc();
                return Ok(());
            }
        }
        inflight.insert((sid, seq), t0);
        out.extend(engine.ingest(&sample)?);
        if policy.every > 0 && (seq + 1) % policy.every == 0 {
            if let Some(snapshot) = engine.snapshot(sid) {
                state_mgr.publish(StateCheckpoint {
                    stream_id: sid,
                    seq,
                    snapshot,
                });
            }
        }
        Ok(())
    };

    while let Ok(job) = rx.recv() {
        match job {
            Job::Sample(sample, t0) => {
                let mut verdicts = Vec::new();
                tick += 1;
                process(
                    &mut *engine,
                    sample,
                    t0,
                    &mut inflight,
                    &mut seen,
                    &mut restored_at,
                    tick,
                    &mut last_seen,
                    &mut verdicts,
                )?;
                evict_idle_streams(
                    &mut *engine,
                    &state_mgr,
                    &metrics,
                    policy.evict_after,
                    tick,
                    &mut last_seen,
                    &mut seen,
                    &mut restored_at,
                    &mut inflight,
                );
                emit(verdicts, &mut inflight)?;
            }
            Job::Batch(samples, t0) => {
                // Accumulate the whole burst's verdicts and emit once.
                let mut all = Vec::with_capacity(samples.len());
                for sample in samples {
                    tick += 1;
                    process(
                        &mut *engine,
                        sample,
                        t0,
                        &mut inflight,
                        &mut seen,
                        &mut restored_at,
                        tick,
                        &mut last_seen,
                        &mut all,
                    )?;
                    evict_idle_streams(
                        &mut *engine,
                        &state_mgr,
                        &metrics,
                        policy.evict_after,
                        tick,
                        &mut last_seen,
                        &mut seen,
                        &mut restored_at,
                        &mut inflight,
                    );
                }
                emit(all, &mut inflight)?;
            }
            Job::Flush => {
                let verdicts = engine.flush()?;
                emit(verdicts, &mut inflight)?;
            }
            // Crash simulation: drop everything on the floor, no flush.
            Job::Abort => return Ok(()),
        }
    }
    // Input closed: final flush for whatever is still buffered.
    let verdicts = engine.flush()?;
    emit(verdicts, &mut inflight)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg(engine: EngineKind, workers: usize) -> ServiceConfig {
        ServiceConfig {
            engine,
            workers,
            n_features: 2,
            queue_capacity: 64,
            ..Default::default()
        }
    }

    #[test]
    fn software_service_classifies_everything() {
        let svc = Service::start(base_cfg(EngineKind::Software, 3)).unwrap();
        let mut rng = crate::util::prng::SplitMix64::new(1);
        for seq in 0..200u64 {
            for sid in 0..6u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![rng.next_f64(), rng.next_f64()],
                })
                .unwrap();
            }
        }
        let metrics = svc.metrics();
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 1200);
        assert_eq!(metrics.samples_in.get(), 1200);
        assert_eq!(metrics.verdicts_out.get(), 1200);
    }

    #[test]
    fn per_stream_order_is_preserved() {
        let svc = Service::start(base_cfg(EngineKind::Software, 4)).unwrap();
        for seq in 0..300u64 {
            for sid in 0..8u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.1, 0.2],
                })
                .unwrap();
            }
        }
        let out = svc.finish().unwrap();
        let mut last_seq: HashMap<u64, u64> = HashMap::new();
        for c in &out {
            let v = &c.verdict;
            if let Some(&prev) = last_seq.get(&v.stream_id) {
                assert!(v.seq > prev, "stream {} reordered", v.stream_id);
            }
            last_seq.insert(v.stream_id, v.seq);
        }
        assert_eq!(last_seq.len(), 8);
    }

    #[test]
    fn checkpointing_publishes_states() {
        let mut cfg = base_cfg(EngineKind::Software, 2);
        cfg.checkpoint_every = 50;
        let svc = Service::start(cfg).unwrap();
        let mgr = svc.state_manager();
        for seq in 0..120u64 {
            for sid in 0..4u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![0.5, 0.5],
                })
                .unwrap();
            }
        }
        svc.finish().unwrap();
        assert_eq!(mgr.len(), 4);
        let cp = mgr.latest(2).unwrap();
        assert_eq!(cp.seq, 99); // checkpoint at seq 49 then 99
        let crate::engine::Snapshot::Software(snap) = cp.snapshot else {
            panic!("software engine must publish software snapshots")
        };
        assert_eq!(snap.state.k, 100);
    }

    #[test]
    fn rtl_and_ensemble_engines_checkpoint_too() {
        // Checkpointing is engine-agnostic now — every backend
        // publishes, not just the software engine.
        for kind in [EngineKind::Rtl, EngineKind::Ensemble] {
            let mut cfg = base_cfg(kind, 2);
            cfg.checkpoint_every = 20;
            let svc = Service::start(cfg).unwrap();
            let mgr = svc.state_manager();
            for seq in 0..40u64 {
                for sid in 0..3u64 {
                    svc.submit(Sample {
                        stream_id: sid,
                        seq,
                        values: vec![0.3, 0.7],
                    })
                    .unwrap();
                }
            }
            svc.finish().unwrap();
            assert_eq!(mgr.len(), 3, "engine {kind}");
            let cp = mgr.latest(1).unwrap();
            assert_eq!(cp.seq, 39);
            assert_eq!(cp.snapshot.kind(), kind.to_string());
        }
    }

    #[test]
    fn abort_skips_flush_and_keeps_checkpoints() {
        let mut cfg = base_cfg(EngineKind::Rtl, 2);
        cfg.checkpoint_every = 10;
        let svc = Service::start(cfg).unwrap();
        let mgr = svc.state_manager();
        for seq in 0..10u64 {
            svc.submit(Sample { stream_id: 0, seq, values: vec![0.1, 0.2] })
                .unwrap();
        }
        let out = svc.abort().unwrap();
        // RTL latency = 2: the two in-flight verdicts died with the
        // worker instead of being flushed out.
        assert_eq!(out.len(), 8);
        assert_eq!(mgr.latest(0).unwrap().seq, 9);
    }

    #[test]
    fn ensemble_service_classifies_everything_with_member_metrics() {
        let cfg = base_cfg(EngineKind::Ensemble, 3); // default trio roster
        let n_members = cfg.ensemble.members.len();
        let svc = Service::start(cfg).unwrap();
        let em = svc.ensemble_metrics().expect("ensemble metrics");
        assert_eq!(em.members.len(), n_members);
        let mut rng = crate::util::prng::SplitMix64::new(9);
        for seq in 0..150u64 {
            for sid in 0..6u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![rng.next_f64(), rng.next_f64()],
                })
                .unwrap();
            }
        }
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 900);
        assert_eq!(em.fused_verdicts.get(), 900);
        for m in &em.members {
            assert_eq!(m.votes.get(), 900);
        }
    }

    #[test]
    fn non_ensemble_service_has_no_ensemble_metrics() {
        let svc = Service::start(base_cfg(EngineKind::Software, 1)).unwrap();
        assert!(svc.ensemble_metrics().is_none());
        svc.finish().unwrap();
    }

    #[test]
    fn idle_streams_are_evicted_everywhere_and_restart_fresh() {
        // Single worker so the eviction tick is deterministic. Stream 0
        // goes idle while stream 1 keeps flowing; after `evict_after`
        // idle ticks, stream 0's state must vanish from the engine, the
        // StateManager AND the durable store — and its id re-appearing
        // must start a fresh stream (k = 1), not resurrect stale state.
        let store = Arc::new(crate::persist::MemoryStore::new());
        let mut cfg = base_cfg(EngineKind::Software, 1);
        cfg.checkpoint_every = 10;
        cfg.restore_on_resume = true;
        cfg.evict_after = 40;
        let svc = Service::start_from_store(cfg, store.clone()).unwrap();
        let mgr = svc.state_manager();
        let metrics = svc.metrics();
        for seq in 0..20u64 {
            svc.submit(Sample { stream_id: 0, seq, values: vec![0.1, 0.2] })
                .unwrap();
        }
        for seq in 0..100u64 {
            svc.submit(Sample { stream_id: 1, seq, values: vec![0.3, 0.4] })
                .unwrap();
        }
        // Stream 0 re-appears mid-sequence AFTER its eviction: with no
        // checkpoint left to restore, it must restart at k = 1.
        svc.submit(Sample { stream_id: 0, seq: 50, values: vec![0.1, 0.2] })
            .unwrap();
        let out = svc.finish().unwrap();
        assert_eq!(metrics.stream_evictions.get(), 1);
        assert!(mgr.latest(0).is_none(), "in-memory checkpoint evicted");
        assert_eq!(store.records_for(0), 0, "durable checkpoints evicted");
        assert!(mgr.latest(1).is_some(), "live stream untouched");
        let reborn = out
            .iter()
            .find(|c| c.verdict.stream_id == 0 && c.verdict.seq == 50)
            .expect("re-appearing stream classified");
        assert_eq!(reborn.verdict.k, 1, "evicted stream must start fresh");
    }

    #[test]
    fn eviction_disabled_by_default() {
        let mut cfg = base_cfg(EngineKind::Software, 1);
        cfg.checkpoint_every = 10;
        let svc = Service::start(cfg).unwrap();
        let mgr = svc.state_manager();
        let metrics = svc.metrics();
        for seq in 0..10u64 {
            svc.submit(Sample { stream_id: 0, seq, values: vec![0.1, 0.2] })
                .unwrap();
        }
        for seq in 0..500u64 {
            svc.submit(Sample { stream_id: 1, seq, values: vec![0.3, 0.4] })
                .unwrap();
        }
        svc.finish().unwrap();
        assert_eq!(metrics.stream_evictions.get(), 0);
        assert!(mgr.latest(0).is_some());
    }

    #[test]
    fn start_from_store_recovers_checkpoints() {
        let store = Arc::new(crate::persist::MemoryStore::new());
        let mut cfg = base_cfg(EngineKind::Software, 2);
        cfg.checkpoint_every = 10;
        cfg.restore_on_resume = true;
        // Incarnation 1 publishes durably, then is dropped entirely.
        {
            let svc =
                Service::start_from_store(cfg.clone(), store.clone())
                    .unwrap();
            for seq in 0..20u64 {
                for sid in 0..3u64 {
                    svc.submit(Sample {
                        stream_id: sid,
                        seq,
                        values: vec![0.2, 0.8],
                    })
                    .unwrap();
                }
            }
            svc.abort().unwrap();
        }
        // Incarnation 2 recovers all three streams from the store.
        let svc = Service::start_from_store(cfg, store).unwrap();
        let mgr = svc.state_manager();
        assert_eq!(mgr.len(), 3);
        for sid in 0..3u64 {
            assert_eq!(mgr.latest(sid).unwrap().seq, 19);
        }
        svc.finish().unwrap();
    }

    #[test]
    fn rtl_service_matches_sample_count() {
        let svc = Service::start(base_cfg(EngineKind::Rtl, 2)).unwrap();
        for seq in 0..50u64 {
            for sid in 0..3u64 {
                svc.submit(Sample {
                    stream_id: sid,
                    seq,
                    values: vec![seq as f64 * 0.01, 0.3],
                })
                .unwrap();
            }
        }
        let out = svc.finish().unwrap();
        assert_eq!(out.len(), 150);
    }
}

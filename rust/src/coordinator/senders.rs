//! Epoch-versioned, lock-free sender registry (ISSUE 6 tentpole,
//! part 2) — replaces the old `Arc<Mutex<Vec<Sender<Job>>>>` that
//! every submit had to lock.
//!
//! A [`SenderTable`] is an immutable vec of per-worker
//! [`WorkerSlot`]s, stamped with the routing epoch it corresponds to.
//! The [`SenderRegistry`] publishes the current table through a
//! [`Swap`] — submitters reach it with **one atomic pointer load**
//! ([`SenderRegistry::load`]); the shared table IS the submit-side
//! cache, and "revalidation" is the writer restamping a successor
//! table whenever the routing epoch moves (scale/migration). A
//! submitter that observes `sender_table.epoch() != shard_table.epoch()`
//! has hit the (microseconds-wide) install window; it counts a
//! route-epoch miss and proceeds — the worst case is a stray sample,
//! which the coordinator's stray re-routing already handles.
//!
//! Each [`WorkerSlot`] carries the worker's two ingress queues — the
//! SPSC data ring ([`SpscRing`]) and the bounded control channel — and
//! the [`Doorbell`] that lets the worker sleep without a Condvar on
//! the producers' fast path (one `SeqCst` load per enqueue; the
//! producer only takes the doorbell mutex when the worker is actually
//! parked).

use std::sync::atomic::{fence, AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::ring::{PushOutcome, SpscRing};
use crate::obs::recorder::{record, EventKind, NO_WORKER};
use crate::stream::{bounded, Receiver, SendError, Sender};
use crate::util::swap::Swap;

const RUNNING: u32 = 0;
const PARKED: u32 = 1;

/// How long a parked worker naps before re-checking its queues even
/// without a doorbell ring — a safety net, not the wake mechanism.
const PARK_NAP: Duration = Duration::from_millis(10);

/// Worker sleep/wake rendezvous. Producers pay one atomic load when
/// the worker is awake (the steady state); the mutex+condvar are only
/// touched around actual parking.
#[derive(Debug)]
pub struct Doorbell {
    state: AtomicU32,
    mu: Mutex<()>,
    cv: Condvar,
}

impl Default for Doorbell {
    fn default() -> Self {
        Doorbell {
            state: AtomicU32::new(RUNNING),
            mu: Mutex::new(()),
            cv: Condvar::new(),
        }
    }
}

impl Doorbell {
    /// Wake the worker if it is parked (or about to park). Producer
    /// side; call *after* publishing work.
    pub fn notify(&self) {
        // The fence orders our work-publication before the state load,
        // pairing with the parker's SeqCst state store before its
        // idle check: one of the two sides must see the other.
        fence(Ordering::SeqCst);
        if self.state.load(Ordering::SeqCst) == PARKED {
            let _guard = self.mu.lock().unwrap();
            self.state.store(RUNNING, Ordering::SeqCst);
            self.cv.notify_all();
        }
    }

    /// Park while `idle()` holds and nobody rings. Worker side. The
    /// re-check of `idle` after announcing PARKED (and periodically on
    /// the nap timeout) makes a lost wakeup cost at most one nap.
    pub fn park_while<F: Fn() -> bool>(&self, idle: F) {
        self.state.store(PARKED, Ordering::SeqCst);
        fence(Ordering::SeqCst);
        let mut guard = self.mu.lock().unwrap();
        while self.state.load(Ordering::SeqCst) == PARKED && idle() {
            let (g, _) = self.cv.wait_timeout(guard, PARK_NAP).unwrap();
            guard = g;
        }
        drop(guard);
        self.state.store(RUNNING, Ordering::SeqCst);
    }
}

/// One worker's ingress: the SPSC data ring (fast path), the bounded
/// control channel (strays, migration control, diverted producers),
/// and the doorbell. Immutable once built; shared via `Arc` between
/// the sender table, the service, and the worker thread.
#[derive(Debug)]
pub struct WorkerSlot<T> {
    ring: SpscRing<T>,
    ctl: Sender<T>,
    doorbell: Doorbell,
}

impl<T: Send> WorkerSlot<T> {
    /// Build a slot plus the worker-side receiving end of its control
    /// channel. Ring and channel each get `cap` slots.
    pub fn with_capacity(cap: usize) -> (Arc<Self>, Receiver<T>) {
        let (ctl, rx) = bounded(cap);
        let slot = Arc::new(WorkerSlot {
            ring: SpscRing::new(cap),
            ctl,
            doorbell: Doorbell::default(),
        });
        (slot, rx)
    }

    /// Fast-path publish to the data ring (claims on first use). Rings
    /// the doorbell on success; every other outcome hands the value
    /// back for the caller to divert or retry.
    pub fn try_push(&self, token: u64, value: T) -> PushOutcome<T> {
        let outcome = self.ring.try_push(token, value);
        if matches!(outcome, PushOutcome::Pushed) {
            self.doorbell.notify();
        }
        outcome
    }

    /// Blocking control-channel send + doorbell.
    pub fn send_ctl(&self, value: T) -> Result<(), SendError> {
        self.ctl.send(value)?;
        self.doorbell.notify();
        Ok(())
    }

    /// Non-blocking control-channel send + doorbell (value back when
    /// full, like `Sender::try_send`).
    pub fn try_send_ctl(&self, value: T) -> Result<Option<T>, SendError> {
        match self.ctl.try_send(value)? {
            Some(back) => Ok(Some(back)),
            None => {
                self.doorbell.notify();
                Ok(None)
            }
        }
    }

    /// Blocking control-channel send that hands the value back on
    /// closure (instead of dropping it) + doorbell.
    pub fn send_ctl_reclaim(&self, value: T) -> Result<(), T> {
        self.ctl.send_reclaim(value)?;
        self.doorbell.notify();
        Ok(())
    }

    /// Whether the control channel is at capacity (racy; backpressure
    /// accounting).
    pub fn ctl_is_full(&self) -> bool {
        self.ctl.is_full()
    }

    /// Consumer-side ring pop (worker thread only).
    pub fn pop_ring(&self) -> Option<T> {
        self.ring.pop()
    }

    pub fn ring_is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Racy occupancy of the data ring — an observability gauge (queue
    /// depth per worker), not a synchronization primitive.
    pub fn queue_depth(&self) -> usize {
        self.ring.len()
    }

    /// Ring the doorbell without sending (used by closers).
    pub fn notify(&self) {
        self.doorbell.notify();
    }

    /// Park until the given receiver or the ring has work, or either
    /// closes. Worker side; `rx` must be this slot's receiver.
    pub fn park(&self, rx: &Receiver<T>) {
        self.doorbell.park_while(|| {
            self.ring.is_empty() && rx.is_empty() && !rx.is_closed()
        });
    }

    /// Close just the ring (worker exit path: the control channel's
    /// closure is what *triggered* the exit, or remains open so
    /// producers get a proper error from it).
    pub fn close_ring(&self) {
        self.ring.close();
    }

    /// Full ingress shutdown: control channel and ring both refuse new
    /// work; the worker drains what is buffered and exits. Idempotent.
    pub fn close(&self) {
        self.ctl.close();
        self.ring.close();
        self.doorbell.notify();
    }
}

/// Immutable worker-indexed slot table, stamped with the routing epoch
/// it was installed against.
#[derive(Debug)]
pub struct SenderTable<T> {
    epoch: u64,
    slots: Vec<Arc<WorkerSlot<T>>>,
}

impl<T> SenderTable<T> {
    pub fn new(slots: Vec<Arc<WorkerSlot<T>>>, epoch: u64) -> Self {
        SenderTable { epoch, slots }
    }

    /// Routing epoch this table was stamped for.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn get(&self, worker: usize) -> Option<&Arc<WorkerSlot<T>>> {
        self.slots.get(worker)
    }

    pub fn slots(&self) -> &[Arc<WorkerSlot<T>>] {
        &self.slots
    }
}

/// The shared publication point: one [`Swap`] cell over
/// [`SenderTable`]s. Writers (scale/migration/stop) serialize on the
/// swap's writer lock; readers never lock.
#[derive(Debug)]
pub struct SenderRegistry<T> {
    swap: Swap<SenderTable<T>>,
}

impl<T> SenderRegistry<T> {
    pub fn new(slots: Vec<Arc<WorkerSlot<T>>>, epoch: u64) -> Self {
        SenderRegistry {
            swap: Swap::new(Arc::new(SenderTable::new(slots, epoch))),
        }
    }

    /// The current table: a single atomic load, no lock, no refcount.
    #[inline]
    pub fn load(&self) -> &SenderTable<T> {
        self.swap.load()
    }

    /// Owned handle for control-plane work that outlives a borrow.
    pub fn snapshot(&self) -> Arc<SenderTable<T>> {
        self.swap.snapshot()
    }

    /// Append a worker slot (scale-up). Keeps the current epoch stamp;
    /// the follow-up table install calls [`SenderRegistry::restamp`].
    pub fn push(&self, slot: Arc<WorkerSlot<T>>) {
        self.swap.store_with(|cur| {
            let mut slots = cur.slots.clone();
            slots.push(slot);
            SenderTable::new(slots, cur.epoch)
        });
    }

    /// Drop workers `n..` (scale-down), restamping with the epoch of
    /// the already-installed shrunken routing table. Returns the
    /// retired slots so the caller can send Retire and close them.
    pub fn truncate(&self, n: usize, epoch: u64) -> Vec<Arc<WorkerSlot<T>>> {
        let mut retired = Vec::new();
        self.swap.store_with(|cur| {
            let mut slots = cur.slots.clone();
            retired = slots.split_off(n.min(slots.len()));
            SenderTable::new(slots, epoch)
        });
        retired
    }

    /// Re-publish the same slots under a new routing epoch — the
    /// "cache invalidation" step every table install performs.
    pub fn restamp(&self, epoch: u64) {
        self.swap.store_with(|cur| {
            SenderTable::new(cur.slots.clone(), epoch)
        });
        record(EventKind::EpochSwap, epoch, 0, NO_WORKER);
    }

    /// Publish an empty table (service stop): every subsequent submit
    /// observes `is_empty` and reports the service as stopped.
    pub fn clear(&self) {
        self.swap
            .store_with(|cur| SenderTable::new(Vec::new(), cur.epoch));
    }
}

impl<T> Swap<SenderTable<T>> {
    /// Writer-side helper: derive and install a successor table.
    fn store_with<F>(&self, f: F)
    where
        F: FnOnce(&SenderTable<T>) -> SenderTable<T>,
    {
        let _ = self.rcu::<std::convert::Infallible, _>(|cur| {
            Ok(Arc::new(f(cur)))
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::thread;
    use std::time::Instant;

    #[test]
    fn registry_push_truncate_restamp_follow_epochs() {
        let (s0, _r0) = WorkerSlot::<u64>::with_capacity(4);
        let reg = SenderRegistry::new(vec![s0], 0);
        assert_eq!(reg.load().epoch(), 0);
        assert_eq!(reg.load().len(), 1);

        let (s1, _r1) = WorkerSlot::<u64>::with_capacity(4);
        reg.push(s1);
        assert_eq!(reg.load().len(), 2);
        assert_eq!(reg.load().epoch(), 0, "push keeps the stamp");

        reg.restamp(3);
        assert_eq!(reg.load().epoch(), 3);
        assert_eq!(reg.load().len(), 2);

        let retired = reg.truncate(1, 4);
        assert_eq!(retired.len(), 1);
        assert_eq!(reg.load().len(), 1);
        assert_eq!(reg.load().epoch(), 4);

        reg.clear();
        assert!(reg.load().is_empty());
    }

    #[test]
    fn slot_ring_then_ctl_paths_deliver() {
        let (slot, rx) = WorkerSlot::<u64>::with_capacity(4);
        let tok = crate::coordinator::ring::thread_token();
        assert!(matches!(slot.try_push(tok, 1), PushOutcome::Pushed));
        slot.send_ctl(2).unwrap();
        assert_eq!(rx.try_recv().unwrap(), Some(2));
        assert_eq!(slot.pop_ring(), Some(1));
        assert_eq!(slot.pop_ring(), None);
    }

    #[test]
    fn slot_close_errors_both_planes() {
        let (slot, rx) = WorkerSlot::<u64>::with_capacity(4);
        let tok = crate::coordinator::ring::thread_token();
        slot.close();
        assert!(matches!(
            slot.try_push(tok, 1),
            PushOutcome::Closed(_) | PushOutcome::NoClaim(_)
        ));
        assert_eq!(slot.send_ctl(2), Err(SendError));
        assert!(rx.recv().is_err());
    }

    #[test]
    fn doorbell_wakes_a_parked_thread_promptly() {
        let bell = Arc::new(Doorbell::default());
        let idle = Arc::new(AtomicBool::new(true));
        let parker = {
            let bell = bell.clone();
            let idle = idle.clone();
            thread::spawn(move || {
                let t0 = Instant::now();
                bell.park_while(|| idle.load(Ordering::SeqCst));
                t0.elapsed()
            })
        };
        thread::sleep(Duration::from_millis(30));
        idle.store(false, Ordering::SeqCst);
        bell.notify();
        let parked_for = parker.join().unwrap();
        assert!(
            parked_for >= Duration::from_millis(20),
            "parked only {parked_for:?}"
        );
    }

    #[test]
    fn doorbell_park_skips_when_not_idle() {
        let bell = Doorbell::default();
        let t0 = Instant::now();
        bell.park_while(|| false);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }
}

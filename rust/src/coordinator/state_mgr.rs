//! Per-stream state checkpointing (recovery / migration support).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::engine::Snapshot;

/// One checkpoint of a stream's complete detector state — whatever the
/// backing engine is (software counters, RTL register file, XLA carry,
/// or a full ensemble with per-stream combiner weights).
#[derive(Debug, Clone, PartialEq)]
pub struct StateCheckpoint {
    pub stream_id: u64,
    /// Sequence number of the last sample folded into this snapshot
    /// (the watermark the upstream re-requests samples after).
    pub seq: u64,
    /// Engine-agnostic detector state.
    pub snapshot: Snapshot,
}

/// Thread-safe checkpoint store.
///
/// Engines publish checkpoints every `interval` samples; on failover a
/// new worker restores the newest checkpoint and re-requests samples
/// after `seq` from the source (at-least-once upstream, exactly-once
/// detector state).
#[derive(Debug, Default)]
pub struct StateManager {
    store: Mutex<HashMap<u64, StateCheckpoint>>,
}

impl StateManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish (overwrites an older checkpoint for the stream).
    pub fn publish(&self, cp: StateCheckpoint) {
        let mut store = self.store.lock().unwrap();
        match store.get(&cp.stream_id) {
            Some(prev) if prev.seq >= cp.seq => {} // stale, ignore
            _ => {
                store.insert(cp.stream_id, cp);
            }
        }
    }

    /// Latest checkpoint for a stream.
    pub fn latest(&self, stream_id: u64) -> Option<StateCheckpoint> {
        self.store.lock().unwrap().get(&stream_id).cloned()
    }

    /// Remove a finished stream's checkpoint.
    pub fn evict(&self, stream_id: u64) -> Option<StateCheckpoint> {
        self.store.lock().unwrap().remove(&stream_id)
    }

    /// Number of checkpointed streams.
    pub fn len(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    /// Whether no checkpoints exist.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::teda::TedaDetector;

    fn checkpoint(sid: u64, seq: u64) -> StateCheckpoint {
        let mut det = TedaDetector::new(2, 3.0);
        for i in 0..=seq {
            det.step(&[i as f64 * 0.1, 0.5]);
        }
        StateCheckpoint {
            stream_id: sid,
            seq,
            snapshot: Snapshot::Software(det.snapshot()),
        }
    }

    #[test]
    fn publish_and_restore_roundtrip() {
        let mgr = StateManager::new();
        let cp = checkpoint(1, 9);
        mgr.publish(cp.clone());
        let got = mgr.latest(1).unwrap();
        assert_eq!(got, cp);
        let Snapshot::Software(snap) = got.snapshot else { unreachable!() };
        assert_eq!(snap.state.k, 10);
    }

    #[test]
    fn stale_checkpoints_ignored() {
        let mgr = StateManager::new();
        mgr.publish(checkpoint(1, 20));
        mgr.publish(checkpoint(1, 10)); // older — must not overwrite
        assert_eq!(mgr.latest(1).unwrap().seq, 20);
    }

    #[test]
    fn restored_detector_continues_identically() {
        // A detector restored from a checkpoint must continue exactly
        // like the uninterrupted one — the failover correctness
        // property — with its counters intact, not reset to zero.
        let samples: Vec<Vec<f64>> = (0..50)
            .map(|i| {
                if i == 20 {
                    vec![1e6, -1e6] // mid-prefix outlier bumps the counter
                } else {
                    vec![(i % 9) as f64 * 0.2, 1.0]
                }
            })
            .collect();
        let mut full = TedaDetector::new(2, 3.0);
        for s in &samples[..30] {
            full.step(s);
        }
        assert!(full.n_outliers() > 0, "prefix must contain an outlier");
        let mgr = StateManager::new();
        mgr.publish(StateCheckpoint {
            stream_id: 5,
            seq: 29,
            snapshot: Snapshot::Software(full.snapshot()),
        });
        // "Failover": new detector restores and replays the tail.
        let mut restored = TedaDetector::new(2, 3.0);
        let Snapshot::Software(snap) = mgr.latest(5).unwrap().snapshot
        else {
            unreachable!()
        };
        restored.restore(snap);
        assert_eq!(restored.n_outliers(), full.n_outliers());
        for s in &samples[30..] {
            let a = full.step(s);
            let b = restored.step(s);
            assert_eq!(a, b);
        }
        // Counter equality holds after the tail too.
        assert_eq!(restored.n_outliers(), full.n_outliers());
        assert_eq!(restored.k(), full.k());
    }

    #[test]
    fn evict_removes() {
        let mgr = StateManager::new();
        mgr.publish(checkpoint(3, 1));
        assert_eq!(mgr.len(), 1);
        assert!(mgr.evict(3).is_some());
        assert!(mgr.is_empty());
        assert!(mgr.latest(3).is_none());
    }
}

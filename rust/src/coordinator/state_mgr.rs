//! Per-stream state checkpointing (recovery / migration support).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::Snapshot;
use crate::obs::recorder::{record, EventKind, NO_WORKER};
use crate::persist::CheckpointStore;
use crate::{Error, Result};

/// One checkpoint of a stream's complete detector state — whatever the
/// backing engine is (software counters, RTL register file, XLA carry,
/// or a full ensemble with per-stream combiner weights).
#[derive(Debug, Clone, PartialEq)]
pub struct StateCheckpoint {
    pub stream_id: u64,
    /// Sequence number of the last sample folded into this snapshot
    /// (the watermark the upstream re-requests samples after).
    pub seq: u64,
    /// Engine-agnostic detector state.
    pub snapshot: Snapshot,
}

/// Thread-safe checkpoint store.
///
/// Engines publish checkpoints every `interval` samples; on failover a
/// new worker restores the newest checkpoint and re-requests samples
/// after `seq` from the source (at-least-once upstream, exactly-once
/// detector state).
///
/// With an attached durable [`CheckpointStore`] every accepted publish
/// is also written through (and every eviction propagated), so a
/// full-process death can be recovered by opening the same store and
/// calling [`StateManager::recover`] — that is what
/// `Service::start_from_store` does.
#[derive(Default)]
pub struct StateManager {
    store: Mutex<HashMap<u64, StateCheckpoint>>,
    /// Optional durable write-through backend.
    durable: Option<Arc<dyn CheckpointStore>>,
    /// Durable writes/evictions that failed (publishing stays
    /// non-blocking for the hot path; failures are observable here
    /// instead of wedging the worker).
    persist_errors: AtomicU64,
}

impl std::fmt::Debug for StateManager {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StateManager")
            .field("streams", &self.len())
            .field(
                "durable",
                &self.durable.as_ref().map(|s| s.name()),
            )
            .field("persist_errors", &self.persist_errors())
            .finish()
    }
}

impl StateManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// A manager that writes every accepted checkpoint through to a
    /// durable backend.
    pub fn with_store(durable: Arc<dyn CheckpointStore>) -> Self {
        StateManager { durable: Some(durable), ..Self::default() }
    }

    /// The attached durable backend, if any.
    pub fn durable_store(&self) -> Option<Arc<dyn CheckpointStore>> {
        self.durable.clone()
    }

    /// Durable writes/evictions that failed so far.
    pub fn persist_errors(&self) -> u64 {
        self.persist_errors.load(Ordering::Relaxed)
    }

    /// Publish (overwrites an older checkpoint for the stream).
    pub fn publish(&self, cp: StateCheckpoint) {
        // Clone only when a durable backend will actually consume it —
        // ensemble snapshots (member states, window buffers, open
        // quorums) are not cheap to deep-copy on every interval.
        let to_persist = self.durable.is_some().then(|| cp.clone());
        let stream_id = cp.stream_id;
        let accepted = {
            let mut store = self.store.lock().unwrap();
            match store.get(&cp.stream_id) {
                Some(prev) if prev.seq >= cp.seq => false, // stale, ignore
                _ => {
                    store.insert(cp.stream_id, cp);
                    true
                }
            }
        };
        if accepted {
            record(EventKind::Snapshot, stream_id, 0, NO_WORKER);
        }
        // Durable write-through happens OUTSIDE the map lock: file I/O
        // must not serialize every other worker's publishes.
        if let (true, Some(cp), Some(durable)) =
            (accepted, to_persist, &self.durable)
        {
            if durable.put(&cp).is_err() {
                self.persist_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Latest checkpoint for a stream.
    pub fn latest(&self, stream_id: u64) -> Option<StateCheckpoint> {
        self.store.lock().unwrap().get(&stream_id).cloned()
    }

    /// Remove a finished stream's checkpoint (from the durable backend
    /// too, when one is attached).
    pub fn evict(&self, stream_id: u64) -> Option<StateCheckpoint> {
        let removed = self.store.lock().unwrap().remove(&stream_id);
        if let Some(durable) = &self.durable {
            if durable.evict(stream_id).is_err() {
                self.persist_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        removed
    }

    /// Cold-start recovery: load the newest *valid* checkpoint of every
    /// stream in the durable backend into the in-memory map, skipping
    /// corrupt/truncated tails (the backend falls back to the newest
    /// record that still decodes). Returns the number of streams
    /// recovered. Errors only on store-level failures (unreadable
    /// directory), never on individual corrupt records.
    pub fn recover(&self) -> Result<usize> {
        let durable = self.durable.as_ref().ok_or_else(|| {
            Error::Persist(
                "recover() needs a durable store (StateManager::with_store)"
                    .into(),
            )
        })?;
        let mut recovered = 0;
        for stream_id in durable.streams()? {
            if let Some(cp) = durable.latest(stream_id)? {
                self.store.lock().unwrap().insert(stream_id, cp);
                recovered += 1;
            }
        }
        Ok(recovered)
    }

    /// Checkpointed stream ids, ascending (diagnostics — e.g. the
    /// `rebalance` smoke reports which streams hold seal/periodic
    /// watermarks after a churn run).
    pub fn stream_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> =
            self.store.lock().unwrap().keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Number of checkpointed streams.
    pub fn len(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    /// Whether no checkpoints exist.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::teda::TedaDetector;

    fn checkpoint(sid: u64, seq: u64) -> StateCheckpoint {
        let mut det = TedaDetector::new(2, 3.0);
        for i in 0..=seq {
            det.step(&[i as f64 * 0.1, 0.5]);
        }
        StateCheckpoint {
            stream_id: sid,
            seq,
            snapshot: Snapshot::Software(det.snapshot()),
        }
    }

    #[test]
    fn publish_and_restore_roundtrip() {
        let mgr = StateManager::new();
        let cp = checkpoint(1, 9);
        mgr.publish(cp.clone());
        let got = mgr.latest(1).unwrap();
        assert_eq!(got, cp);
        let Snapshot::Software(snap) = got.snapshot else { unreachable!() };
        assert_eq!(snap.state.k, 10);
    }

    #[test]
    fn stale_checkpoints_ignored() {
        let mgr = StateManager::new();
        mgr.publish(checkpoint(1, 20));
        mgr.publish(checkpoint(1, 10)); // older — must not overwrite
        assert_eq!(mgr.latest(1).unwrap().seq, 20);
    }

    #[test]
    fn restored_detector_continues_identically() {
        // A detector restored from a checkpoint must continue exactly
        // like the uninterrupted one — the failover correctness
        // property — with its counters intact, not reset to zero.
        let samples: Vec<Vec<f64>> = (0..50)
            .map(|i| {
                if i == 20 {
                    vec![1e6, -1e6] // mid-prefix outlier bumps the counter
                } else {
                    vec![(i % 9) as f64 * 0.2, 1.0]
                }
            })
            .collect();
        let mut full = TedaDetector::new(2, 3.0);
        for s in &samples[..30] {
            full.step(s);
        }
        assert!(full.n_outliers() > 0, "prefix must contain an outlier");
        let mgr = StateManager::new();
        mgr.publish(StateCheckpoint {
            stream_id: 5,
            seq: 29,
            snapshot: Snapshot::Software(full.snapshot()),
        });
        // "Failover": new detector restores and replays the tail.
        let mut restored = TedaDetector::new(2, 3.0);
        let Snapshot::Software(snap) = mgr.latest(5).unwrap().snapshot
        else {
            unreachable!()
        };
        restored.restore(snap);
        assert_eq!(restored.n_outliers(), full.n_outliers());
        for s in &samples[30..] {
            let a = full.step(s);
            let b = restored.step(s);
            assert_eq!(a, b);
        }
        // Counter equality holds after the tail too.
        assert_eq!(restored.n_outliers(), full.n_outliers());
        assert_eq!(restored.k(), full.k());
    }

    #[test]
    fn stream_ids_sorted() {
        let mgr = StateManager::new();
        mgr.publish(checkpoint(9, 1));
        mgr.publish(checkpoint(2, 1));
        mgr.publish(checkpoint(5, 1));
        assert_eq!(mgr.stream_ids(), vec![2, 5, 9]);
    }

    #[test]
    fn evict_removes() {
        let mgr = StateManager::new();
        mgr.publish(checkpoint(3, 1));
        assert_eq!(mgr.len(), 1);
        assert!(mgr.evict(3).is_some());
        assert!(mgr.is_empty());
        assert!(mgr.latest(3).is_none());
    }

    #[test]
    fn publish_writes_through_to_the_durable_store() {
        let store = Arc::new(crate::persist::MemoryStore::new());
        let mgr = StateManager::with_store(store.clone());
        mgr.publish(checkpoint(1, 9));
        mgr.publish(checkpoint(1, 19));
        mgr.publish(checkpoint(1, 4)); // stale — must NOT reach the store
        assert_eq!(store.records_for(1), 2);
        assert_eq!(store.latest(1).unwrap().unwrap().seq, 19);
        assert_eq!(mgr.persist_errors(), 0);
    }

    #[test]
    fn evict_propagates_to_the_durable_store() {
        let store = Arc::new(crate::persist::MemoryStore::new());
        let mgr = StateManager::with_store(store.clone());
        mgr.publish(checkpoint(7, 5));
        assert!(mgr.evict(7).is_some());
        assert!(store.latest(7).unwrap().is_none());
        assert!(store.streams().unwrap().is_empty());
    }

    #[test]
    fn recover_loads_the_newest_checkpoint_per_stream() {
        let store = Arc::new(crate::persist::MemoryStore::new());
        {
            // "First process": publishes, then dies (dropped).
            let mgr = StateManager::with_store(store.clone());
            mgr.publish(checkpoint(1, 19));
            mgr.publish(checkpoint(1, 39));
            mgr.publish(checkpoint(2, 9));
        }
        // "Second process": empty manager over the same store.
        let mgr = StateManager::with_store(store);
        assert!(mgr.is_empty());
        assert_eq!(mgr.recover().unwrap(), 2);
        assert_eq!(mgr.latest(1).unwrap().seq, 39);
        assert_eq!(mgr.latest(2).unwrap().seq, 9);
        // The recovered snapshot is byte-for-byte the published one.
        assert_eq!(mgr.latest(1).unwrap(), checkpoint(1, 39));
    }

    #[test]
    fn recover_without_a_store_is_an_error() {
        assert!(StateManager::new().recover().is_err());
    }
}

//! Versioned shard map — stream → virtual shard → worker routing.
//!
//! PRs 0–4 pinned every stream to a worker with a static
//! `fnv1a(stream_id) % workers` at startup, so one hot shard capped the
//! whole service and the worker count could never change while serving.
//! This module replaces that with the classic two-level scheme:
//!
//! 1. `stream_id` hashes to one of a **fixed** number of virtual shards
//!    ([`shard_of`]; the count never changes for the lifetime of a
//!    service, so the stream → shard mapping is immutable and needs no
//!    coordination), and
//! 2. an **epoch-numbered** shard → worker assignment table
//!    ([`ShardTable`]) that CAN change: migrations and worker scaling
//!    install a successor table (epoch + 1) into the shared
//!    [`ShardMap`], and every submitter picks it up on its next route.
//!
//! Readers route against [`ShardMap::load`] — since ISSUE 6 a **single
//! atomic pointer load** (the hand-rolled arc-swap in
//! [`crate::util::swap::Swap`]), so the steady-state submit path takes
//! no lock at all. A borrow or [`ShardMap::snapshot`] held across a
//! swap is *detectably* stale (its epoch lags), which is what the
//! coordinator's stray-sample forwarding keys off.

use std::sync::Arc;

use crate::util::propkit::fnv1a;
use crate::util::swap::Swap;
use crate::{Error, Result};

/// Default virtual shard count: enough granularity to balance hundreds
/// of workers, small enough that per-shard gauges stay cheap.
pub const DEFAULT_VIRTUAL_SHARDS: u32 = 256;

/// Immutable stream → virtual shard mapping (FNV-1a over the
/// little-endian stream id, like the old router, then mod the fixed
/// shard count). Deterministic across runs and processes, so
/// checkpoints and shard diagnostics agree between incarnations.
#[inline]
pub fn shard_of(stream_id: u64, virtual_shards: u32) -> u32 {
    debug_assert!(virtual_shards > 0);
    (fnv1a(&stream_id.to_le_bytes()) % virtual_shards as u64) as u32
}

/// One epoch of the shard → worker assignment.
///
/// Tables are immutable once built; mutation is modeled as building a
/// successor (epoch + 1) via [`ShardTable::with_moves`] /
/// [`ShardTable::with_workers`] and installing it into the shared
/// [`ShardMap`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardTable {
    epoch: u64,
    /// Worker index per shard (`assignment[shard] = worker`).
    assignment: Vec<u32>,
    workers: usize,
}

impl ShardTable {
    /// Epoch-0 table spreading shards round-robin across `workers`.
    ///
    /// # Panics
    /// Panics when `virtual_shards == 0` or `workers == 0`.
    pub fn new_uniform(virtual_shards: u32, workers: usize) -> Self {
        assert!(virtual_shards > 0, "need at least one virtual shard");
        assert!(workers > 0, "need at least one worker");
        ShardTable {
            epoch: 0,
            assignment: (0..virtual_shards)
                .map(|s| (s as usize % workers) as u32)
                .collect(),
            workers,
        }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn virtual_shards(&self) -> u32 {
        self.assignment.len() as u32
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Worker currently owning a shard.
    #[inline]
    pub fn worker_of(&self, shard: u32) -> usize {
        self.assignment[shard as usize] as usize
    }

    /// Virtual shard of a stream (table-local shard count).
    #[inline]
    pub fn shard_of(&self, stream_id: u64) -> u32 {
        shard_of(stream_id, self.virtual_shards())
    }

    /// Full route: `(worker, shard)` for a stream.
    #[inline]
    pub fn route(&self, stream_id: u64) -> (usize, u32) {
        let shard = self.shard_of(stream_id);
        (self.worker_of(shard), shard)
    }

    /// Shards owned by one worker, ascending.
    pub fn shards_on(&self, worker: usize) -> Vec<u32> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &w)| w as usize == worker)
            .map(|(s, _)| s as u32)
            .collect()
    }

    /// Shards per worker.
    pub fn shard_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.workers];
        for &w in &self.assignment {
            counts[w as usize] += 1;
        }
        counts
    }

    /// Distribution diagnostic: per-WORKER stream counts for a set of
    /// ids (the old `Router::load`).
    pub fn load(&self, stream_ids: impl Iterator<Item = u64>) -> Vec<usize> {
        let mut counts = vec![0usize; self.workers];
        for sid in stream_ids {
            counts[self.route(sid).0] += 1;
        }
        counts
    }

    /// Per-SHARD stream counts for a set of ids.
    pub fn shard_load(
        &self,
        stream_ids: impl Iterator<Item = u64>,
    ) -> Vec<usize> {
        let mut counts = vec![0usize; self.assignment.len()];
        for sid in stream_ids {
            counts[self.shard_of(sid) as usize] += 1;
        }
        counts
    }

    /// Successor table (epoch + 1) with `moves` applied and the worker
    /// count set to `workers` (≥ every move target + 1; pass the
    /// current count for plain migrations).
    pub fn with_moves(
        &self,
        moves: &[(u32, usize)],
        workers: usize,
    ) -> Result<ShardTable> {
        if workers == 0 {
            return Err(Error::Stream("shard table needs ≥ 1 worker".into()));
        }
        let mut assignment = self.assignment.clone();
        for &(shard, to) in moves {
            let slot = assignment.get_mut(shard as usize).ok_or_else(|| {
                Error::Stream(format!(
                    "shard {shard} out of range (virtual_shards = {})",
                    self.assignment.len()
                ))
            })?;
            if to >= workers {
                return Err(Error::Stream(format!(
                    "shard {shard} → worker {to}, but only {workers} \
                     workers exist"
                )));
            }
            *slot = to as u32;
        }
        if let Some(&w) = assignment.iter().find(|&&w| w as usize >= workers)
        {
            return Err(Error::Stream(format!(
                "worker {w} still owns shards but the table is shrinking \
                 to {workers} workers — migrate its shards first"
            )));
        }
        Ok(ShardTable { epoch: self.epoch + 1, assignment, workers })
    }

    /// Successor table (epoch + 1) that only changes the worker count.
    /// Shrinking requires every retired worker to be shard-free.
    pub fn with_workers(&self, workers: usize) -> Result<ShardTable> {
        self.with_moves(&[], workers)
    }

    /// Minimal-movement rebalance onto `new_workers` workers: shards on
    /// retired workers (index ≥ `new_workers`) all move; surviving
    /// workers then donate their surplus to whoever is below the
    /// balanced share. Returns the move list (may be empty) —
    /// deterministic, so two incarnations plan identically.
    pub fn rebalance_moves(&self, new_workers: usize) -> Vec<(u32, usize)> {
        if new_workers == 0 {
            return Vec::new();
        }
        let vs = self.assignment.len();
        let base = vs / new_workers;
        let extra = vs % new_workers; // workers 0..extra get base + 1
        let target =
            |w: usize| if w < extra { base + 1 } else { base };
        let mut counts = vec![0usize; new_workers];
        // Shards stranded on retired workers move unconditionally.
        let mut homeless: Vec<u32> = Vec::new();
        for (s, &w) in self.assignment.iter().enumerate() {
            if (w as usize) < new_workers {
                counts[w as usize] += 1;
            } else {
                homeless.push(s as u32);
            }
        }
        // Surviving workers donate their surplus (highest shard ids
        // first — any choice works; this one is deterministic).
        for w in 0..new_workers.min(self.workers) {
            let mut surplus = counts[w].saturating_sub(target(w));
            if surplus == 0 {
                continue;
            }
            for (s, &owner) in self.assignment.iter().enumerate().rev() {
                if surplus == 0 {
                    break;
                }
                if owner as usize == w {
                    homeless.push(s as u32);
                    counts[w] -= 1;
                    surplus -= 1;
                }
            }
        }
        homeless.sort_unstable();
        // Hand the pool to whoever is below target, lowest index first.
        let mut moves = Vec::with_capacity(homeless.len());
        let mut next = 0usize;
        for shard in homeless {
            while counts[next] >= target(next) {
                next = (next + 1) % new_workers;
            }
            counts[next] += 1;
            moves.push((shard, next));
        }
        // Drop no-op moves (a "homeless" shard can land back home when
        // the donor was only just above target).
        moves
            .into_iter()
            .filter(|&(s, to)| self.assignment[s as usize] as usize != to)
            .collect()
    }
}

/// The shared, swappable routing state: submitters and workers hold an
/// `Arc<ShardMap>` and route against [`ShardMap::load`] (one atomic
/// load) or take an owned [`ShardMap::snapshot`]; the rebalancer
/// installs successor tables with [`ShardMap::install`], still
/// strictly epoch-ordered (the check runs under the swap's writer
/// lock, which only installers touch).
#[derive(Debug)]
pub struct ShardMap {
    current: Swap<ShardTable>,
}

impl ShardMap {
    pub fn new(table: ShardTable) -> Self {
        ShardMap { current: Swap::new(Arc::new(table)) }
    }

    /// The current table as a borrow — the zero-overhead hot path for
    /// routing. The borrow stays readable across concurrent installs
    /// (retention in [`Swap`]) but its epoch then lags.
    #[inline]
    pub fn load(&self) -> &ShardTable {
        self.current.load()
    }

    /// Owned consistent snapshot of the current table (lock-free: one
    /// pointer load + refcount bump).
    pub fn snapshot(&self) -> Arc<ShardTable> {
        self.current.snapshot()
    }

    /// Current epoch.
    pub fn epoch(&self) -> u64 {
        self.load().epoch
    }

    /// Install a successor table. The epoch must strictly advance —
    /// concurrent rebalancers racing each other is a bug, not a merge.
    pub fn install(&self, table: ShardTable) -> Result<Arc<ShardTable>> {
        self.current.rcu(|cur| {
            if table.epoch <= cur.epoch {
                return Err(Error::Stream(format!(
                    "shard map epoch must advance (current {}, offered {})",
                    cur.epoch, table.epoch
                )));
            }
            Ok(Arc::new(table))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for sid in 0..1000u64 {
            assert_eq!(shard_of(sid, 256), shard_of(sid, 256));
            assert!(shard_of(sid, 256) < 256);
            assert!(shard_of(sid, 7) < 7);
        }
    }

    #[test]
    fn uniform_table_routes_stably_and_covers_all_workers() {
        let t = ShardTable::new_uniform(256, 4);
        assert_eq!(t.epoch(), 0);
        assert_eq!(t.workers(), 4);
        assert_eq!(t.shard_counts(), vec![64; 4]);
        for sid in 0..100u64 {
            assert_eq!(t.route(sid), t.route(sid));
            assert!(t.route(sid).0 < 4);
        }
    }

    #[test]
    fn stream_distribution_roughly_uniform() {
        let t = ShardTable::new_uniform(256, 8);
        let load = t.load(0..8000);
        // each worker should get 1000 ± 35%
        for (w, &c) in load.iter().enumerate() {
            assert!(c > 650 && c < 1350, "worker {w}: {c}");
        }
        assert_eq!(t.shard_load(0..8000).iter().sum::<usize>(), 8000);
    }

    #[test]
    fn single_worker_takes_all() {
        let t = ShardTable::new_uniform(16, 1);
        assert_eq!(t.load(0..50), vec![50]);
    }

    #[test]
    #[should_panic(expected = "at least one worker")]
    fn zero_workers_rejected() {
        ShardTable::new_uniform(16, 0);
    }

    #[test]
    fn moves_advance_the_epoch_and_reroute() {
        let t = ShardTable::new_uniform(8, 2);
        let shard = t.shard_of(42);
        let old_worker = t.worker_of(shard);
        let to = 1 - old_worker;
        let t2 = t.with_moves(&[(shard, to)], 2).unwrap();
        assert_eq!(t2.epoch(), 1);
        assert_eq!(t2.worker_of(shard), to);
        assert_eq!(t2.route(42).0, to);
        // Everything else is untouched.
        for s in 0..8u32 {
            if s != shard {
                assert_eq!(t2.worker_of(s), t.worker_of(s));
            }
        }
    }

    #[test]
    fn invalid_moves_rejected() {
        let t = ShardTable::new_uniform(8, 2);
        assert!(t.with_moves(&[(99, 0)], 2).is_err()); // no such shard
        assert!(t.with_moves(&[(0, 5)], 2).is_err()); // no such worker
        // Shrinking under a still-loaded worker is rejected.
        assert!(t.with_workers(1).is_err());
        assert!(t.with_workers(0).is_err());
    }

    #[test]
    fn rebalance_moves_grow_is_minimal_and_balanced() {
        let t = ShardTable::new_uniform(256, 4);
        let moves = t.rebalance_moves(8);
        // Growing 4 → 8 must move exactly half the shards.
        assert_eq!(moves.len(), 128);
        let t2 = t.with_moves(&moves, 8).unwrap();
        assert_eq!(t2.shard_counts(), vec![32; 8]);
        // And only to the new workers (no churn among survivors).
        for &(_, to) in &moves {
            assert!(to >= 4, "grow moved a shard between survivors");
        }
    }

    #[test]
    fn rebalance_moves_shrink_empties_retired_workers() {
        let t = ShardTable::new_uniform(256, 8);
        let moves = t.rebalance_moves(3);
        let t2 = t.with_moves(&moves, 3).unwrap();
        let counts = t2.shard_counts();
        assert_eq!(counts.iter().sum::<usize>(), 256);
        assert!(counts.iter().all(|&c| c == 85 || c == 86), "{counts:?}");
    }

    #[test]
    fn rebalance_moves_noop_when_already_balanced() {
        let t = ShardTable::new_uniform(256, 4);
        assert!(t.rebalance_moves(4).is_empty());
    }

    #[test]
    fn rebalance_handles_non_dividing_counts() {
        let t = ShardTable::new_uniform(10, 3);
        let moves = t.rebalance_moves(4);
        let t2 = t.with_moves(&moves, 4).unwrap();
        let counts = t2.shard_counts();
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert!(counts.iter().all(|&c| c == 2 || c == 3), "{counts:?}");
    }

    #[test]
    fn map_snapshot_and_install() {
        let map = ShardMap::new(ShardTable::new_uniform(8, 2));
        let snap0 = map.snapshot();
        assert_eq!(snap0.epoch(), 0);
        let t1 = snap0.with_moves(&[(0, 1)], 2).unwrap();
        map.install(t1).unwrap();
        assert_eq!(map.epoch(), 1);
        // The old snapshot is stale but still readable (and detectably
        // behind).
        assert!(snap0.epoch() < map.epoch());
        // Epochs must strictly advance.
        let stale = snap0.with_moves(&[(1, 1)], 2).unwrap(); // epoch 1 again
        assert!(map.install(stale).is_err());
    }

    #[test]
    fn load_borrow_survives_install_and_lags_detectably() {
        // The lock-free read path: a borrow taken before an install
        // stays readable (arc-swap retention) and is detectably stale,
        // while fresh loads see the new epoch immediately.
        let map = ShardMap::new(ShardTable::new_uniform(8, 2));
        let before = map.load();
        assert_eq!(before.epoch(), 0);
        let t1 = before.with_workers(3).unwrap();
        map.install(t1).unwrap();
        assert_eq!(before.epoch(), 0, "old borrow unchanged");
        assert_eq!(map.load().epoch(), 1);
        assert_eq!(map.load().workers(), 3);
        // Routing through the borrow still works (stale but coherent).
        for sid in 0..50u64 {
            assert!(before.route(sid).0 < 2);
            assert!(map.load().route(sid).0 < 3);
        }
    }
}

//! Bounded single-producer/single-consumer ring buffer — the
//! zero-mutex data plane between a submitter thread and its worker.
//!
//! Design (ISSUE 6 tentpole, part 3): the common deployment shape is
//! one ingest thread feeding each worker, so the hot path should be a
//! wait-free array write, not a Mutex+Condvar rendezvous. The ring
//! keeps the *channel semantics* the coordinator already relies on by
//! sitting in front of the bounded control channel, never replacing
//! it:
//!
//! * **Sticky producer claim.** The first thread to push becomes the
//!   ring's sole producer ([`SpscRing::try_push`] claims via a
//!   compare-exchange on a per-thread token, then sticks). Every other
//!   thread is diverted to the worker's control channel. The claim is
//!   what upholds the per-stream ordering contract: a single external
//!   producer for a stream either always rings (order = ring order) or
//!   always channels (order = channel FIFO); it is never split across
//!   both queues with older items trapped behind newer ones.
//! * **Counted backpressure.** A full ring returns the value to the
//!   caller (like `try_send`), so the service can count the event and
//!   spin-wait exactly as the blocking channel send would.
//! * **Close protocol.** [`SpscRing::close`] (idempotent, any thread)
//!   marks the ring closed and then waits out any in-flight push, so
//!   after it returns the consumer's final drain observes every item
//!   that will ever be published. A producer that loses the race sees
//!   `Closed` and falls back to the control channel, whose own closure
//!   reports the error properly.
//!
//! Memory ordering: `tail` is published with `Release` and read by the
//! consumer with `Acquire` (and vice versa for `head`), the classic
//! Lamport SPSC scheme. The close/pushing handshake uses `SeqCst` so
//! the store-buffer interleaving ("both sides miss each other") is
//! impossible.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

/// Claim slot value meaning "no producer yet".
const FREE: u64 = 0;
/// Claim slot value meaning "ring closed, claims impossible".
const CLOSED: u64 = u64::MAX;

static NEXT_TOKEN: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TOKEN: u64 = NEXT_TOKEN.fetch_add(1, Ordering::Relaxed);
}

/// This thread's ring-claim token: process-unique, never `FREE` or
/// `CLOSED`. Cheap after first use (a thread-local read).
pub fn thread_token() -> u64 {
    TOKEN.with(|t| *t)
}

/// Outcome of a [`SpscRing::try_push`]. Every non-`Pushed` variant
/// returns the value so the caller can re-route it.
#[derive(Debug)]
pub enum PushOutcome<T> {
    /// Published; the consumer will see it.
    Pushed,
    /// Ring at capacity — retry or divert (backpressure).
    Full(T),
    /// Ring closed — divert to the control channel.
    Closed(T),
    /// Another thread holds the producer claim — divert.
    NoClaim(T),
}

/// Pad the cursors to (at least) a cache line each so producer and
/// consumer do not false-share.
#[repr(align(64))]
struct CachePadded<T>(T);

/// The ring. `T: Send` is required to move values across the
/// producer/consumer thread boundary; the `UnsafeCell` slots are safe
/// because the head/tail cursors give each slot a unique owner at any
/// instant (producer between reserve and publish, consumer between
/// observe and release).
pub struct SpscRing<T> {
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    cap: usize,
    /// Consumer cursor: next slot to pop. Written only by the consumer.
    head: CachePadded<AtomicUsize>,
    /// Producer cursor: next slot to fill. Written only by the
    /// claimant.
    tail: CachePadded<AtomicUsize>,
    /// Sticky producer claim: `FREE`, a thread token, or `CLOSED`.
    claimant: AtomicU64,
    /// True while the claimant is inside the push window (reserve →
    /// publish). `close` spins this out so no item is published after
    /// the final drain.
    pushing: AtomicBool,
    closed: AtomicBool,
}

// SAFETY: values of T only move across threads (producer writes,
// consumer reads), which is exactly what `T: Send` licenses. The
// cursor protocol ensures no slot is accessed by both sides at once.
unsafe impl<T: Send> Send for SpscRing<T> {}
unsafe impl<T: Send> Sync for SpscRing<T> {}

impl<T> SpscRing<T> {
    /// A ring holding up to `cap` items (≥ 1).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "ring capacity must be >= 1");
        let slots = (0..cap)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        SpscRing {
            slots,
            cap,
            head: CachePadded(AtomicUsize::new(0)),
            tail: CachePadded(AtomicUsize::new(0)),
            claimant: AtomicU64::new(FREE),
            pushing: AtomicBool::new(false),
            closed: AtomicBool::new(false),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Racy item count (diagnostics).
    pub fn len(&self) -> usize {
        let tail = self.tail.0.load(Ordering::Acquire);
        let head = self.head.0.load(Ordering::Acquire);
        tail.wrapping_sub(head)
    }

    /// Racy emptiness check — used by the worker park predicate, whose
    /// doorbell re-check protocol tolerates the race.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Attempt to publish `value` as the producer identified by
    /// `token` (from [`thread_token`]). Claims the ring on first use;
    /// after a claim succeeds the same thread keeps it until close.
    pub fn try_push(&self, token: u64, value: T) -> PushOutcome<T> {
        let holder = self.claimant.load(Ordering::Acquire);
        let claimed = holder == token
            || (holder == FREE
                && self
                    .claimant
                    .compare_exchange(
                        FREE,
                        token,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    )
                    .is_ok());
        if !claimed {
            return PushOutcome::NoClaim(value);
        }
        // Push window: once `pushing` is up, `close` waits for us. The
        // re-check of `closed` inside the window closes the race where
        // close lands between the claim check and the publish.
        self.pushing.store(true, Ordering::SeqCst);
        if self.closed.load(Ordering::SeqCst) {
            self.pushing.store(false, Ordering::Release);
            return PushOutcome::Closed(value);
        }
        let tail = self.tail.0.load(Ordering::Relaxed);
        let head = self.head.0.load(Ordering::Acquire);
        if tail.wrapping_sub(head) >= self.cap {
            self.pushing.store(false, Ordering::Release);
            return PushOutcome::Full(value);
        }
        // SAFETY: slot `tail % cap` is outside the consumer's visible
        // range until the tail store below publishes it.
        unsafe {
            (*self.slots[tail % self.cap].get()).write(value);
        }
        self.tail.0.store(tail.wrapping_add(1), Ordering::Release);
        self.pushing.store(false, Ordering::Release);
        PushOutcome::Pushed
    }

    /// Pop the oldest item. Consumer side only — exactly one thread
    /// (the worker) may call this.
    pub fn pop(&self) -> Option<T> {
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        // SAFETY: the producer published slot `head % cap` via the
        // Release store of `tail` we just Acquired, and will not touch
        // it again until the head store below recycles it.
        let value = unsafe {
            (*self.slots[head % self.cap].get()).assume_init_read()
        };
        self.head.0.store(head.wrapping_add(1), Ordering::Release);
        Some(value)
    }

    /// Close the ring: no item is published after this returns, so a
    /// follow-up [`SpscRing::pop`] drain is complete. Items already
    /// published remain poppable. Idempotent; callable from any
    /// thread (worker exit, service stop, panic cleanup).
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.claimant.store(CLOSED, Ordering::SeqCst);
        // Wait out an in-flight push: it either saw `closed` and
        // aborted, or its publish completes before `pushing` drops.
        while self.pushing.load(Ordering::SeqCst) {
            std::hint::spin_loop();
        }
    }
}

impl<T> Drop for SpscRing<T> {
    fn drop(&mut self) {
        // Drop any items never popped (e.g. abort paths).
        while self.pop().is_some() {}
    }
}

impl<T> std::fmt::Debug for SpscRing<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpscRing")
            .field("cap", &self.cap)
            .field("len", &self.len())
            .field("closed", &self.is_closed())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    fn push_ok<T>(ring: &SpscRing<T>, token: u64, v: T) {
        match ring.try_push(token, v) {
            PushOutcome::Pushed => {}
            other => panic!("expected Pushed, got {other:?}"),
        }
    }

    #[test]
    fn tokens_are_unique_per_thread_and_stable() {
        let a = thread_token();
        assert_eq!(a, thread_token());
        let b = thread::spawn(thread_token).join().unwrap();
        assert_ne!(a, b);
        assert_ne!(a, FREE);
        assert_ne!(a, CLOSED);
    }

    #[test]
    fn wraparound_preserves_fifo() {
        // Capacity 4, 100 items: the cursors wrap many times and every
        // item must come out once, in order.
        let ring = SpscRing::new(4);
        let tok = thread_token();
        let mut next_pop = 0u64;
        for i in 0..100u64 {
            push_ok(&ring, tok, i);
            if ring.len() == 4 {
                for _ in 0..4 {
                    assert_eq!(ring.pop(), Some(next_pop));
                    next_pop += 1;
                }
            }
        }
        while let Some(v) = ring.pop() {
            assert_eq!(v, next_pop);
            next_pop += 1;
        }
        assert_eq!(next_pop, 100);
    }

    #[test]
    fn full_ring_returns_the_value() {
        let ring = SpscRing::new(2);
        let tok = thread_token();
        push_ok(&ring, tok, 1);
        push_ok(&ring, tok, 2);
        match ring.try_push(tok, 3) {
            PushOutcome::Full(v) => assert_eq!(v, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(ring.pop(), Some(1));
        push_ok(&ring, tok, 3); // slot freed
        assert_eq!(ring.pop(), Some(2));
        assert_eq!(ring.pop(), Some(3));
        assert_eq!(ring.pop(), None);
    }

    #[test]
    fn second_producer_is_diverted() {
        let ring = SpscRing::new(8);
        let tok = thread_token();
        push_ok(&ring, tok, 1u32);
        match ring.try_push(tok + 1, 2) {
            PushOutcome::NoClaim(v) => assert_eq!(v, 2),
            other => panic!("expected NoClaim, got {other:?}"),
        }
        // The claimant itself keeps pushing fine.
        push_ok(&ring, tok, 3);
    }

    #[test]
    fn close_rejects_pushes_and_claims_but_drains() {
        let ring = SpscRing::new(8);
        let tok = thread_token();
        push_ok(&ring, tok, 1u32);
        push_ok(&ring, tok, 2);
        ring.close();
        ring.close(); // idempotent
        match ring.try_push(tok, 3) {
            PushOutcome::NoClaim(v) | PushOutcome::Closed(v) => {
                assert_eq!(v, 3);
            }
            other => panic!("expected rejection, got {other:?}"),
        }
        assert_eq!(ring.pop(), Some(1));
        assert_eq!(ring.pop(), Some(2));
        assert_eq!(ring.pop(), None);
    }

    #[test]
    fn cross_thread_transfer_is_complete_and_ordered() {
        const N: u64 = 100_000;
        let ring = Arc::new(SpscRing::new(64));
        let producer = {
            let ring = ring.clone();
            thread::spawn(move || {
                let tok = thread_token();
                let mut backoffs = 0u64;
                for i in 0..N {
                    let mut v = i;
                    loop {
                        match ring.try_push(tok, v) {
                            PushOutcome::Pushed => break,
                            PushOutcome::Full(back) => {
                                v = back;
                                backoffs += 1;
                                thread::yield_now();
                            }
                            other => panic!("unexpected {other:?}"),
                        }
                    }
                }
                backoffs
            })
        };
        let mut got = 0u64;
        while got < N {
            if let Some(v) = ring.pop() {
                assert_eq!(v, got, "out of order");
                got += 1;
            } else {
                thread::yield_now();
            }
        }
        // A 64-slot ring carrying 100k items must have hit Full at
        // least occasionally OR the consumer kept pace — either way
        // the count above is the real assertion; just join here.
        let _ = producer.join().unwrap();
        assert_eq!(ring.pop(), None);
    }

    #[test]
    fn drop_releases_unpopped_items() {
        let sentinel = Arc::new(());
        {
            let ring = SpscRing::new(8);
            let tok = thread_token();
            for _ in 0..5 {
                push_ok(&ring, tok, sentinel.clone());
            }
            assert_eq!(Arc::strong_count(&sentinel), 6);
        }
        assert_eq!(Arc::strong_count(&sentinel), 1);
    }
}

//! RTL engine: one cycle-accurate hardware pipeline per stream.

use std::collections::HashMap;

use crate::rtl::TedaRtl;
use crate::stream::Sample;
use crate::Result;

use super::{runs, Engine, EngineVerdict, Snapshot};

/// Per-stream pipeline instance (the "multiple TEDA modules in
/// parallel" deployment of §5.2.1, one module per stream).
pub struct RtlEngine {
    n_features: usize,
    m: f32,
    streams: HashMap<u64, TedaRtl>,
    /// Reusable f64 → f32 input latch: one conversion buffer for every
    /// clock instead of a fresh `Vec<f32>` per sample.
    x32: Vec<f32>,
}

impl RtlEngine {
    pub fn new(n_features: usize, m: f64) -> Self {
        RtlEngine {
            n_features,
            m: m as f32,
            streams: HashMap::new(),
            x32: Vec::new(),
        }
    }
}

impl Engine for RtlEngine {
    fn name(&self) -> &'static str {
        "rtl"
    }

    fn ingest(&mut self, sample: &Sample) -> Result<Vec<EngineVerdict>> {
        let (n, m) = (self.n_features, self.m);
        let x32 = &mut self.x32;
        let rtl = match self.streams.entry(sample.stream_id) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(TedaRtl::new(n, m)?)
            }
        };
        x32.clear();
        x32.extend(sample.values.iter().map(|&v| v as f32));
        // The pipeline emits the verdict for sample k−2; its k identifies
        // the seq (streams start at seq 0 ⇒ seq = k − 1).
        Ok(match rtl.clock(x32)? {
            Some(v) => vec![EngineVerdict {
                stream_id: sample.stream_id,
                seq: v.k - 1,
                k: v.k,
                eccentricity: v.eccentricity as f64,
                zeta: v.zeta as f64,
                threshold: v.threshold as f64,
                outlier: v.outlier,
            }],
            None => Vec::new(),
        })
    }

    fn process_batch(
        &mut self,
        samples: &[Sample],
        out: &mut Vec<EngineVerdict>,
    ) -> Result<()> {
        let (n, m) = (self.n_features, self.m);
        let x32 = &mut self.x32;
        for run in runs(samples) {
            let sid = run[0].stream_id;
            // One pipeline resolution per run, then clock the whole run
            // through without re-dispatching per sample.
            let rtl = match self.streams.entry(sid) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    e.into_mut()
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(TedaRtl::new(n, m)?)
                }
            };
            for sample in run {
                x32.clear();
                x32.extend(sample.values.iter().map(|&v| v as f32));
                if let Some(v) = rtl.clock(x32)? {
                    out.push(EngineVerdict {
                        stream_id: sid,
                        seq: v.k - 1,
                        k: v.k,
                        eccentricity: v.eccentricity as f64,
                        zeta: v.zeta as f64,
                        threshold: v.threshold as f64,
                        outlier: v.outlier,
                    });
                }
            }
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<Vec<EngineVerdict>> {
        let mut out = Vec::new();
        for (&sid, rtl) in self.streams.iter_mut() {
            for v in rtl.drain()? {
                out.push(EngineVerdict {
                    stream_id: sid,
                    seq: v.k - 1,
                    k: v.k,
                    eccentricity: v.eccentricity as f64,
                    zeta: v.zeta as f64,
                    threshold: v.threshold as f64,
                    outlier: v.outlier,
                });
            }
        }
        // Draining injects bubbles; pipelines cannot continue afterwards.
        self.streams.clear();
        Ok(out)
    }

    fn active_streams(&self) -> usize {
        self.streams.len()
    }

    fn snapshot(&self, stream_id: u64) -> Option<Snapshot> {
        self.streams
            .get(&stream_id)
            .map(|rtl| Snapshot::Rtl(rtl.save()))
    }

    fn restore(&mut self, stream_id: u64, snapshot: Snapshot) -> Result<()> {
        let snap = match snapshot {
            Snapshot::Rtl(s) => s,
            other => return Err(other.kind_mismatch("rtl")),
        };
        // A fresh pipeline adopts the saved register file — geometry is
        // validated by `load` (the snapshot carries its own n and m).
        let mut rtl = TedaRtl::new(self.n_features, self.m)?;
        rtl.load(&snap)?;
        self.streams.insert(stream_id, rtl);
        Ok(())
    }

    fn evict(&mut self, stream_id: u64) {
        // The pipeline goes with the stream — its ≤ LATENCY in-flight
        // verdicts are dropped, as documented on `Engine::evict`.
        self.streams.remove(&stream_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::testutil::{interleaved, run_engine};
    use crate::engine::SoftwareEngine;

    #[test]
    fn emits_with_pipeline_latency_then_flushes_tail() {
        let mut eng = RtlEngine::new(2, 3.0);
        let samples = interleaved(2, 10, 2, 3);
        let out = run_engine(&mut eng, &samples);
        assert_eq!(out.len(), 20); // every sample classified after flush
    }

    #[test]
    fn flags_match_software_engine() {
        let samples = interleaved(3, 120, 2, 21);
        let mut rtl = RtlEngine::new(2, 3.0);
        let mut sw = SoftwareEngine::new(2, 3.0);
        let a = run_engine(&mut rtl, &samples);
        let b = run_engine(&mut sw, &samples);
        assert_eq!(a.len(), b.len());
        for (key, va) in &a {
            let vb = &b[key];
            if va.k == 1 {
                // ζ₁ is NaN in hardware (0/0 divider, Eq. 1 guard) but
                // both sides must agree it is not an outlier.
                assert!(!va.outlier && !vb.outlier);
                continue;
            }
            // f32 hardware vs f64 software: flags agree away from the
            // threshold; compare zeta within loose tolerance.
            assert!(
                (va.zeta - vb.zeta).abs() <= 1e-3 * vb.zeta.abs().max(1.0),
                "{key:?}: {} vs {}",
                va.zeta,
                vb.zeta
            );
        }
    }

    #[test]
    fn snapshot_restore_keeps_inflight_verdicts() {
        // Cut an interleaved run mid-stream: the restored engine must
        // emit the in-flight verdicts (pipeline latency = 2) exactly as
        // the uninterrupted engine would.
        let samples = interleaved(2, 30, 2, 8);
        let cut = samples.len() / 2;
        let mut oracle = RtlEngine::new(2, 3.0);
        let full = run_engine(&mut oracle, &samples);

        let mut live = RtlEngine::new(2, 3.0);
        let mut got = std::collections::BTreeMap::new();
        for s in &samples[..cut] {
            for v in live.ingest(s).unwrap() {
                got.insert((v.stream_id, v.seq), v);
            }
        }
        let mut restored = RtlEngine::new(2, 3.0);
        for sid in 0..2u64 {
            restored.restore(sid, live.snapshot(sid).unwrap()).unwrap();
        }
        for s in &samples[cut..] {
            for v in restored.ingest(s).unwrap() {
                got.insert((v.stream_id, v.seq), v);
            }
        }
        for v in restored.flush().unwrap() {
            got.insert((v.stream_id, v.seq), v);
        }
        // NaN-safe equality (ζ₁ is NaN by design): compare bit patterns.
        assert_eq!(got.len(), full.len());
        for (key, a) in &got {
            let b = &full[key];
            assert_eq!(a.k, b.k, "{key:?}");
            assert_eq!(a.outlier, b.outlier, "{key:?}");
            assert_eq!(a.zeta.to_bits(), b.zeta.to_bits(), "{key:?}");
            assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
        }
    }
}

//! RTL engine: one cycle-accurate hardware pipeline per stream.

use std::collections::HashMap;

use crate::rtl::TedaRtl;
use crate::stream::Sample;
use crate::Result;

use super::{Engine, EngineVerdict};

/// Per-stream pipeline instance (the "multiple TEDA modules in
/// parallel" deployment of §5.2.1, one module per stream).
pub struct RtlEngine {
    n_features: usize,
    m: f32,
    streams: HashMap<u64, TedaRtl>,
}

impl RtlEngine {
    pub fn new(n_features: usize, m: f64) -> Self {
        RtlEngine { n_features, m: m as f32, streams: HashMap::new() }
    }
}

impl Engine for RtlEngine {
    fn name(&self) -> &'static str {
        "rtl"
    }

    fn ingest(&mut self, sample: &Sample) -> Result<Vec<EngineVerdict>> {
        let (n, m) = (self.n_features, self.m);
        let rtl = match self.streams.entry(sample.stream_id) {
            std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(TedaRtl::new(n, m)?)
            }
        };
        let x32: Vec<f32> = sample.values.iter().map(|&v| v as f32).collect();
        // The pipeline emits the verdict for sample k−2; its k identifies
        // the seq (streams start at seq 0 ⇒ seq = k − 1).
        Ok(match rtl.clock(&x32)? {
            Some(v) => vec![EngineVerdict {
                stream_id: sample.stream_id,
                seq: v.k - 1,
                k: v.k,
                eccentricity: v.eccentricity as f64,
                zeta: v.zeta as f64,
                threshold: v.threshold as f64,
                outlier: v.outlier,
            }],
            None => Vec::new(),
        })
    }

    fn flush(&mut self) -> Result<Vec<EngineVerdict>> {
        let mut out = Vec::new();
        for (&sid, rtl) in self.streams.iter_mut() {
            for v in rtl.drain()? {
                out.push(EngineVerdict {
                    stream_id: sid,
                    seq: v.k - 1,
                    k: v.k,
                    eccentricity: v.eccentricity as f64,
                    zeta: v.zeta as f64,
                    threshold: v.threshold as f64,
                    outlier: v.outlier,
                });
            }
        }
        // Draining injects bubbles; pipelines cannot continue afterwards.
        self.streams.clear();
        Ok(out)
    }

    fn active_streams(&self) -> usize {
        self.streams.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::testutil::{interleaved, run_engine};
    use crate::engine::SoftwareEngine;

    #[test]
    fn emits_with_pipeline_latency_then_flushes_tail() {
        let mut eng = RtlEngine::new(2, 3.0);
        let samples = interleaved(2, 10, 2, 3);
        let out = run_engine(&mut eng, &samples);
        assert_eq!(out.len(), 20); // every sample classified after flush
    }

    #[test]
    fn flags_match_software_engine() {
        let samples = interleaved(3, 120, 2, 21);
        let mut rtl = RtlEngine::new(2, 3.0);
        let mut sw = SoftwareEngine::new(2, 3.0);
        let a = run_engine(&mut rtl, &samples);
        let b = run_engine(&mut sw, &samples);
        assert_eq!(a.len(), b.len());
        for (key, va) in &a {
            let vb = &b[key];
            if va.k == 1 {
                // ζ₁ is NaN in hardware (0/0 divider, Eq. 1 guard) but
                // both sides must agree it is not an outlier.
                assert!(!va.outlier && !vb.outlier);
                continue;
            }
            // f32 hardware vs f64 software: flags agree away from the
            // threshold; compare zeta within loose tolerance.
            assert!(
                (va.zeta - vb.zeta).abs() <= 1e-3 * vb.zeta.abs().max(1.0),
                "{key:?}: {} vs {}",
                va.zeta,
                vb.zeta
            );
        }
    }
}

//! XLA engine: drives the AOT-compiled JAX/Pallas artifact through PJRT.
//!
//! Batching model: every stream buffers samples until it has a full
//! T-chunk; full chunks from up to S streams are packed into one
//! (S, T, N) execution (S and T fixed by the artifact variant chosen at
//! construction). Streams with fewer than S ready chunks are padded with
//! dummy lanes whose outputs are discarded — lanes are independent, so
//! padding is sound. Partial chunks at [`Engine::flush`] run through a
//! scalar f32 fallback that computes the identical recurrence, so stream
//! state never forks from the artifact's semantics.

use std::collections::HashMap;
use std::sync::Arc;

use crate::runtime::{Executable, XlaRuntime};
use crate::stream::Sample;
use crate::teda::TedaState;
use crate::{Error, Result};

use super::{runs, Engine, EngineVerdict, Snapshot};

/// Checkpoint of one stream inside the [`XlaEngine`]: the f32 carry
/// tensors (exactly the artifact's VMEM state) plus every buffered
/// sample that has not executed yet — full chunks waiting for
/// co-batching partners and the partially filled tail. Restoring
/// re-queues those samples verbatim, so their verdicts are emitted by
/// the restored engine instead of being lost with the dead worker.
#[derive(Debug, Clone, PartialEq)]
pub struct XlaSnapshot {
    /// Per-feature mean carry μ (length N).
    pub mu: Vec<f32>,
    /// Variance carry σ².
    pub var: f32,
    /// Iteration carry k (f32, as the artifact stores it).
    pub k: f32,
    /// Chebyshev multiplier baked into the artifact variant.
    pub m: f64,
    /// Full unexecuted T-chunks: (seq of first sample, t·n values).
    pub chunks: Vec<(u64, Vec<f32>)>,
    /// Partially filled chunk (t_filled × n values).
    pub buf: Vec<f32>,
    /// seq of the first sample in `buf`.
    pub seq_base: u64,
}

struct StreamState {
    /// f32 carry, exactly the artifact's state tensors.
    mu: Vec<f32>,
    var: f32,
    k: f32,
    /// Full T-chunks waiting to execute: (seq of first sample, t·n
    /// flattened values). A stream may queue several chunks while the
    /// batcher waits for co-batching partners; chunks of one stream
    /// execute strictly in order (state carries between them), so one
    /// batch holds at most one chunk per stream.
    chunks: std::collections::VecDeque<(u64, Vec<f32>)>,
    /// Partially-filled chunk (t_filled × n values).
    buf: Vec<f32>,
    /// seq of the first sample in `buf`.
    seq_base: u64,
}

/// PJRT-backed batching engine.
pub struct XlaEngine {
    exe: Arc<Executable>,
    n: usize,
    t: usize,
    s: usize,
    m: f64,
    streams: HashMap<u64, StreamState>,
    /// Streams holding a full chunk, in arrival order.
    ready: Vec<u64>,
    /// Execute as soon as `min_ready` full chunks are waiting (≤ s);
    /// 1 = lowest latency, s = maximal batching.
    min_ready: usize,
    /// Number of chunk executions so far (metrics hook).
    pub chunks_executed: u64,
    /// Samples classified through the scalar fallback.
    pub scalar_samples: u64,
}

impl XlaEngine {
    /// Build from a runtime: picks the smallest pallas variant with
    /// matching N whose capacity fits `min_batch_samples`.
    pub fn new(
        runtime: &XlaRuntime,
        n_features: usize,
        min_batch_samples: usize,
    ) -> Result<Self> {
        let spec = runtime
            .manifest()
            .select(n_features, min_batch_samples)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no artifact variant with n={n_features}"
                ))
            })?
            .clone();
        let exe = runtime.load(&spec.name)?;
        Ok(XlaEngine {
            n: spec.n,
            t: spec.t,
            s: spec.s,
            m: spec.m,
            exe,
            streams: HashMap::new(),
            ready: Vec::new(),
            min_ready: 1,
            chunks_executed: 0,
            scalar_samples: 0,
        })
    }

    /// Batching aggressiveness: wait for `min_ready` full stream-chunks
    /// before executing (clamped to [1, S]).
    pub fn with_min_ready(mut self, min_ready: usize) -> Self {
        self.min_ready = min_ready.clamp(1, self.s);
        self
    }

    /// The artifact variant geometry (S, T, N).
    pub fn geometry(&self) -> (usize, usize, usize) {
        (self.s, self.t, self.n)
    }

    /// Pick up to S *unique* streams from the ready list (preserving
    /// arrival order); duplicate entries (further chunks of the same
    /// stream) stay queued for the next batch.
    fn take_batch_ids(&mut self) -> Vec<u64> {
        let mut ids: Vec<u64> = Vec::with_capacity(self.s);
        let mut rest: Vec<u64> = Vec::new();
        for id in self.ready.drain(..) {
            if ids.len() < self.s && !ids.contains(&id) {
                ids.push(id);
            } else {
                rest.push(id);
            }
        }
        self.ready = rest;
        ids
    }

    /// Execute one packed batch: the front chunk of each given stream.
    fn execute_batch(&mut self, ids: &[u64]) -> Result<Vec<EngineVerdict>> {
        debug_assert!(ids.len() <= self.s);
        let (s, t, n) = (self.s, self.t, self.n);
        let mut mu = vec![0f32; s * n];
        let mut var = vec![0f32; s];
        let mut k = vec![0f32; s];
        let mut x = vec![0f32; s * t * n];
        let mut seq_bases = Vec::with_capacity(ids.len());
        for (lane, id) in ids.iter().enumerate() {
            let st = self.streams.get_mut(id).unwrap();
            let (seq_base, chunk) =
                st.chunks.pop_front().expect("stream in batch has a chunk");
            mu[lane * n..(lane + 1) * n].copy_from_slice(&st.mu);
            var[lane] = st.var;
            k[lane] = st.k;
            x[lane * t * n..(lane + 1) * t * n].copy_from_slice(&chunk);
            seq_bases.push(seq_base);
        }
        // Dummy lanes keep zeros — fresh state over zero samples.
        let outs = self.exe.run_f32(&[&mu, &var, &k, &x])?;
        self.chunks_executed += 1;
        let (ecc, zeta, outlier) = (&outs[0], &outs[1], &outs[2]);
        let (mu2, var2, k2) = (&outs[3], &outs[4], &outs[5]);

        let mut verdicts = Vec::with_capacity(ids.len() * t);
        for (lane, id) in ids.iter().enumerate() {
            let st = self.streams.get_mut(id).unwrap();
            let k0 = st.k as u64;
            for ti in 0..t {
                let idx = lane * t + ti;
                let kk = k0 + ti as u64 + 1;
                verdicts.push(EngineVerdict {
                    stream_id: *id,
                    seq: seq_bases[lane] + ti as u64,
                    k: kk,
                    eccentricity: ecc[idx] as f64,
                    zeta: zeta[idx] as f64,
                    threshold: (self.m * self.m + 1.0) / (2.0 * kk as f64),
                    outlier: outlier[idx] > 0.5,
                });
            }
            st.mu.copy_from_slice(&mu2[lane * n..(lane + 1) * n]);
            st.var = var2[lane];
            st.k = k2[lane];
        }
        Ok(verdicts)
    }

    /// Scalar f32 fallback for a partial chunk (same recurrence).
    fn scalar_chunk(&mut self, id: u64) -> Vec<EngineVerdict> {
        let m = self.m;
        let n = self.n;
        let st = self.streams.get_mut(&id).unwrap();
        let mut state = TedaState::<f32> {
            mean: st.mu.clone(),
            var: st.var,
            k: st.k as u64,
        };
        let mut out = Vec::new();
        let samples = st.buf.len() / n;
        for i in 0..samples {
            let x = &st.buf[i * n..(i + 1) * n];
            let step = state.step(x, m as f32);
            out.push(EngineVerdict {
                stream_id: id,
                seq: st.seq_base + i as u64,
                k: state.k,
                eccentricity: step.eccentricity as f64,
                zeta: step.zeta as f64,
                threshold: step.threshold as f64,
                outlier: step.outlier,
            });
        }
        st.mu.copy_from_slice(&state.mean);
        st.var = state.var;
        st.k = state.k as f32;
        st.seq_base += samples as u64;
        st.buf.clear();
        self.scalar_samples += samples as u64;
        out
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn ingest(&mut self, sample: &Sample) -> Result<Vec<EngineVerdict>> {
        if sample.values.len() != self.n {
            return Err(Error::Stream(format!(
                "stream {}: sample dim {} != engine dim {}",
                sample.stream_id,
                sample.values.len(),
                self.n
            )));
        }
        let chunk_len = self.t * self.n;
        let st =
            self.streams.entry(sample.stream_id).or_insert_with(|| {
                StreamState {
                    mu: vec![0.0; sample.values.len()],
                    var: 0.0,
                    k: 0.0,
                    chunks: std::collections::VecDeque::new(),
                    buf: Vec::with_capacity(chunk_len),
                    seq_base: sample.seq,
                }
            });
        for &v in &sample.values {
            st.buf.push(v as f32);
        }
        if st.buf.len() == chunk_len {
            let chunk =
                std::mem::replace(&mut st.buf, Vec::with_capacity(chunk_len));
            st.chunks.push_back((st.seq_base, chunk));
            st.seq_base += self.t as u64;
            self.ready.push(sample.stream_id);
        }
        if self.ready.len() >= self.min_ready.min(self.s) {
            let ids = self.take_batch_ids();
            return self.execute_batch(&ids);
        }
        Ok(Vec::new())
    }

    fn process_batch(
        &mut self,
        samples: &[Sample],
        out: &mut Vec<EngineVerdict>,
    ) -> Result<()> {
        let (n, t) = (self.n, self.t);
        let chunk_len = t * n;
        for run in runs(samples) {
            let sid = run[0].stream_id;
            // Dim-check the head before touching the map, exactly like
            // the per-sample path: a bad first sample must not create
            // stream state.
            if run[0].values.len() != n {
                return Err(Error::Stream(format!(
                    "stream {sid}: sample dim {} != engine dim {n}",
                    run[0].values.len(),
                )));
            }
            // One stream resolution per run; the run fills (S, T, N)
            // chunks directly instead of buffering sample-by-sample.
            let st = self.streams.entry(sid).or_insert_with(|| StreamState {
                mu: vec![0.0; n],
                var: 0.0,
                k: 0.0,
                chunks: std::collections::VecDeque::new(),
                buf: Vec::with_capacity(chunk_len),
                seq_base: run[0].seq,
            });
            let mut queued = 0usize;
            for sample in run {
                if sample.values.len() != n {
                    // Keep the chunks already completed so engine state
                    // matches the per-sample path, which buffers
                    // everything up to the offending sample.
                    self.ready
                        .extend(std::iter::repeat(sid).take(queued));
                    return Err(Error::Stream(format!(
                        "stream {}: sample dim {} != engine dim {}",
                        sample.stream_id,
                        sample.values.len(),
                        n
                    )));
                }
                for &v in &sample.values {
                    st.buf.push(v as f32);
                }
                if st.buf.len() == chunk_len {
                    let chunk = std::mem::replace(
                        &mut st.buf,
                        Vec::with_capacity(chunk_len),
                    );
                    st.chunks.push_back((st.seq_base, chunk));
                    st.seq_base += t as u64;
                    queued += 1;
                }
            }
            self.ready.extend(std::iter::repeat(sid).take(queued));
        }
        // Drain every full batch the burst produced. Lanes are
        // independent and a stream's chunks execute strictly in order,
        // so deferring execution to the end of the burst changes only
        // which streams co-batch, never any verdict value.
        while self.ready.len() >= self.min_ready.min(self.s) {
            let ids = self.take_batch_ids();
            let verdicts = self.execute_batch(&ids)?;
            out.extend(verdicts);
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<Vec<EngineVerdict>> {
        let mut out = Vec::new();
        // Full chunks first (possibly several padded batches)...
        while !self.ready.is_empty() {
            let ids = self.take_batch_ids();
            out.extend(self.execute_batch(&ids)?);
        }
        // ...then partial buffers through the scalar path.
        let partial: Vec<u64> = self
            .streams
            .iter()
            .filter(|(_, st)| !st.buf.is_empty())
            .map(|(&id, _)| id)
            .collect();
        for id in partial {
            out.extend(self.scalar_chunk(id));
        }
        Ok(out)
    }

    fn active_streams(&self) -> usize {
        self.streams.len()
    }

    fn snapshot(&self, stream_id: u64) -> Option<Snapshot> {
        self.streams.get(&stream_id).map(|st| {
            Snapshot::Xla(XlaSnapshot {
                mu: st.mu.clone(),
                var: st.var,
                k: st.k,
                m: self.m,
                chunks: st.chunks.iter().cloned().collect(),
                buf: st.buf.clone(),
                seq_base: st.seq_base,
            })
        })
    }

    fn restore(&mut self, stream_id: u64, snapshot: Snapshot) -> Result<()> {
        let snap = match snapshot {
            Snapshot::Xla(s) => s,
            other => return Err(other.kind_mismatch("xla")),
        };
        let chunk_len = self.t * self.n;
        if snap.mu.len() != self.n
            || snap.m != self.m
            || snap.buf.len() >= chunk_len
            || snap.buf.len() % self.n != 0
            || snap.chunks.iter().any(|(_, c)| c.len() != chunk_len)
        {
            return Err(Error::Stream(format!(
                "xla snapshot does not fit engine geometry \
                 (S,T,N,m)=({},{},{},{})",
                self.s, self.t, self.n, self.m
            )));
        }
        // Replacing a stream's state also replaces its ready-queue
        // entries (one per full unexecuted chunk).
        self.ready.retain(|&id| id != stream_id);
        self.ready
            .extend(std::iter::repeat(stream_id).take(snap.chunks.len()));
        self.streams.insert(
            stream_id,
            StreamState {
                mu: snap.mu,
                var: snap.var,
                k: snap.k,
                chunks: snap.chunks.into_iter().collect(),
                buf: snap.buf,
                seq_base: snap.seq_base,
            },
        );
        Ok(())
    }

    fn evict(&mut self, stream_id: u64) {
        // Unexecuted chunks leave the ready queue with the stream.
        self.streams.remove(&stream_id);
        self.ready.retain(|&id| id != stream_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::testutil::{interleaved, run_engine};
    use crate::engine::SoftwareEngine;

    fn runtime() -> Option<XlaRuntime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            Some(XlaRuntime::new(dir).unwrap())
        } else {
            eprintln!("artifacts missing; skipping XLA engine test");
            None
        }
    }

    #[test]
    fn batches_and_matches_software_flags() {
        let Some(rt) = runtime() else { return };
        let mut eng = XlaEngine::new(&rt, 2, 1).unwrap();
        let (_, t, _) = eng.geometry();
        // 4 streams, enough for several chunks + a partial tail.
        let per_stream = t * 3 + t / 2;
        let samples = interleaved(4, per_stream, 2, 77);
        let mut sw = SoftwareEngine::new(2, 3.0);
        let a = run_engine(&mut eng, &samples);
        let b = run_engine(&mut sw, &samples);
        assert_eq!(a.len(), 4 * per_stream);
        assert_eq!(a.len(), b.len());
        assert!(eng.chunks_executed >= 3);
        assert!(eng.scalar_samples > 0); // the partial tail
        let mut flag_diffs = 0;
        for (key, va) in &a {
            let vb = &b[key];
            assert_eq!(va.k, vb.k, "{key:?}");
            if va.outlier != vb.outlier {
                flag_diffs += 1; // f32-vs-f64 threshold-edge differences
            }
        }
        assert!(
            flag_diffs as f64 <= 0.01 * a.len() as f64,
            "flag diffs {flag_diffs}/{}",
            a.len()
        );
    }

    #[test]
    fn state_carries_across_chunks() {
        let Some(rt) = runtime() else { return };
        let mut eng = XlaEngine::new(&rt, 2, 1).unwrap();
        let (_, t, _) = eng.geometry();
        let samples = interleaved(1, t * 2, 2, 5);
        let out = run_engine(&mut eng, &samples);
        // k must be contiguous 1..=2t for the single stream.
        let ks: Vec<u64> = out.values().map(|v| v.k).collect();
        assert_eq!(ks, (1..=2 * t as u64).collect::<Vec<_>>());
    }

    #[test]
    fn min_ready_controls_batching() {
        let Some(rt) = runtime() else { return };
        let mut eng = XlaEngine::new(&rt, 2, 256).unwrap().with_min_ready(4);
        let (s, t, _) = eng.geometry();
        assert!(s >= 4);
        // Feed 4 streams exactly one chunk each; execution fires only
        // when the 4th becomes ready.
        let samples = interleaved(4, t, 2, 13);
        let mut got = 0;
        for smp in &samples {
            got += eng.ingest(smp).unwrap().len();
        }
        assert_eq!(got, 4 * t);
        assert_eq!(eng.chunks_executed, 1);
    }

    #[test]
    fn snapshot_restore_mid_chunk_matches_uninterrupted() {
        let Some(rt) = runtime() else { return };
        let mut eng = XlaEngine::new(&rt, 2, 1).unwrap();
        let (_, t, _) = eng.geometry();
        let samples = interleaved(1, t + t / 2, 2, 3);
        let mut full_eng = XlaEngine::new(&rt, 2, 1).unwrap();
        let full = run_engine(&mut full_eng, &samples);
        // Cut mid-chunk: buffered samples must survive the failover.
        let cut = t + 2;
        let mut got = std::collections::BTreeMap::new();
        for s in &samples[..cut] {
            for v in eng.ingest(s).unwrap() {
                got.insert((v.stream_id, v.seq), v);
            }
        }
        let mut restored = XlaEngine::new(&rt, 2, 1).unwrap();
        restored.restore(0, eng.snapshot(0).unwrap()).unwrap();
        for s in &samples[cut..] {
            for v in restored.ingest(s).unwrap() {
                got.insert((v.stream_id, v.seq), v);
            }
        }
        for v in restored.flush().unwrap() {
            got.insert((v.stream_id, v.seq), v);
        }
        assert_eq!(got.len(), full.len());
        for (key, a) in &got {
            let b = &full[key];
            assert_eq!(a.k, b.k, "{key:?}");
            assert_eq!(a.outlier, b.outlier, "{key:?}");
            assert_eq!(a.zeta.to_bits(), b.zeta.to_bits(), "{key:?}");
        }
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let Some(rt) = runtime() else { return };
        let mut eng = XlaEngine::new(&rt, 2, 1).unwrap();
        let bad = Sample { stream_id: 0, seq: 0, values: vec![1.0; 5] };
        assert!(eng.ingest(&bad).is_err());
    }
}

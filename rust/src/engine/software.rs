//! Software (scalar f64) engine — the Table 5 "software platform" row.

use std::collections::HashMap;

use crate::stream::Sample;
use crate::teda::TedaDetector;
use crate::Result;

use super::{Engine, EngineVerdict};

/// One f64 `TedaDetector` per stream; verdicts are immediate.
pub struct SoftwareEngine {
    n_features: usize,
    m: f64,
    streams: HashMap<u64, TedaDetector>,
}

impl SoftwareEngine {
    pub fn new(n_features: usize, m: f64) -> Self {
        SoftwareEngine { n_features, m, streams: HashMap::new() }
    }

    /// Direct access to a stream's detector (state manager integration).
    pub fn detector(&self, stream_id: u64) -> Option<&TedaDetector> {
        self.streams.get(&stream_id)
    }
}

impl Engine for SoftwareEngine {
    fn name(&self) -> &'static str {
        "software"
    }

    fn ingest(&mut self, sample: &Sample) -> Result<Vec<EngineVerdict>> {
        let det = self
            .streams
            .entry(sample.stream_id)
            .or_insert_with(|| TedaDetector::new(self.n_features, self.m));
        let v = det.step(&sample.values);
        Ok(vec![EngineVerdict {
            stream_id: sample.stream_id,
            seq: sample.seq,
            k: v.k,
            eccentricity: v.eccentricity,
            zeta: v.zeta,
            threshold: v.threshold,
            outlier: v.outlier,
        }])
    }

    fn flush(&mut self) -> Result<Vec<EngineVerdict>> {
        Ok(Vec::new()) // nothing ever pends
    }

    fn active_streams(&self) -> usize {
        self.streams.len()
    }

    fn as_software(&mut self) -> Option<&mut SoftwareEngine> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::testutil::{interleaved, run_engine};

    #[test]
    fn verdict_per_sample_immediately() {
        let mut eng = SoftwareEngine::new(2, 3.0);
        let samples = interleaved(3, 50, 2, 11);
        let out = run_engine(&mut eng, &samples);
        assert_eq!(out.len(), 150);
        assert_eq!(eng.active_streams(), 3);
        // k tracks per-stream seq.
        for ((_, seq), v) in &out {
            assert_eq!(v.k, seq + 1);
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut eng = SoftwareEngine::new(1, 3.0);
        // Stream 0: tight around 0. Stream 1: tight around 100.
        for seq in 0..100u64 {
            let a = Sample {
                stream_id: 0,
                seq,
                values: vec![(seq % 7) as f64 * 0.01],
            };
            let b = Sample {
                stream_id: 1,
                seq,
                values: vec![100.0 + (seq % 7) as f64 * 0.01],
            };
            eng.ingest(&a).unwrap();
            eng.ingest(&b).unwrap();
        }
        // A 100-ish value is normal for stream 1, outlier for stream 0.
        let probe0 = Sample { stream_id: 0, seq: 100, values: vec![100.0] };
        let probe1 = Sample { stream_id: 1, seq: 100, values: vec![100.0] };
        assert!(eng.ingest(&probe0).unwrap()[0].outlier);
        assert!(!eng.ingest(&probe1).unwrap()[0].outlier);
    }
}

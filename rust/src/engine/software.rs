//! Software (scalar f64) engine — the Table 5 "software platform" row.

use std::collections::HashMap;

use crate::stream::Sample;
use crate::teda::TedaDetector;
use crate::{Error, Result};

use super::{runs, Engine, EngineVerdict, Snapshot};

/// One f64 `TedaDetector` per stream; verdicts are immediate.
pub struct SoftwareEngine {
    n_features: usize,
    m: f64,
    streams: HashMap<u64, TedaDetector>,
}

impl SoftwareEngine {
    pub fn new(n_features: usize, m: f64) -> Self {
        SoftwareEngine { n_features, m, streams: HashMap::new() }
    }

    /// Direct access to a stream's detector (state manager integration).
    pub fn detector(&self, stream_id: u64) -> Option<&TedaDetector> {
        self.streams.get(&stream_id)
    }
}

impl Engine for SoftwareEngine {
    fn name(&self) -> &'static str {
        "software"
    }

    fn ingest(&mut self, sample: &Sample) -> Result<Vec<EngineVerdict>> {
        let det = self
            .streams
            .entry(sample.stream_id)
            .or_insert_with(|| TedaDetector::new(self.n_features, self.m));
        let v = det.step(&sample.values);
        Ok(vec![EngineVerdict {
            stream_id: sample.stream_id,
            seq: sample.seq,
            k: v.k,
            eccentricity: v.eccentricity,
            zeta: v.zeta,
            threshold: v.threshold,
            outlier: v.outlier,
        }])
    }

    fn process_batch(
        &mut self,
        samples: &[Sample],
        out: &mut Vec<EngineVerdict>,
    ) -> Result<()> {
        out.reserve(samples.len());
        for run in runs(samples) {
            let sid = run[0].stream_id;
            let det = self
                .streams
                .entry(sid)
                .or_insert_with(|| TedaDetector::new(self.n_features, self.m));
            let mut seqs = run.iter().map(|s| s.seq);
            det.run_with(run.iter().map(|s| s.values.as_slice()), |v| {
                out.push(EngineVerdict {
                    stream_id: sid,
                    seq: seqs.next().expect("one verdict per sample"),
                    k: v.k,
                    eccentricity: v.eccentricity,
                    zeta: v.zeta,
                    threshold: v.threshold,
                    outlier: v.outlier,
                });
            });
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<Vec<EngineVerdict>> {
        Ok(Vec::new()) // nothing ever pends
    }

    fn active_streams(&self) -> usize {
        self.streams.len()
    }

    fn snapshot(&self, stream_id: u64) -> Option<Snapshot> {
        self.streams
            .get(&stream_id)
            .map(|det| Snapshot::Software(det.snapshot()))
    }

    fn restore(&mut self, stream_id: u64, snapshot: Snapshot) -> Result<()> {
        let snap = match snapshot {
            Snapshot::Software(s) => s,
            other => return Err(other.kind_mismatch("software")),
        };
        if snap.state.n_features() != self.n_features || snap.m != self.m {
            return Err(Error::Stream(format!(
                "snapshot is for (n={}, m={}), engine configured for \
                 (n={}, m={})",
                snap.state.n_features(),
                snap.m,
                self.n_features,
                self.m
            )));
        }
        let det = self
            .streams
            .entry(stream_id)
            .or_insert_with(|| TedaDetector::new(self.n_features, self.m));
        det.restore(snap);
        Ok(())
    }

    fn evict(&mut self, stream_id: u64) {
        self.streams.remove(&stream_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::testutil::{interleaved, run_engine};

    #[test]
    fn verdict_per_sample_immediately() {
        let mut eng = SoftwareEngine::new(2, 3.0);
        let samples = interleaved(3, 50, 2, 11);
        let out = run_engine(&mut eng, &samples);
        assert_eq!(out.len(), 150);
        assert_eq!(eng.active_streams(), 3);
        // k tracks per-stream seq.
        for ((_, seq), v) in &out {
            assert_eq!(v.k, seq + 1);
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut eng = SoftwareEngine::new(1, 3.0);
        // Stream 0: tight around 0. Stream 1: tight around 100.
        for seq in 0..100u64 {
            let a = Sample {
                stream_id: 0,
                seq,
                values: vec![(seq % 7) as f64 * 0.01],
            };
            let b = Sample {
                stream_id: 1,
                seq,
                values: vec![100.0 + (seq % 7) as f64 * 0.01],
            };
            eng.ingest(&a).unwrap();
            eng.ingest(&b).unwrap();
        }
        // A 100-ish value is normal for stream 1, outlier for stream 0.
        let probe0 = Sample { stream_id: 0, seq: 100, values: vec![100.0] };
        let probe1 = Sample { stream_id: 1, seq: 100, values: vec![100.0] };
        assert!(eng.ingest(&probe0).unwrap()[0].outlier);
        assert!(!eng.ingest(&probe1).unwrap()[0].outlier);
    }

    #[test]
    fn snapshot_restore_continues_identically() {
        let samples = interleaved(2, 60, 2, 7);
        let mut a = SoftwareEngine::new(2, 3.0);
        for s in &samples {
            a.ingest(s).unwrap();
        }
        assert!(a.snapshot(99).is_none()); // unknown stream
        let mut b = SoftwareEngine::new(2, 3.0);
        for sid in 0..2u64 {
            b.restore(sid, a.snapshot(sid).unwrap()).unwrap();
        }
        let probe = Sample { stream_id: 1, seq: 60, values: vec![9.0, 9.0] };
        assert_eq!(a.ingest(&probe).unwrap(), b.ingest(&probe).unwrap());
        // Counters travelled too.
        assert_eq!(
            a.detector(1).unwrap().n_outliers(),
            b.detector(1).unwrap().n_outliers()
        );
    }

    #[test]
    fn evict_drops_the_stream_and_restarts_fresh() {
        let mut eng = SoftwareEngine::new(2, 3.0);
        let samples = interleaved(2, 30, 2, 19);
        for s in &samples {
            eng.ingest(s).unwrap();
        }
        assert_eq!(eng.active_streams(), 2);
        eng.evict(0);
        eng.evict(99); // unknown stream: no-op
        assert_eq!(eng.active_streams(), 1);
        assert!(eng.snapshot(0).is_none());
        // Re-appearing id starts a fresh stream.
        let v = eng
            .ingest(&Sample { stream_id: 0, seq: 50, values: vec![0.1, 0.2] })
            .unwrap();
        assert_eq!(v[0].k, 1);
        // The surviving stream kept its state.
        assert!(eng.detector(1).unwrap().k() >= 30);
    }

    #[test]
    fn restore_rejects_wrong_kind_and_shape() {
        let mut a = SoftwareEngine::new(3, 3.0);
        a.ingest(&Sample { stream_id: 0, seq: 0, values: vec![0.0; 3] })
            .unwrap();
        let snap = a.snapshot(0).unwrap();
        let mut b = SoftwareEngine::new(2, 3.0);
        assert!(b.restore(0, snap).is_err()); // feature mismatch
        let snap = a.snapshot(0).unwrap();
        let mut c = SoftwareEngine::new(3, 2.5);
        assert!(c.restore(0, snap).is_err()); // threshold mismatch
        let mut rtl = crate::engine::RtlEngine::new(3, 3.0);
        let snap = a.snapshot(0).unwrap();
        assert!(rtl.restore(0, snap).is_err()); // kind mismatch
    }
}

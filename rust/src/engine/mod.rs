//! Detector engines — the pluggable backends the coordinator drives.
//!
//! All three compute Algorithm 1; they differ in *how*:
//!
//! - [`SoftwareEngine`] — scalar f64 [`crate::teda::TedaDetector`] per
//!   stream. Zero latency, the reference for correctness and the
//!   "software platform" row of Table 5.
//! - [`RtlEngine`] — one cycle-accurate [`crate::rtl::TedaRtl`] pipeline
//!   per stream (f32, 2-cycle latency — verdicts stream out exactly as
//!   the FPGA would emit them).
//! - [`XlaEngine`] — the AOT-compiled JAX/Pallas artifact via PJRT:
//!   samples are buffered into (S, T, N) chunks, states live in f32
//!   exactly like the artifact's VMEM carry. Partial chunks at flush go
//!   through a scalar f32 fallback so stream state stays exact.
//!
//! Engines are deliberately synchronous and single-threaded; the
//! coordinator owns parallelism by sharding streams across worker
//! threads, mirroring the paper's "multiple TEDA modules applied in
//! parallel" scaling argument (§5.2.1).

mod rtl_engine;
mod software;
mod xla_engine;

pub use rtl_engine::RtlEngine;
pub use software::SoftwareEngine;
pub use xla_engine::XlaEngine;

use crate::stream::Sample;
use crate::Result;

/// One classified sample leaving an engine.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineVerdict {
    pub stream_id: u64,
    /// The sample's per-stream sequence number.
    pub seq: u64,
    /// TEDA iteration k (= seq + 1 when streams start fresh).
    pub k: u64,
    pub eccentricity: f64,
    pub zeta: f64,
    pub threshold: f64,
    pub outlier: bool,
}

/// A detector backend processing interleaved multi-stream samples.
///
/// Deliberately NOT `Send`: the XLA engine wraps PJRT handles that are
/// single-threaded; the coordinator constructs each engine *inside* its
/// worker thread.
pub trait Engine {
    /// Engine label ("software" | "rtl" | "xla").
    fn name(&self) -> &'static str;

    /// Absorb one sample; returns any verdicts that became ready (for
    /// this or other streams — batching engines emit in bursts).
    fn ingest(&mut self, sample: &Sample) -> Result<Vec<EngineVerdict>>;

    /// Force out every pending verdict (end of stream / shutdown).
    fn flush(&mut self) -> Result<Vec<EngineVerdict>>;

    /// Streams with in-flight state.
    fn active_streams(&self) -> usize;

    /// Checkpointing hook: the software engine exposes its detectors;
    /// other engines return `None` (their state lives in f32 tensors /
    /// pipeline registers and is checkpointed at chunk boundaries only).
    fn as_software(&mut self) -> Option<&mut SoftwareEngine> {
        None
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::stream::Sample;

    /// Feed `samples` (already interleaved) through an engine and return
    /// verdicts keyed by (stream, seq), asserting uniqueness.
    pub fn run_engine(
        eng: &mut dyn Engine,
        samples: &[Sample],
    ) -> std::collections::BTreeMap<(u64, u64), EngineVerdict> {
        let mut out = std::collections::BTreeMap::new();
        for s in samples {
            for v in eng.ingest(s).unwrap() {
                let key = (v.stream_id, v.seq);
                assert!(out.insert(key, v).is_none(), "duplicate {key:?}");
            }
        }
        for v in eng.flush().unwrap() {
            let key = (v.stream_id, v.seq);
            assert!(out.insert(key, v).is_none(), "duplicate {key:?}");
        }
        out
    }

    /// Round-robin interleave across `n_streams` synthetic streams.
    pub fn interleaved(
        n_streams: u64,
        per_stream: usize,
        n: usize,
        seed: u64,
    ) -> Vec<Sample> {
        use crate::util::prng::SplitMix64;
        let mut rngs: Vec<SplitMix64> = (0..n_streams)
            .map(|s| SplitMix64::new(seed ^ (s * 7919)))
            .collect();
        let mut out = Vec::new();
        for seq in 0..per_stream {
            for sid in 0..n_streams {
                let rng = &mut rngs[sid as usize];
                out.push(Sample {
                    stream_id: sid,
                    seq: seq as u64,
                    values: (0..n).map(|_| rng.uniform(0.0, 1.0)).collect(),
                });
            }
        }
        out
    }
}

//! Detector engines — the pluggable backends the coordinator drives.
//!
//! All three compute Algorithm 1; they differ in *how*:
//!
//! - [`SoftwareEngine`] — scalar f64 [`crate::teda::TedaDetector`] per
//!   stream. Zero latency, the reference for correctness and the
//!   "software platform" row of Table 5.
//! - [`RtlEngine`] — one cycle-accurate [`crate::rtl::TedaRtl`] pipeline
//!   per stream (f32, 2-cycle latency — verdicts stream out exactly as
//!   the FPGA would emit them).
//! - [`XlaEngine`] — the AOT-compiled JAX/Pallas artifact via PJRT:
//!   samples are buffered into (S, T, N) chunks, states live in f32
//!   exactly like the artifact's VMEM carry. Partial chunks at flush go
//!   through a scalar f32 fallback so stream state stays exact.
//!
//! Engines are deliberately synchronous and single-threaded; the
//! coordinator owns parallelism by sharding streams across worker
//! threads, mirroring the paper's "multiple TEDA modules applied in
//! parallel" scaling argument (§5.2.1).

mod rtl_engine;
mod software;
mod xla_engine;

pub use rtl_engine::RtlEngine;
pub use software::SoftwareEngine;
pub use xla_engine::{XlaEngine, XlaSnapshot};

use crate::stream::Sample;
use crate::{Error, Result};

/// Engine-agnostic checkpoint of ONE stream's complete detector state.
///
/// The TEDA recurrence carries only `(μ_k, σ²_k, k)` per stream, which
/// is what makes line-rate checkpointing affordable; each variant adds
/// exactly what its backend needs on top of that carry so a restore is
/// *observably identical* to never having failed:
///
/// - [`Snapshot::Software`] — recurrence state **and** detection
///   counters ([`crate::teda::DetectorSnapshot`]).
/// - [`Snapshot::Rtl`] — the full pipeline register file
///   ([`crate::rtl::RtlSnapshot`]): architectural state *and* the
///   ≤ 2 in-flight samples still inside the MEAN→VARIANCE→OUTLIER
///   stages, so the restored pipeline emits their verdicts bit-exactly.
/// - [`Snapshot::Xla`] — the f32 carry tensors plus buffered samples
///   not yet executed through the artifact.
/// - [`Snapshot::Ensemble`] — every member's snapshot, the per-stream
///   combiner weights, and the unfused quorum slots, all captured at
///   one `(stream, seq)` watermark so no member restores ahead of the
///   fusion barrier ([`crate::ensemble::EnsembleSnapshot`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Snapshot {
    /// Software TEDA detector state + counters.
    Software(crate::teda::DetectorSnapshot),
    /// RTL pipeline register file (in-flight samples included).
    Rtl(crate::rtl::RtlSnapshot),
    /// XLA engine carry + unexecuted sample buffers.
    Xla(XlaSnapshot),
    /// All ensemble member snapshots + combiner weights + quorum slots.
    Ensemble(crate::ensemble::EnsembleSnapshot),
}

impl Snapshot {
    /// Which engine family produced this snapshot.
    pub fn kind(&self) -> &'static str {
        match self {
            Snapshot::Software(_) => "software",
            Snapshot::Rtl(_) => "rtl",
            Snapshot::Xla(_) => "xla",
            Snapshot::Ensemble(_) => "ensemble",
        }
    }

    /// Uniform error for a snapshot handed to the wrong engine family.
    pub(crate) fn kind_mismatch(&self, engine: &'static str) -> Error {
        Error::Stream(format!(
            "cannot restore a '{}' snapshot into the '{engine}' engine",
            self.kind()
        ))
    }
}

/// One classified sample leaving an engine.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineVerdict {
    pub stream_id: u64,
    /// The sample's per-stream sequence number.
    pub seq: u64,
    /// TEDA iteration k (= seq + 1 when streams start fresh).
    pub k: u64,
    pub eccentricity: f64,
    pub zeta: f64,
    pub threshold: f64,
    pub outlier: bool,
}

/// A detector backend processing interleaved multi-stream samples.
///
/// Deliberately NOT `Send`: the XLA engine wraps PJRT handles that are
/// single-threaded; the coordinator constructs each engine *inside* its
/// worker thread.
pub trait Engine {
    /// Engine label ("software" | "rtl" | "xla").
    fn name(&self) -> &'static str;

    /// Absorb one sample; returns any verdicts that became ready (for
    /// this or other streams — batching engines emit in bursts).
    fn ingest(&mut self, sample: &Sample) -> Result<Vec<EngineVerdict>>;

    /// Batch-native processing: absorb a whole burst, appending every
    /// verdict that became ready to `out` instead of allocating a
    /// return `Vec` per sample.
    ///
    /// Contract: bit-identical to calling [`Engine::ingest`] on each
    /// sample in order — same verdicts, same float bit patterns, same
    /// errors at the same sample — differing only in cost. Backends
    /// override the default per-sample fallback to resolve per-stream
    /// state once per *run* of consecutive same-stream samples (see
    /// [`runs`]) and keep the recurrence in a tight loop.
    fn process_batch(
        &mut self,
        samples: &[Sample],
        out: &mut Vec<EngineVerdict>,
    ) -> Result<()> {
        for sample in samples {
            out.extend(self.ingest(sample)?);
        }
        Ok(())
    }

    /// Force out every pending verdict (end of stream / shutdown).
    fn flush(&mut self) -> Result<Vec<EngineVerdict>>;

    /// Streams with in-flight state.
    fn active_streams(&self) -> usize;

    /// Checkpoint one stream's complete detector state, or `None` when
    /// the engine holds no state for that stream yet. Every engine
    /// implements this — failover must not silently degrade by backend.
    fn snapshot(&self, stream_id: u64) -> Option<Snapshot>;

    /// Restore one stream from a snapshot taken by an engine of the
    /// same kind and geometry (failover / migration / rebalancing).
    /// Replaces whatever state this engine already holds for the
    /// stream; samples with `seq` greater than the snapshot's watermark
    /// are then re-fed by the at-least-once upstream.
    fn restore(&mut self, stream_id: u64, snapshot: Snapshot) -> Result<()>;

    /// Drop ALL state for one finished stream (the coordinator's
    /// eviction policy). A no-op for unknown streams. Any in-flight
    /// verdicts for the stream are discarded with it — callers evict
    /// only streams they consider finished. If the same stream id
    /// reappears later it starts fresh at `k = 1`.
    fn evict(&mut self, stream_id: u64);
}

/// Iterate the maximal runs of consecutive same-stream samples in a
/// burst — the unit every batch-native kernel resolves per-stream
/// state for exactly once. Bursts arrive grouped by routed worker, so
/// runs are long in steady state (see EXPERIMENTS.md §Perf).
pub fn runs(samples: &[Sample]) -> impl Iterator<Item = &[Sample]> {
    let mut i = 0;
    std::iter::from_fn(move || {
        if i >= samples.len() {
            return None;
        }
        let sid = samples[i].stream_id;
        let mut j = i + 1;
        while j < samples.len() && samples[j].stream_id == sid {
            j += 1;
        }
        let run = &samples[i..j];
        i = j;
        Some(run)
    })
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::stream::Sample;

    /// Feed `samples` (already interleaved) through an engine and return
    /// verdicts keyed by (stream, seq), asserting uniqueness.
    pub fn run_engine(
        eng: &mut dyn Engine,
        samples: &[Sample],
    ) -> std::collections::BTreeMap<(u64, u64), EngineVerdict> {
        let mut out = std::collections::BTreeMap::new();
        for s in samples {
            for v in eng.ingest(s).unwrap() {
                let key = (v.stream_id, v.seq);
                assert!(out.insert(key, v).is_none(), "duplicate {key:?}");
            }
        }
        for v in eng.flush().unwrap() {
            let key = (v.stream_id, v.seq);
            assert!(out.insert(key, v).is_none(), "duplicate {key:?}");
        }
        out
    }

    /// Round-robin interleave across `n_streams` synthetic streams.
    pub fn interleaved(
        n_streams: u64,
        per_stream: usize,
        n: usize,
        seed: u64,
    ) -> Vec<Sample> {
        use crate::util::prng::SplitMix64;
        let mut rngs: Vec<SplitMix64> = (0..n_streams)
            .map(|s| SplitMix64::new(seed ^ (s * 7919)))
            .collect();
        let mut out = Vec::new();
        for seq in 0..per_stream {
            for sid in 0..n_streams {
                let rng = &mut rngs[sid as usize];
                out.push(Sample {
                    stream_id: sid,
                    seq: seq as u64,
                    values: (0..n).map(|_| rng.uniform(0.0, 1.0)).collect(),
                });
            }
        }
        out
    }
}

//! Bounded MPMC channel (Mutex + Condvar), the backpressure primitive.
//!
//! Closure happens two ways: implicitly when one side's handles all
//! drop (the original contract), or explicitly via [`Sender::close`] —
//! needed since the lock-free sender registry retains `Sender` clones
//! for the life of the service, so closure-by-last-drop alone can no
//! longer signal worker retirement. A closed channel still delivers
//! everything already buffered before `recv` starts erroring.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Error: channel closed (explicitly, or no receivers remain).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SendError;

/// Error: channel closed (explicitly, or no senders remain) and empty.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

struct Shared<T> {
    q: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

struct State<T> {
    buf: VecDeque<T>,
    senders: usize,
    receivers: usize,
    closed: bool,
}

/// Sending half (clonable).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half (clonable — MPMC).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// Create an unbounded channel (`send` never blocks). Use ONLY for
/// result/return paths where the producer must never deadlock against
/// its own consumer; ingress paths should stay [`bounded`] so
/// backpressure reaches the sources.
pub fn unbounded<T>() -> (Sender<T>, Receiver<T>) {
    bounded(usize::MAX)
}

/// Create a bounded channel with capacity `cap` (≥ 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1, "capacity must be >= 1");
    let shared = Arc::new(Shared {
        q: Mutex::new(State {
            // Pre-size modestly; unbounded channels grow on demand.
            buf: VecDeque::with_capacity(cap.min(1024)),
            senders: 1,
            receivers: 1,
            closed: false,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        cap,
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Blocking send; returns Err when the channel is closed or every
    /// receiver is gone (a blocked send also unblocks with Err on
    /// [`Sender::close`]).
    pub fn send(&self, value: T) -> Result<(), SendError> {
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if st.receivers == 0 || st.closed {
                return Err(SendError);
            }
            if st.buf.len() < self.shared.cap {
                st.buf.push_back(value);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; returns the value back (`Ok(Some(value))`)
    /// when the queue is full so the caller can count a backpressure
    /// event and fall back to a blocking [`Sender::send`].
    pub fn try_send(&self, value: T) -> Result<Option<T>, SendError> {
        let mut st = self.shared.q.lock().unwrap();
        if st.receivers == 0 || st.closed {
            return Err(SendError);
        }
        if st.buf.len() < self.shared.cap {
            st.buf.push_back(value);
            self.shared.not_empty.notify_one();
            Ok(None)
        } else {
            Ok(Some(value))
        }
    }

    /// Blocking send that hands the value back on closure instead of
    /// dropping it — the submit retry path re-routes the job under a
    /// fresh table rather than losing it.
    pub fn send_reclaim(&self, value: T) -> Result<(), T> {
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if st.receivers == 0 || st.closed {
                return Err(value);
            }
            if st.buf.len() < self.shared.cap {
                st.buf.push_back(value);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Whether the queue is currently at capacity (racy; used for
    /// backpressure accounting before a blocking send).
    pub fn is_full(&self) -> bool {
        self.shared.q.lock().unwrap().buf.len() >= self.shared.cap
    }

    /// Explicitly close the channel from the sending side: subsequent
    /// sends error immediately, receivers drain what is already
    /// buffered and then see [`RecvError`]. Idempotent.
    pub fn close(&self) {
        let mut st = self.shared.q.lock().unwrap();
        st.closed = true;
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    /// Current queue depth (diagnostics only; racy by nature).
    pub fn len(&self) -> usize {
        self.shared.q.lock().unwrap().buf.len()
    }

    /// Whether the queue is currently empty (racy; diagnostics).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.q.lock().unwrap().senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.q.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            // Wake blocked receivers so they observe closure.
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; returns Err when the channel is closed (all
    /// senders gone, or explicit close) AND the buffer is drained.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.shared.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 || st.closed {
                return Err(RecvError);
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with a timeout; `Ok(None)` on timeout.
    pub fn recv_timeout(&self, dur: Duration) -> Result<Option<T>, RecvError> {
        let deadline = std::time::Instant::now() + dur;
        let mut st = self.shared.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.shared.not_full.notify_one();
                return Ok(Some(v));
            }
            if st.senders == 0 || st.closed {
                return Err(RecvError);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self
                .shared
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if res.timed_out() && st.buf.is_empty() {
                if st.senders == 0 || st.closed {
                    return Err(RecvError);
                }
                return Ok(None);
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<Option<T>, RecvError> {
        let mut st = self.shared.q.lock().unwrap();
        if let Some(v) = st.buf.pop_front() {
            self.shared.not_full.notify_one();
            return Ok(Some(v));
        }
        if st.senders == 0 || st.closed {
            return Err(RecvError);
        }
        Ok(None)
    }

    /// Whether the buffer is currently empty (racy; used by the worker
    /// park predicate together with the doorbell's re-check protocol).
    pub fn is_empty(&self) -> bool {
        self.shared.q.lock().unwrap().buf.is_empty()
    }

    /// Whether the channel is closed (explicitly or all senders gone).
    /// Buffered items may still be pending even when true.
    pub fn is_closed(&self) -> bool {
        let st = self.shared.q.lock().unwrap();
        st.senders == 0 || st.closed
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.q.lock().unwrap().receivers += 1;
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.q.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            self.shared.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order_single_thread() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn blocks_and_resumes_on_full() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(tx.try_send(3).unwrap(), Some(3)); // full, value back
        let t = thread::spawn(move || tx.send(3)); // blocks
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1); // frees a slot
        t.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }

    #[test]
    fn recv_err_after_senders_drop() {
        let (tx, rx) = bounded::<u32>(4);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 7);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn send_err_after_receiver_drop() {
        let (tx, rx) = bounded::<u32>(4);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError));
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (tx, rx) = bounded::<u32>(4);
        assert_eq!(rx.recv_timeout(Duration::from_millis(10)).unwrap(), None);
        tx.send(9).unwrap();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)).unwrap(),
            Some(9)
        );
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let (tx, rx) = bounded::<u64>(16);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..1000u64 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), 4000);
        all.dedup();
        assert_eq!(all.len(), 4000, "duplicate deliveries");
    }

    #[test]
    fn send_reclaim_returns_the_value_on_closure() {
        let (tx, rx) = bounded::<u32>(2);
        assert_eq!(tx.send_reclaim(1), Ok(()));
        tx.close();
        assert_eq!(tx.send_reclaim(2), Err(2));
        drop(rx);
        assert_eq!(tx.send_reclaim(3), Err(3));
    }

    #[test]
    fn explicit_close_delivers_buffered_then_errors() {
        let (tx, rx) = bounded::<u32>(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        tx.close();
        // New sends fail immediately even though receivers exist...
        assert_eq!(tx.send(3), Err(SendError));
        assert_eq!(tx.try_send(3), Err(SendError));
        // ...but the backlog still drains in order before RecvError.
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        assert!(rx.recv().is_err());
        assert!(rx.try_recv().is_err());
        assert!(rx.is_closed());
    }

    #[test]
    fn close_unblocks_a_blocked_sender() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let tx2 = tx.clone();
        let t = thread::spawn(move || tx2.send(2)); // blocks: full
        thread::sleep(Duration::from_millis(20));
        tx.close();
        assert_eq!(t.join().unwrap(), Err(SendError));
        assert_eq!(rx.recv().unwrap(), 1);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn close_unblocks_a_blocked_receiver() {
        let (tx, rx) = bounded::<u32>(1);
        let t = thread::spawn(move || rx.recv());
        thread::sleep(Duration::from_millis(20));
        tx.close();
        assert!(t.join().unwrap().is_err());
    }

    #[test]
    fn per_producer_fifo_preserved() {
        // Single consumer: items from one producer arrive in their send
        // order (the per-stream ordering property the router relies on).
        let (tx, rx) = bounded::<(u8, u64)>(4);
        let t1 = {
            let tx = tx.clone();
            thread::spawn(move || {
                for i in 0..500 {
                    tx.send((1, i)).unwrap();
                }
            })
        };
        drop(tx);
        let mut last = None;
        while let Ok((p, i)) = rx.recv() {
            assert_eq!(p, 1);
            if let Some(prev) = last {
                assert!(i > prev);
            }
            last = Some(i);
        }
        t1.join().unwrap();
        assert_eq!(last, Some(499));
    }
}

//! Stream sources: the sample message type plus replay & synthetic
//! generators feeding the coordinator.

use crate::damadics::Trace;
use crate::util::prng::SplitMix64;

/// One sample travelling through the service.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Which logical stream this sample belongs to.
    pub stream_id: u64,
    /// Per-stream sequence number (0-based, contiguous).
    pub seq: u64,
    /// Feature vector (length N, fixed per stream).
    pub values: Vec<f64>,
}

/// Anything that can produce the next sample of a stream.
pub trait StreamSource: Send {
    /// The stream id this source feeds.
    fn stream_id(&self) -> u64;

    /// Next sample, or `None` when the source is exhausted.
    fn next_sample(&mut self) -> Option<Sample>;

    /// Feature dimension.
    fn n_features(&self) -> usize;
}

/// Replays a recorded [`Trace`] (e.g. a DAMADICS day) as a stream.
pub struct ReplaySource {
    stream_id: u64,
    trace: Trace,
    pos: usize,
    /// Optional cap on replayed samples (whole trace when None).
    limit: Option<usize>,
}

impl ReplaySource {
    pub fn new(stream_id: u64, trace: Trace) -> Self {
        ReplaySource { stream_id, trace, pos: 0, limit: None }
    }

    /// Replay only the first `limit` samples.
    pub fn with_limit(mut self, limit: usize) -> Self {
        self.limit = Some(limit);
        self
    }

    /// Ground-truth label for a sequence number (fault window membership).
    pub fn label(&self, seq: u64) -> Option<bool> {
        self.trace.labels.get(seq as usize).copied()
    }
}

impl StreamSource for ReplaySource {
    fn stream_id(&self) -> u64 {
        self.stream_id
    }

    fn next_sample(&mut self) -> Option<Sample> {
        if let Some(l) = self.limit {
            if self.pos >= l {
                return None;
            }
        }
        let s = self.trace.samples.get(self.pos)?;
        let sample = Sample {
            stream_id: self.stream_id,
            seq: self.pos as u64,
            values: s.clone(),
        };
        self.pos += 1;
        Some(sample)
    }

    fn n_features(&self) -> usize {
        self.trace.n_features()
    }
}

/// Synthetic stationary stream with occasional injected outliers —
/// the workload generator for throughput/latency benches.
pub struct SyntheticSource {
    stream_id: u64,
    n: usize,
    rng: SplitMix64,
    seq: u64,
    total: usize,
    /// Probability of an injected gross outlier per sample.
    outlier_p: f64,
}

impl SyntheticSource {
    pub fn new(stream_id: u64, n: usize, total: usize, seed: u64) -> Self {
        SyntheticSource {
            stream_id,
            n,
            rng: SplitMix64::new(seed ^ stream_id.wrapping_mul(0x9E37)),
            seq: 0,
            total,
            outlier_p: 0.0,
        }
    }

    /// Inject gross outliers with probability `p` per sample.
    pub fn with_outliers(mut self, p: f64) -> Self {
        self.outlier_p = p;
        self
    }
}

impl StreamSource for SyntheticSource {
    fn stream_id(&self) -> u64 {
        self.stream_id
    }

    fn next_sample(&mut self) -> Option<Sample> {
        if self.seq as usize >= self.total {
            return None;
        }
        let outlier = self.rng.next_f64() < self.outlier_p;
        let values: Vec<f64> = (0..self.n)
            .map(|_| {
                let base = self.rng.normal_with(0.5, 0.05);
                if outlier {
                    base + 25.0
                } else {
                    base
                }
            })
            .collect();
        let s = Sample { stream_id: self.stream_id, seq: self.seq, values };
        self.seq += 1;
        Some(s)
    }

    fn n_features(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::damadics::ActuatorSim;

    #[test]
    fn replay_source_replays_in_order() {
        let mut cfg = crate::damadics::ActuatorConfig::default();
        cfg.samples = 100;
        let trace = ActuatorSim::new(5, cfg).generate_day(None);
        let mut src = ReplaySource::new(7, trace);
        assert_eq!(src.n_features(), 2);
        let mut count = 0u64;
        while let Some(s) = src.next_sample() {
            assert_eq!(s.stream_id, 7);
            assert_eq!(s.seq, count);
            count += 1;
        }
        assert_eq!(count, 100);
    }

    #[test]
    fn replay_limit_respected() {
        let mut cfg = crate::damadics::ActuatorConfig::default();
        cfg.samples = 50;
        let trace = ActuatorSim::new(5, cfg).generate_day(None);
        let mut src = ReplaySource::new(1, trace).with_limit(10);
        let mut n = 0;
        while src.next_sample().is_some() {
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn synthetic_deterministic_and_bounded() {
        let collect = |seed| {
            let mut s = SyntheticSource::new(3, 2, 20, seed);
            let mut v = Vec::new();
            while let Some(x) = s.next_sample() {
                v.push(x);
            }
            v
        };
        let a = collect(9);
        let b = collect(9);
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        assert!(a.iter().all(|s| s.values.len() == 2));
    }

    #[test]
    fn synthetic_outliers_injected() {
        let mut s = SyntheticSource::new(1, 1, 2000, 4).with_outliers(0.05);
        let mut big = 0;
        while let Some(x) = s.next_sample() {
            if x.values[0] > 10.0 {
                big += 1;
            }
        }
        assert!(big > 20 && big < 300, "big={big}");
    }
}

//! Streaming substrate: bounded channels with backpressure, sample
//! messages, and stream sources.
//!
//! `std::sync::mpsc` has no bounded MPMC flavour and crates.io is
//! unavailable in this environment (DESIGN.md §3), so [`channel`]
//! provides the Mutex+Condvar bounded channel the coordinator is built
//! on: `send` *blocks* when the queue is full — that is the
//! backpressure mechanism propagating from a slow engine all the way to
//! the sources.

mod channel;
mod source;

pub use channel::{bounded, unbounded, Receiver, RecvError, SendError, Sender};
pub use source::{ReplaySource, Sample, StreamSource, SyntheticSource};

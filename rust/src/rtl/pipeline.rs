//! The paper's TEDA pipeline netlist (Figs. 1–5), instantiated
//! component-by-component with the paper's instance names.
//!
//! Bit-exactness contract: for every sample with `k ≥ 2` and σ² > 0 the
//! wire values equal `teda::TedaState::<f32>::step` exactly (same IEEE
//! operations in the same order — see rtl_vs_oracle integration tests).
//! At `k = 1` the ECCENTRICITY divider sees 0/0 (the paper's Eq. 1 guard
//! `[σ²] > 0` notes the value is undefined there); the NaN propagates to
//! OCOMP1 which — like the FPGA comparator core — returns *false* for
//! unordered comparisons, so the k = 1 sample is never flagged, matching
//! Algorithm 1.

use crate::{Error, Result};

use super::netlist::{CompKind, Netlist, RegFile, Wire};

/// Checkpoint of a live [`TedaRtl`] pipeline: the full register file
/// (pipeline registers included, so in-flight samples survive) plus the
/// sample counter. Loading it into a freshly constructed pipeline of the
/// same `(n, m)` resumes the stream bit-exactly — the paper's
/// architectural state `(μ, σ², k)` lives in MREGn/VREG1/KCNT, and the
/// stage A→B / B→C registers carry the ≤ `LATENCY` samples whose
/// verdicts have not left the OUTLIER module yet.
#[derive(Debug, Clone, PartialEq)]
pub struct RtlSnapshot {
    /// Feature count the pipeline was built for.
    pub n: usize,
    /// Chebyshev multiplier baked into the CONSTM core.
    pub m: f32,
    /// Samples clocked in so far.
    pub samples_in: u64,
    /// Every register's latched value + the KCNT state.
    pub regs: RegFile,
}

/// One classified sample leaving the OUTLIER module.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RtlVerdict {
    /// Sample index k (1-based), from the OREG2-synchronized counter.
    pub k: u64,
    /// Eccentricity ξ_k (NaN at k = 1 — see module docs).
    pub eccentricity: f32,
    /// Normalized eccentricity ζ_k.
    pub zeta: f32,
    /// Chebyshev threshold (m²+1)/(2k) from the D3 divider.
    pub threshold: f32,
    /// OCOMP1 output.
    pub outlier: bool,
    /// Mean vector μ_k as latched in the MREGn registers.
    pub variance: f32,
}

/// The full TEDA hardware pipeline for `n` features.
///
/// ```
/// use teda_fpga::rtl::TedaRtl;
/// let mut rtl = TedaRtl::new(2, 3.0).unwrap();
/// assert_eq!(rtl.clock(&[0.5, 0.5]).unwrap(), None); // pipeline filling
/// assert_eq!(rtl.clock(&[0.6, 0.4]).unwrap(), None);
/// let v = rtl.clock(&[0.5, 0.5]).unwrap().unwrap();  // verdict for k=1
/// assert_eq!(v.k, 1);
/// assert!(!v.outlier);
/// ```
pub struct TedaRtl {
    nl: Netlist,
    n: usize,
    m: f32,
    // Input ports.
    x_in: Vec<Wire>,
    // Observed output wires (stage C).
    ecc: Wire,
    zeta: Wire,
    threshold: Wire,
    outlier: Wire,
    k_out: Wire,
    var_wire: Wire,
    samples_in: u64,
}

/// Pipeline latency: a sample's verdict appears this many cycles after
/// it is clocked in (§4.1: ECCENTRICITY/OUTLIER are two cycles delayed
/// w.r.t. the MEAN input). The first verdict therefore completes at the
/// end of cycle 3 — the paper's initial delay d = 3·t_c (Eq. 7).
pub const LATENCY: u64 = 2;

impl TedaRtl {
    /// Build the netlist for `n`-feature samples and threshold `m`.
    pub fn new(n: usize, m: f32) -> Result<Self> {
        if n == 0 {
            return Err(Error::Rtl("n_features must be > 0".into()));
        }
        if !(m > 0.0) {
            return Err(Error::Rtl(format!("m must be > 0, got {m}")));
        }
        let mut nl = Netlist::new();

        // ------------------------------------------------- input ports
        let x_in: Vec<Wire> = (0..n).map(|_| nl.input()).collect();

        // ------------------------------------------------- K-logic
        // Sample counter with int→float converters; k_prev = k − 1 comes
        // from the pre-increment register value (free in hardware).
        let kk = nl.add("KCNT", CompKind::Counter, &[])?;
        let (k, k_prev) = (kk[0], kk[1]);
        let one = nl.add1("CONST1", CompKind::Const(1.0), &[])?;
        // D1: 1/k, D2: (k−1)/k — the two shared divider cores.
        let inv_k = nl.add1("D1", CompKind::Div, &[one, k])?;
        let ratio = nl.add1("D2", CompKind::Div, &[k_prev, k])?;

        // ------------------------------------------------- MEAN (Fig. 2)
        // Per feature: MCOMPn, MMUXn, MREGn, MMULT1n, MMULT2n, MSUMn.
        let mut mu_regs = Vec::with_capacity(n); // MREGn outputs = μ_{k}
        for i in 1..=n {
            let is_first =
                nl.add1(format!("MCOMP{i}"), CompKind::CompEqConst(1.0), &[k])?;
            let mreg = nl.add1(
                format!("MREG{i}"),
                CompKind::Reg { init: 0.0 },
                &[],
            )?;
            let m1 =
                nl.add1(format!("MMULT1{i}"), CompKind::Mult, &[mreg, ratio])?;
            let m2 = nl.add1(
                format!("MMULT2{i}"),
                CompKind::Mult,
                &[x_in[i - 1], inv_k],
            )?;
            let msum = nl.add1(format!("MSUM{i}"), CompKind::Add, &[m1, m2])?;
            let mmux = nl.add1(
                format!("MMUX{i}"),
                CompKind::Mux,
                &[is_first, x_in[i - 1], msum],
            )?;
            nl.connect_reg(&format!("MREG{i}"), mmux)?;
            mu_regs.push(mreg);
        }

        // --------------------------------- stage A→B pipeline registers
        // VREGn delay the sample, VREG2 delays k (§4.3); IREG1/RREG1
        // delay the shared 1/k and (k−1)/k values ("to avoid redundant
        // operations" — §4.3 note on forwarding 1/k).
        let mut x_d = Vec::with_capacity(n);
        for i in 1..=n {
            let r = nl.add1(
                format!("VREG{}", i + 2),
                CompKind::Reg { init: 0.0 },
                &[],
            )?;
            nl.connect_reg(&format!("VREG{}", i + 2), x_in[i - 1])?;
            x_d.push(r);
        }
        let k_d = nl.add1("VREG2", CompKind::Reg { init: 0.0 }, &[])?;
        nl.connect_reg("VREG2", k)?;
        let inv_k_d = nl.add1("IREG1", CompKind::Reg { init: 0.0 }, &[])?;
        nl.connect_reg("IREG1", inv_k)?;
        let ratio_d = nl.add1("RREG1", CompKind::Reg { init: 0.0 }, &[])?;
        nl.connect_reg("RREG1", ratio)?;

        // --------------------------------------------- VARIANCE (Fig. 3)
        let is_first_d =
            nl.add1("VCOMP1", CompKind::CompEqConst(1.0), &[k_d])?;
        // ‖x_k − μ_k‖²: VSUBn, VMULT1_n, VSUM1 (left-fold adder chain so
        // the sum order matches the software oracle exactly).
        let mut sq_terms = Vec::with_capacity(n);
        for i in 1..=n {
            let d = nl.add1(
                format!("VSUB{i}"),
                CompKind::Sub,
                &[x_d[i - 1], mu_regs[i - 1]],
            )?;
            let sq =
                nl.add1(format!("VMULT1_{i}"), CompKind::Mult, &[d, d])?;
            sq_terms.push(sq);
        }
        let mut sq_dist = sq_terms[0];
        for (j, &t) in sq_terms.iter().enumerate().skip(1) {
            sq_dist =
                nl.add1(format!("VSUM1_{j}"), CompKind::Add, &[sq_dist, t])?;
        }
        let var_reg = nl.add1("VREG1", CompKind::Reg { init: 0.0 }, &[])?;
        let vm3 = nl.add1("VMULT3", CompKind::Mult, &[var_reg, ratio_d])?;
        let vm2 = nl.add1("VMULT2", CompKind::Mult, &[sq_dist, inv_k_d])?;
        let vsum2 = nl.add1("VSUM2", CompKind::Add, &[vm3, vm2])?;
        let zero = nl.add1("CONST0", CompKind::Const(0.0), &[])?;
        let vmux1 =
            nl.add1("VMUX1", CompKind::Mux, &[is_first_d, zero, vsum2])?;
        nl.connect_reg("VREG1", vmux1)?;

        // --------------------------------- stage B→C pipeline registers
        // EREG3 holds ‖x−μ‖², EREG4 the twice-delayed 1/k (Fig. 4);
        // OREG1 the twice-delayed k (Fig. 5).
        let sq_dist_d = nl.add1("EREG3", CompKind::Reg { init: 0.0 }, &[])?;
        nl.connect_reg("EREG3", sq_dist)?;
        let inv_k_dd = nl.add1("EREG4", CompKind::Reg { init: 0.0 }, &[])?;
        nl.connect_reg("EREG4", inv_k_d)?;
        let k_dd = nl.add1("OREG1", CompKind::Reg { init: 0.0 }, &[])?;
        nl.connect_reg("OREG1", k_d)?;

        // ----------------------------------------- ECCENTRICITY (Fig. 4)
        // ξ = 1/k + ‖x−μ‖² / (σ²·k). VREG1 holds σ²_k during this cycle.
        let var_k = nl.add1("EMULT1", CompKind::Mult, &[var_reg, k_dd])?;
        let ediv = nl.add1("EDIV1", CompKind::Div, &[sq_dist_d, var_k])?;
        let ecc = nl.add1("ESUM1", CompKind::Add, &[inv_k_dd, ediv])?;

        // ---------------------------------------------- OUTLIER (Fig. 5)
        // ζ = ξ/2 (ODIV1 — exponent decrement), threshold (m²+1)/2 ÷ k
        // (D3, the constant stored in the module per §4.1), OCOMP1.
        let zeta = nl.add1("ODIV1", CompKind::Half, &[ecc])?;
        let c_thr = nl.add1(
            "CONSTM",
            CompKind::Const((m * m + 1.0) * 0.5),
            &[],
        )?;
        let threshold = nl.add1("D3", CompKind::Div, &[c_thr, k_dd])?;
        let outlier = nl.add1("OCOMP1", CompKind::CompGt, &[zeta, threshold])?;
        // OREG2 re-registers the iteration number at the module boundary
        // (§4.5, Fig. 5); the combinational stage-C outputs read out in
        // the same cycle are synchronized with OREG1's k (`k_dd`).
        let _oreg2 = nl.add1("OREG2", CompKind::Reg { init: 0.0 }, &[])?;
        nl.connect_reg("OREG2", k_dd)?;
        let k_out = k_dd;

        nl.validate()?;
        Ok(TedaRtl {
            nl,
            n,
            m,
            x_in,
            ecc,
            zeta,
            threshold,
            outlier,
            k_out,
            var_wire: var_reg,
            samples_in: 0,
        })
    }

    /// Feature count N.
    pub fn n_features(&self) -> usize {
        self.n
    }

    /// Chebyshev multiplier m.
    pub fn m(&self) -> f32 {
        self.m
    }

    /// Clock one sample in; returns the verdict for sample `k − LATENCY`
    /// once the pipeline is full (`None` during the first two cycles —
    /// the paper's initial delay d = 3·t_c).
    ///
    /// # Errors
    /// Returns an error if `x.len() != n_features`.
    pub fn clock(&mut self, x: &[f32]) -> Result<Option<RtlVerdict>> {
        if x.len() != self.n {
            return Err(Error::Rtl(format!(
                "sample has {} features, pipeline built for {}",
                x.len(),
                self.n
            )));
        }
        for (w, &v) in self.x_in.clone().iter().zip(x) {
            self.nl.set(*w, v);
        }
        self.nl.clock();
        self.samples_in += 1;
        if self.samples_in <= LATENCY {
            return Ok(None);
        }
        Ok(Some(self.read_verdict()))
    }

    /// Flush the pipeline after the last sample: clock `LATENCY` bubbles
    /// and return the remaining verdicts.
    pub fn drain(&mut self) -> Result<Vec<RtlVerdict>> {
        let zeros = vec![0.0; self.n];
        let mut out = Vec::with_capacity(LATENCY as usize);
        for _ in 0..LATENCY {
            // Bubbles advance the pipeline; their own (future) verdicts
            // are discarded by the caller because k_out identifies them.
            if let Some(v) = self.clock(&zeros)? {
                out.push(v);
            }
        }
        // Keep only verdicts for real samples.
        let real = self.samples_in - LATENCY;
        out.retain(|v| v.k <= real);
        Ok(out)
    }

    fn read_verdict(&self) -> RtlVerdict {
        RtlVerdict {
            k: self.nl.get(self.k_out) as u64,
            eccentricity: self.nl.get(self.ecc),
            zeta: self.nl.get(self.zeta),
            threshold: self.nl.get(self.threshold),
            outlier: self.nl.get(self.outlier) != 0.0,
            variance: self.nl.get(self.var_wire),
        }
    }

    /// Run a whole f32 sample batch through the pipeline (clock + drain),
    /// returning one verdict per sample.
    pub fn run(&mut self, samples: &[Vec<f32>]) -> Result<Vec<RtlVerdict>> {
        let mut out = Vec::with_capacity(samples.len());
        for s in samples {
            if let Some(v) = self.clock(s)? {
                out.push(v);
            }
        }
        out.extend(self.drain()?);
        Ok(out)
    }

    /// The underlying netlist (synthesis / netlist dumps).
    pub fn netlist(&self) -> &Netlist {
        &self.nl
    }

    /// Reset pipeline state (k back to 0, registers to init).
    pub fn reset(&mut self) {
        self.nl.reset();
        self.samples_in = 0;
    }

    /// Checkpoint the live pipeline (register file + counters).
    pub fn save(&self) -> RtlSnapshot {
        RtlSnapshot {
            n: self.n,
            m: self.m,
            samples_in: self.samples_in,
            regs: self.nl.save_state(),
        }
    }

    /// Restore a checkpoint taken with [`TedaRtl::save`] from a pipeline
    /// of the same geometry. In-flight samples are restored with the
    /// registers, so the next [`TedaRtl::clock`] emits exactly the
    /// verdict the snapshotted pipeline would have emitted.
    pub fn load(&mut self, snap: &RtlSnapshot) -> Result<()> {
        if snap.n != self.n || snap.m != self.m {
            return Err(Error::Rtl(format!(
                "snapshot is for (n={}, m={}), pipeline is (n={}, m={})",
                snap.n, snap.m, self.n, self.m
            )));
        }
        self.nl.load_state(&snap.regs)?;
        self.samples_in = snap.samples_in;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::teda::TedaState;
    use crate::util::prng::SplitMix64;

    #[test]
    fn pipeline_latency_is_two_cycles() {
        let mut rtl = TedaRtl::new(1, 3.0).unwrap();
        assert!(rtl.clock(&[1.0]).unwrap().is_none());
        assert!(rtl.clock(&[2.0]).unwrap().is_none());
        let v = rtl.clock(&[3.0]).unwrap().unwrap();
        assert_eq!(v.k, 1);
    }

    #[test]
    fn matches_software_oracle_bit_exact() {
        // The central RTL property: wire-level equality with the f32
        // software reference for k ≥ 2.
        let mut rtl = TedaRtl::new(2, 3.0).unwrap();
        let mut sw = TedaState::<f32>::new(2);
        let mut rng = SplitMix64::new(99);
        let samples: Vec<Vec<f32>> = (0..500)
            .map(|_| vec![rng.uniform(-2.0, 2.0) as f32, rng.uniform(-2.0, 2.0) as f32])
            .collect();
        let verdicts = rtl.run(&samples).unwrap();
        assert_eq!(verdicts.len(), samples.len());
        for (i, v) in verdicts.iter().enumerate() {
            let step = sw.step(&samples[i], 3.0);
            assert_eq!(v.k, (i + 1) as u64, "k mismatch");
            if v.k >= 2 {
                assert_eq!(
                    v.eccentricity.to_bits(),
                    step.eccentricity.to_bits(),
                    "ecc bits k={}",
                    v.k
                );
                assert_eq!(v.zeta.to_bits(), step.zeta.to_bits());
                assert_eq!(v.threshold.to_bits(), step.threshold.to_bits());
            }
            assert_eq!(v.outlier, step.outlier, "outlier k={}", v.k);
        }
    }

    #[test]
    fn k1_is_nan_but_not_outlier() {
        let mut rtl = TedaRtl::new(2, 3.0).unwrap();
        let samples = vec![vec![1.0, 2.0], vec![1.5, 2.5], vec![0.5, 1.5]];
        let verdicts = rtl.run(&samples).unwrap();
        assert!(verdicts[0].eccentricity.is_nan());
        assert!(!verdicts[0].outlier);
    }

    #[test]
    fn detects_gross_outlier() {
        let mut rtl = TedaRtl::new(1, 3.0).unwrap();
        let mut rng = SplitMix64::new(3);
        let mut samples: Vec<Vec<f32>> =
            (0..300).map(|_| vec![rng.uniform(0.0, 1.0) as f32]).collect();
        samples.push(vec![1000.0]);
        let verdicts = rtl.run(&samples).unwrap();
        assert!(verdicts.last().unwrap().outlier);
        let flagged = verdicts.iter().filter(|v| v.outlier).count();
        assert!(flagged >= 1 && flagged < 10);
    }

    #[test]
    fn wrong_arity_rejected() {
        let mut rtl = TedaRtl::new(2, 3.0).unwrap();
        assert!(rtl.clock(&[1.0]).is_err());
    }

    #[test]
    fn invalid_construction_rejected() {
        assert!(TedaRtl::new(0, 3.0).is_err());
        assert!(TedaRtl::new(2, 0.0).is_err());
        assert!(TedaRtl::new(2, -1.0).is_err());
    }

    #[test]
    fn reset_replays_identically() {
        let mut rtl = TedaRtl::new(2, 3.0).unwrap();
        let mut rng = SplitMix64::new(17);
        let samples: Vec<Vec<f32>> = (0..50)
            .map(|_| vec![rng.uniform(0.0, 1.0) as f32, rng.uniform(0.0, 1.0) as f32])
            .collect();
        let a = rtl.run(&samples).unwrap();
        rtl.reset();
        let b = rtl.run(&samples).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.k, y.k);
            assert_eq!(x.outlier, y.outlier);
            assert_eq!(x.zeta.to_bits(), y.zeta.to_bits());
        }
    }

    #[test]
    fn component_inventory_matches_paper_n2() {
        // 3N+3 = 9 FP multiplier cores at N=2 (§5.2.1 calibration:
        // 9 cores × 3 DSP48E1 = the paper's 27 "multipliers").
        let rtl = TedaRtl::new(2, 3.0).unwrap();
        let nl = rtl.netlist();
        let mults = nl.count(|c| matches!(c.kind, CompKind::Mult));
        assert_eq!(mults, 9);
        let divs = nl.count(|c| matches!(c.kind, CompKind::Div));
        assert_eq!(divs, 4); // D1, D2, EDIV1, D3
        let regs = nl.count(|c| matches!(c.kind, CompKind::Reg { .. }));
        assert_eq!(regs, 12); // 2 MREG + 2 VREGn + VREG2 + IREG1 + RREG1
                              // + VREG1 + EREG3 + EREG4 + OREG1 + OREG2
    }

    #[test]
    fn multiplier_count_scales_3n_plus_3() {
        for n in 1..=6 {
            let rtl = TedaRtl::new(n, 3.0).unwrap();
            let mults =
                rtl.netlist().count(|c| matches!(c.kind, CompKind::Mult));
            assert_eq!(mults, 3 * n + 3, "n={n}");
        }
    }

    #[test]
    fn save_load_resumes_pipeline_bit_exactly_at_every_cut() {
        // Snapshot after every prefix of a stream; a fresh pipeline
        // restored from the snapshot must emit bitwise-identical verdicts
        // for the rest of the stream, including the in-flight tail.
        let mut rng = SplitMix64::new(23);
        let samples: Vec<Vec<f32>> = (0..40)
            .map(|_| {
                vec![
                    rng.uniform(-2.0, 2.0) as f32,
                    rng.uniform(-2.0, 2.0) as f32,
                ]
            })
            .collect();
        let mut oracle = TedaRtl::new(2, 3.0).unwrap();
        let full = oracle.run(&samples).unwrap();
        for cut in 0..samples.len() {
            let mut live = TedaRtl::new(2, 3.0).unwrap();
            let mut got: Vec<RtlVerdict> = Vec::new();
            for s in &samples[..cut] {
                if let Some(v) = live.clock(s).unwrap() {
                    got.push(v);
                }
            }
            let snap = live.save();
            let mut restored = TedaRtl::new(2, 3.0).unwrap();
            restored.load(&snap).unwrap();
            for s in &samples[cut..] {
                if let Some(v) = restored.clock(s).unwrap() {
                    got.push(v);
                }
            }
            got.extend(restored.drain().unwrap());
            assert_eq!(got.len(), full.len(), "cut={cut}");
            for (a, b) in got.iter().zip(&full) {
                assert_eq!(a.k, b.k, "cut={cut}");
                assert_eq!(a.outlier, b.outlier, "cut={cut} k={}", a.k);
                assert_eq!(
                    a.zeta.to_bits(),
                    b.zeta.to_bits(),
                    "cut={cut} k={}",
                    a.k
                );
            }
        }
    }

    #[test]
    fn load_rejects_geometry_mismatch() {
        let a = TedaRtl::new(2, 3.0).unwrap();
        let snap = a.save();
        let mut wrong_n = TedaRtl::new(3, 3.0).unwrap();
        assert!(wrong_n.load(&snap).is_err());
        let mut wrong_m = TedaRtl::new(2, 2.5).unwrap();
        assert!(wrong_m.load(&snap).is_err());
    }

    #[test]
    fn drain_returns_tail_verdicts_only() {
        let mut rtl = TedaRtl::new(1, 3.0).unwrap();
        for i in 0..5 {
            rtl.clock(&[i as f32]).unwrap();
        }
        let tail = rtl.drain().unwrap();
        assert_eq!(tail.len(), 2);
        assert_eq!(tail[0].k, 4);
        assert_eq!(tail[1].k, 5);
    }
}

//! Generic RTL netlist: components, wires, cycle-based simulation.
//!
//! Semantics: a flat netlist of combinational components and registers
//! over f32 wires (booleans are encoded 0.0/1.0, as a single-bit wire
//! would be). Each clock cycle runs two phases:
//!
//! 1. **evaluate** — combinational components are evaluated in netlist
//!    order (construction enforces topological validity: a combinational
//!    input must already be driven); register components drive their
//!    *latched* state onto their output wire at the start of the phase.
//! 2. **latch** — every register captures its input wire; the counter
//!    increments.
//!
//! This matches synchronous RTL with registers breaking all cycles.

use crate::{Error, Result};

/// Index of a wire in the netlist's value vector.
pub type Wire = usize;

/// Component kinds (one output wire each unless noted).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompKind {
    /// Constant driver.
    Const(f32),
    /// f32 adder.
    Add,
    /// f32 subtractor.
    Sub,
    /// f32 multiplier (DSP-mapped FP core).
    Mult,
    /// f32 divider (logic-mapped FP core).
    Div,
    /// Divide-by-two (exponent decrement — near-free in hardware).
    Half,
    /// 2:1 multiplexer: out = sel != 0 ? a : b.
    Mux,
    /// Equality comparator against a constant: out = (a == c).
    CompEqConst(f32),
    /// Greater-than comparator: out = (a > b).
    CompGt,
    /// 32-bit sample counter with int→float converters. TWO outputs:
    /// `k` (count *after* increment for the incoming sample) and
    /// `k_prev = k − 1` (the register value before increment, free in
    /// hardware). Increments at every latch phase.
    Counter,
    /// f32 register (one output; input connected possibly after
    /// construction to close recurrences).
    Reg { init: f32 },
}

/// One instantiated component.
#[derive(Debug, Clone)]
pub struct Component {
    /// Instance name — the paper's labels (MMULT11, VSUM2, …).
    pub name: String,
    pub kind: CompKind,
    /// Input wires (arity fixed by kind).
    pub inputs: Vec<Wire>,
    /// Output wires (1, or 2 for Counter).
    pub outputs: Vec<Wire>,
}

/// The complete sequential state of a netlist: every register's latched
/// value (in component order) plus the counter. Two identically
/// constructed netlists (same builder code, same parameters) have the
/// same register layout, so a `RegFile` saved from one loads into the
/// other — this is what makes checkpoint/restore of a live pipeline
/// exact: combinational wires are recomputed from registers on the next
/// clock, so registers ARE the pipeline's whole state.
#[derive(Debug, Clone, PartialEq)]
pub struct RegFile {
    /// Latched value of every `Reg` component, in component order.
    regs: Vec<f32>,
    /// Sample counter (pre-increment view).
    counter: u64,
    /// Cycles simulated.
    cycles: u64,
}

impl RegFile {
    /// Rebuild a register file from its raw parts (the persistence
    /// codec's decode path). Shape validation happens when the file is
    /// loaded into a netlist ([`Netlist::load_state`]).
    pub fn from_parts(regs: Vec<f32>, counter: u64, cycles: u64) -> Self {
        RegFile { regs, counter, cycles }
    }

    /// Latched register values, in component order.
    pub fn regs(&self) -> &[f32] {
        &self.regs
    }

    /// Sample counter (pre-increment view).
    pub fn counter(&self) -> u64 {
        self.counter
    }

    /// Cycles simulated when the state was captured.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }
}

/// A complete netlist plus simulation state.
#[derive(Debug, Clone)]
pub struct Netlist {
    comps: Vec<Component>,
    /// Current wire values (phase-1 results).
    values: Vec<f32>,
    /// Which wires are driven (for topological validation).
    driven: Vec<bool>,
    /// Register states, indexed like `comps` (None for non-regs).
    reg_state: Vec<Option<f32>>,
    /// Counter state (sample count before increment).
    counter_state: u64,
    cycles: u64,
}

impl Default for Netlist {
    fn default() -> Self {
        Self::new()
    }
}

impl Netlist {
    /// Empty netlist.
    pub fn new() -> Self {
        Netlist {
            comps: Vec::new(),
            values: Vec::new(),
            driven: Vec::new(),
            reg_state: Vec::new(),
            counter_state: 0,
            cycles: 0,
        }
    }

    /// Allocate an *input port* wire (driven externally each cycle).
    pub fn input(&mut self) -> Wire {
        let w = self.alloc_wire();
        self.driven[w] = true;
        w
    }

    fn alloc_wire(&mut self) -> Wire {
        self.values.push(0.0);
        self.driven.push(false);
        self.values.len() - 1
    }

    fn check_driven(&self, name: &str, ins: &[Wire]) -> Result<()> {
        for &w in ins {
            if !self.driven[w] {
                return Err(Error::Rtl(format!(
                    "component {name}: input wire {w} not yet driven \
                     (combinational loop or construction-order bug)"
                )));
            }
        }
        Ok(())
    }

    /// Add a component; returns its output wire(s).
    ///
    /// Combinational inputs must already be driven (register outputs are
    /// driven from construction time, so recurrences go through `Reg`).
    pub fn add(
        &mut self,
        name: impl Into<String>,
        kind: CompKind,
        inputs: &[Wire],
    ) -> Result<Vec<Wire>> {
        let name = name.into();
        let arity = match kind {
            CompKind::Const(_) => 0,
            CompKind::Counter => 0,
            CompKind::Half | CompKind::CompEqConst(_) => 1,
            CompKind::Reg { .. } => 0, // input connected separately
            CompKind::Add
            | CompKind::Sub
            | CompKind::Mult
            | CompKind::Div
            | CompKind::CompGt => 2,
            CompKind::Mux => 3,
        };
        if inputs.len() != arity {
            return Err(Error::Rtl(format!(
                "component {name}: arity {} expected, got {}",
                arity,
                inputs.len()
            )));
        }
        // Registers break cycles: their inputs are wired later. All other
        // components are combinational and need driven inputs NOW.
        if !matches!(kind, CompKind::Reg { .. }) {
            self.check_driven(&name, inputs)?;
        }
        let n_outputs = if matches!(kind, CompKind::Counter) { 2 } else { 1 };
        let outputs: Vec<Wire> =
            (0..n_outputs).map(|_| self.alloc_wire()).collect();
        for &w in &outputs {
            self.driven[w] = true; // regs/counter drive state; comb computed
        }
        let state = match kind {
            CompKind::Reg { init } => Some(init),
            _ => None,
        };
        self.reg_state.push(state);
        self.comps.push(Component { name, kind, inputs: inputs.to_vec(), outputs });
        Ok(self.comps.last().unwrap().outputs.clone())
    }

    /// Convenience: add and return the single output wire.
    pub fn add1(
        &mut self,
        name: impl Into<String>,
        kind: CompKind,
        inputs: &[Wire],
    ) -> Result<Wire> {
        Ok(self.add(name, kind, inputs)?[0])
    }

    /// Connect a register's input wire (closing a recurrence).
    pub fn connect_reg(&mut self, reg_name: &str, input: Wire) -> Result<()> {
        if !self.driven[input] {
            return Err(Error::Rtl(format!(
                "connect_reg {reg_name}: wire {input} not driven"
            )));
        }
        let comp = self
            .comps
            .iter_mut()
            .find(|c| c.name == reg_name)
            .ok_or_else(|| Error::Rtl(format!("no component {reg_name}")))?;
        if !matches!(comp.kind, CompKind::Reg { .. }) {
            return Err(Error::Rtl(format!("{reg_name} is not a register")));
        }
        if !comp.inputs.is_empty() {
            return Err(Error::Rtl(format!("{reg_name} already connected")));
        }
        comp.inputs.push(input);
        Ok(())
    }

    /// Every register must have exactly one input after construction.
    pub fn validate(&self) -> Result<()> {
        for c in &self.comps {
            if matches!(c.kind, CompKind::Reg { .. }) && c.inputs.len() != 1 {
                return Err(Error::Rtl(format!(
                    "register {} left unconnected",
                    c.name
                )));
            }
        }
        Ok(())
    }

    /// Drive an input-port wire for the current cycle.
    pub fn set(&mut self, wire: Wire, value: f32) {
        self.values[wire] = value;
    }

    /// Read any wire's current (post-evaluate) value.
    pub fn get(&self, wire: Wire) -> f32 {
        self.values[wire]
    }

    /// One clock cycle: evaluate then latch.
    pub fn clock(&mut self) {
        // Phase 1 — evaluate in construction (topological) order.
        for (i, c) in self.comps.iter().enumerate() {
            let v = &mut self.values;
            match c.kind {
                CompKind::Const(x) => v[c.outputs[0]] = x,
                CompKind::Add => {
                    v[c.outputs[0]] = v[c.inputs[0]] + v[c.inputs[1]]
                }
                CompKind::Sub => {
                    v[c.outputs[0]] = v[c.inputs[0]] - v[c.inputs[1]]
                }
                CompKind::Mult => {
                    v[c.outputs[0]] = v[c.inputs[0]] * v[c.inputs[1]]
                }
                CompKind::Div => {
                    v[c.outputs[0]] = v[c.inputs[0]] / v[c.inputs[1]]
                }
                CompKind::Half => v[c.outputs[0]] = v[c.inputs[0]] * 0.5,
                CompKind::Mux => {
                    v[c.outputs[0]] = if v[c.inputs[0]] != 0.0 {
                        v[c.inputs[1]]
                    } else {
                        v[c.inputs[2]]
                    }
                }
                CompKind::CompEqConst(x) => {
                    v[c.outputs[0]] =
                        if v[c.inputs[0]] == x { 1.0 } else { 0.0 }
                }
                CompKind::CompGt => {
                    v[c.outputs[0]] =
                        if v[c.inputs[0]] > v[c.inputs[1]] { 1.0 } else { 0.0 }
                }
                CompKind::Counter => {
                    // k for the sample entering THIS cycle (post-increment
                    // view), k_prev = k − 1 (pre-increment register).
                    let k = self.counter_state + 1;
                    v[c.outputs[0]] = k as f32;
                    v[c.outputs[1]] = self.counter_state as f32;
                }
                CompKind::Reg { .. } => {
                    v[c.outputs[0]] = self.reg_state[i].unwrap();
                }
            }
        }
        // Phase 2 — latch.
        for (i, c) in self.comps.iter().enumerate() {
            match c.kind {
                CompKind::Reg { .. } => {
                    self.reg_state[i] = Some(self.values[c.inputs[0]]);
                }
                CompKind::Counter => {}
                _ => {}
            }
        }
        self.counter_state += 1;
        self.cycles += 1;
    }

    /// Reset registers to their init values and the counter to zero.
    pub fn reset(&mut self) {
        for (i, c) in self.comps.iter().enumerate() {
            if let CompKind::Reg { init } = c.kind {
                self.reg_state[i] = Some(init);
            }
        }
        self.counter_state = 0;
        self.cycles = 0;
        for v in &mut self.values {
            *v = 0.0;
        }
    }

    /// Cycles simulated since construction/reset.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Capture the full sequential state (registers + counter).
    pub fn save_state(&self) -> RegFile {
        RegFile {
            regs: self.reg_state.iter().filter_map(|s| *s).collect(),
            counter: self.counter_state,
            cycles: self.cycles,
        }
    }

    /// Restore sequential state previously captured with
    /// [`Netlist::save_state`] from an identically constructed netlist.
    ///
    /// Combinational wire values are NOT restored: they are recomputed
    /// from the registers on the next [`Netlist::clock`], exactly as in
    /// hardware after a bitstream readback-capture restore.
    pub fn load_state(&mut self, rf: &RegFile) -> Result<()> {
        let n_regs = self.reg_state.iter().filter(|s| s.is_some()).count();
        if rf.regs.len() != n_regs {
            return Err(Error::Rtl(format!(
                "register file has {} entries, netlist has {} registers \
                 (snapshot from a differently shaped netlist?)",
                rf.regs.len(),
                n_regs
            )));
        }
        let mut it = rf.regs.iter();
        for s in self.reg_state.iter_mut() {
            if s.is_some() {
                *s = Some(*it.next().unwrap());
            }
        }
        self.counter_state = rf.counter;
        self.cycles = rf.cycles;
        Ok(())
    }

    /// All components (for synthesis/timing analysis and netlist dumps).
    pub fn components(&self) -> &[Component] {
        &self.comps
    }

    /// Count components matching a predicate.
    pub fn count(&self, pred: impl Fn(&Component) -> bool) -> usize {
        self.comps.iter().filter(|c| pred(c)).count()
    }

    /// Human-readable netlist dump (one line per instance).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for c in &self.comps {
            out.push_str(&format!(
                "{:<12} {:?} inputs={:?} outputs={:?}\n",
                c.name, c.kind, c.inputs, c.outputs
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combinational_add_mult() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let sum = nl.add1("S", CompKind::Add, &[a, b]).unwrap();
        let prod = nl.add1("P", CompKind::Mult, &[sum, b]).unwrap();
        nl.set(a, 2.0);
        nl.set(b, 3.0);
        nl.clock();
        assert_eq!(nl.get(sum), 5.0);
        assert_eq!(nl.get(prod), 15.0);
    }

    #[test]
    fn register_delays_one_cycle() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let r = nl.add1("R", CompKind::Reg { init: 9.0 }, &[]).unwrap();
        nl.connect_reg("R", a).unwrap();
        nl.validate().unwrap();
        nl.set(a, 1.0);
        nl.clock();
        assert_eq!(nl.get(r), 9.0); // init visible during first cycle
        nl.set(a, 2.0);
        nl.clock();
        assert_eq!(nl.get(r), 1.0); // previous input
    }

    #[test]
    fn register_recurrence_accumulates() {
        // r <= r + in  (accumulator)
        let mut nl = Netlist::new();
        let a = nl.input();
        let r = nl.add1("R", CompKind::Reg { init: 0.0 }, &[]).unwrap();
        let sum = nl.add1("S", CompKind::Add, &[r, a]).unwrap();
        nl.connect_reg("R", sum).unwrap();
        for i in 1..=4 {
            nl.set(a, i as f32);
            nl.clock();
        }
        assert_eq!(nl.get(sum), 10.0); // 1+2+3+4
    }

    #[test]
    fn counter_outputs_k_and_prev() {
        let mut nl = Netlist::new();
        let outs = nl.add("K", CompKind::Counter, &[]).unwrap();
        nl.clock();
        assert_eq!(nl.get(outs[0]), 1.0);
        assert_eq!(nl.get(outs[1]), 0.0);
        nl.clock();
        assert_eq!(nl.get(outs[0]), 2.0);
        assert_eq!(nl.get(outs[1]), 1.0);
    }

    #[test]
    fn mux_and_comparators() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let b = nl.input();
        let eq = nl.add1("E", CompKind::CompEqConst(1.0), &[a]).unwrap();
        let gt = nl.add1("G", CompKind::CompGt, &[a, b]).unwrap();
        let mux = nl.add1("M", CompKind::Mux, &[eq, a, b]).unwrap();
        nl.set(a, 1.0);
        nl.set(b, 5.0);
        nl.clock();
        assert_eq!(nl.get(eq), 1.0);
        assert_eq!(nl.get(gt), 0.0);
        assert_eq!(nl.get(mux), 1.0);
        nl.set(a, 7.0);
        nl.clock();
        assert_eq!(nl.get(eq), 0.0);
        assert_eq!(nl.get(gt), 1.0);
        assert_eq!(nl.get(mux), 5.0);
    }

    #[test]
    fn use_before_def_rejected() {
        let mut nl = Netlist::new();
        let r = nl.add1("R", CompKind::Reg { init: 0.0 }, &[]).unwrap();
        // Wire r+1 does not exist / is not driven:
        let bogus = r + 100;
        let _ = bogus;
        let a = nl.alloc_wire_public_for_test();
        assert!(nl.add1("S", CompKind::Add, &[r, a]).is_err());
    }

    #[test]
    fn unconnected_register_fails_validation() {
        let mut nl = Netlist::new();
        nl.add1("R", CompKind::Reg { init: 0.0 }, &[]).unwrap();
        assert!(nl.validate().is_err());
    }

    #[test]
    fn reset_restores_initial_state() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let r = nl.add1("R", CompKind::Reg { init: 3.0 }, &[]).unwrap();
        nl.connect_reg("R", a).unwrap();
        nl.set(a, 8.0);
        nl.clock();
        nl.clock();
        assert_eq!(nl.get(r), 8.0);
        nl.reset();
        nl.set(a, 0.0);
        nl.clock();
        assert_eq!(nl.get(r), 3.0);
        assert_eq!(nl.cycles(), 1);
    }

    #[test]
    fn save_load_state_resumes_accumulator_exactly() {
        // r <= r + in, snapshotted mid-run and restored into a fresh
        // identically built netlist: both must continue identically.
        fn build() -> (Netlist, Wire, Wire) {
            let mut nl = Netlist::new();
            let a = nl.input();
            let r = nl.add1("R", CompKind::Reg { init: 0.0 }, &[]).unwrap();
            let sum = nl.add1("S", CompKind::Add, &[r, a]).unwrap();
            nl.connect_reg("R", sum).unwrap();
            (nl, a, sum)
        }
        let (mut live, a1, s1) = build();
        for i in 1..=5 {
            live.set(a1, i as f32);
            live.clock();
        }
        let rf = live.save_state();
        let (mut restored, a2, s2) = build();
        restored.load_state(&rf).unwrap();
        assert_eq!(restored.cycles(), live.cycles());
        for i in 6..=9 {
            live.set(a1, i as f32);
            restored.set(a2, i as f32);
            live.clock();
            restored.clock();
            assert_eq!(live.get(s1), restored.get(s2));
        }
    }

    #[test]
    fn load_state_rejects_mismatched_shape() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let r = nl.add1("R", CompKind::Reg { init: 0.0 }, &[]).unwrap();
        nl.connect_reg("R", a).unwrap();
        let _ = r;
        let mut other = Netlist::new();
        let b = other.input();
        for i in 0..2 {
            let name = format!("R{i}");
            other.add1(&name, CompKind::Reg { init: 0.0 }, &[]).unwrap();
            other.connect_reg(&name, b).unwrap();
        }
        assert!(nl.load_state(&other.save_state()).is_err());
    }

    #[test]
    fn half_is_exact() {
        let mut nl = Netlist::new();
        let a = nl.input();
        let h = nl.add1("H", CompKind::Half, &[a]).unwrap();
        nl.set(a, 7.0);
        nl.clock();
        assert_eq!(nl.get(h), 3.5);
    }

    impl Netlist {
        /// Test helper: an undriven wire.
        fn alloc_wire_public_for_test(&mut self) -> Wire {
            self.alloc_wire()
        }
    }
}

//! Cycle-accurate RTL simulator of the paper's TEDA hardware
//! architecture (Figs. 1–5).
//!
//! This module is the substitution for the paper's Virtex-6 FPGA
//! implementation (DESIGN.md §2): the exact netlist of the four modules —
//! MEAN (Fig. 2), VARIANCE (Fig. 3), ECCENTRICITY (Fig. 4), OUTLIER
//! (Fig. 5) — is instantiated component-by-component (MCOMPn, MMUXn,
//! MREGn, MMULT1n, … the paper's instance names are preserved) and
//! simulated cycle-by-cycle with IEEE-754 f32 arithmetic, which is what
//! the Xilinx floating-point operator cores compute.
//!
//! The same netlist drives the synthesis estimator ([`crate::synth`]):
//! resource occupation (Table 3) and the critical-path timing model
//! (Table 4) are derived from the very component instances simulated
//! here, so function and cost cannot drift apart.
//!
//! Pipeline structure (§4.1): three stages —
//!
//! ```text
//! cycle c   : MEAN     computes μ_k                  (sample x_k enters)
//! cycle c+1 : VARIANCE computes σ²_k, ‖x_k−μ_k‖²
//! cycle c+2 : ECCENTRICITY + OUTLIER emit ξ_k, ζ_k, outlier_k
//! ```
//!
//! so the verdict for `x_k` appears [`TedaRtl::LATENCY`] = 2 cycles after
//! it was clocked in, matching "the output of the ECCENTRICITY and
//! OUTLIER modules are ... two [cycles delayed] in relation to MEAN
//! module", and the initial delay is `d = 3·t_c` (Eq. 7: the first
//! verdict exists at the end of the 3rd cycle).

mod netlist;
mod pipeline;

pub use netlist::{CompKind, Component, Netlist, RegFile, Wire};
pub use pipeline::{RtlSnapshot, RtlVerdict, TedaRtl};

//! Versioned, dependency-free binary codec for [`StateCheckpoint`]s.
//!
//! Every checkpoint record is self-describing and self-verifying:
//!
//! ```text
//! offset  size  field
//! 0       8     magic  "TEDACKPT"
//! 8       2     format version (LE u16, currently 1)
//! 10      2     flags (LE u16, must be 0 — rejected if unknown)
//! 12      4     payload length (LE u32, must equal remaining bytes)
//! 16      4     CRC-32 of the payload (LE u32, poly 0xEDB88320)
//! 20      —     payload
//! ```
//!
//! The payload is `stream_id (u64) · seq (u64) · snapshot`, where the
//! snapshot is a tagged union covering every engine family (software
//! detector state + counters, RTL register file, XLA carry + buffers,
//! ensemble members + weights + open quorums). All integers are
//! little-endian; floats are encoded via their IEEE bit patterns, so
//! NaN payloads (the RTL ζ₁) survive a round trip bit-exactly.
//!
//! Robustness contract (enforced by `tests/persist_corruption.rs`):
//! [`decode`] returns a clean [`Error::Persist`] — never panics, never
//! fabricates state — for truncated, bit-flipped, zero-length, or
//! trailing-garbage input. The CRC is verified *before* the payload is
//! parsed, and the parser itself bounds-checks every read, so even a
//! CRC collision cannot cause an out-of-bounds access or an oversized
//! allocation (vector lengths are validated against the bytes actually
//! present before allocating).

use crate::coordinator::StateCheckpoint;
use crate::engine::{EngineVerdict, Snapshot, XlaSnapshot};
use crate::ensemble::{EnsembleSnapshot, MemberSnapshot, MemberVote};
use crate::rtl::{RegFile, RtlSnapshot};
use crate::teda::{DetectorSnapshot, TedaState};
use crate::{Error, Result};

/// Record magic: identifies a TEDA checkpoint file.
pub const MAGIC: [u8; 8] = *b"TEDACKPT";
/// Current (and only) format version.
pub const VERSION: u16 = 1;
/// Header size in bytes (magic + version + flags + length + CRC).
pub const HEADER_LEN: usize = 20;

// Snapshot variant tags.
const TAG_SOFTWARE: u8 = 1;
const TAG_RTL: u8 = 2;
const TAG_XLA: u8 = 3;
const TAG_ENSEMBLE: u8 = 4;
// Ensemble member variant tags.
const TAG_MEMBER_ENGINE: u8 = 1;
const TAG_MEMBER_MSIGMA: u8 = 2;
const TAG_MEMBER_ZSCORE: u8 = 3;

/// CRC-32 (ISO-HDLC, poly 0xEDB88320 reflected) — the zlib/PNG CRC.
pub fn crc32(data: &[u8]) -> u32 {
    const TABLE: [u32; 256] = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

fn err(msg: impl Into<String>) -> Error {
    Error::Persist(msg.into())
}

// ---------------------------------------------------------------- writer

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Length-prefixed f32 slice.
    fn f32s(&mut self, vs: &[f32]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f32(v);
        }
    }

    /// Length-prefixed f64 slice.
    fn f64s(&mut self, vs: &[f64]) {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f64(v);
        }
    }
}

// ---------------------------------------------------------------- reader

/// Bounds-checked cursor: every read verifies the bytes exist first,
/// so corrupt length fields produce errors, not panics or huge allocs.
struct Reader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(data: &'a [u8]) -> Self {
        Reader { data, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(err(format!(
                "truncated payload: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(u32::from_le_bytes(
            self.take(4)?.try_into().unwrap(),
        )))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(u64::from_le_bytes(
            self.take(8)?.try_into().unwrap(),
        )))
    }

    /// Length-prefixed count, validated against the bytes that must
    /// follow (`elem_size` bytes per element) BEFORE any allocation.
    fn len(&mut self, elem_size: usize, what: &str) -> Result<usize> {
        let n = self.u32()? as usize;
        match n.checked_mul(elem_size) {
            Some(bytes) if bytes <= self.remaining() => Ok(n),
            _ => Err(err(format!(
                "corrupt length for {what}: {n} elements do not fit in \
                 the {} remaining bytes",
                self.remaining()
            ))),
        }
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4, "f32 vector")?;
        (0..n).map(|_| self.f32()).collect()
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.len(8, "f64 vector")?;
        (0..n).map(|_| self.f64()).collect()
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(err(format!("corrupt boolean byte {other:#x}"))),
        }
    }
}

// --------------------------------------------------------------- encode

/// Peek the stream id out of an encoded record without a full decode.
///
/// The payload begins with the stream id (u64 LE) immediately after the
/// fixed header, so routing a sealed bundle's records to workers needs
/// only this 28-byte prefix check — full CRC/structure validation still
/// happens in the worker's [`decode`] on adopt.
pub fn record_stream_id(data: &[u8]) -> Result<u64> {
    if data.len() < HEADER_LEN + 8 {
        return Err(err(format!(
            "record too short to carry a stream id: {} bytes",
            data.len()
        )));
    }
    if data[0..8] != MAGIC {
        return Err(err("bad magic (not a TEDA checkpoint)"));
    }
    Ok(u64::from_le_bytes(
        data[HEADER_LEN..HEADER_LEN + 8].try_into().unwrap(),
    ))
}

/// Frame a sealed bundle (many encoded records) into one byte string:
/// `count:u32 LE` then per record `len:u32 LE` + bytes. This is the
/// transport payload layout for shipping seal → adopt bundles between
/// processes; each inner record keeps its own magic + CRC.
pub fn encode_bundle(records: &[Vec<u8>]) -> Vec<u8> {
    let total: usize =
        4 + records.iter().map(|r| 4 + r.len()).sum::<usize>();
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(&(records.len() as u32).to_le_bytes());
    for rec in records {
        out.extend_from_slice(&(rec.len() as u32).to_le_bytes());
        out.extend_from_slice(rec);
    }
    out
}

/// Inverse of [`encode_bundle`]. Returns the records and how many bytes
/// of `data` were consumed, so a caller embedding a bundle inside a
/// larger frame can keep parsing after it. Allocation is bounded by the
/// input length before any record is copied.
pub fn decode_bundle(data: &[u8]) -> Result<(Vec<Vec<u8>>, usize)> {
    if data.len() < 4 {
        return Err(err("bundle too short for a record count"));
    }
    let count =
        u32::from_le_bytes(data[0..4].try_into().unwrap()) as usize;
    // Each record costs at least 4 length bytes; reject counts the
    // input cannot possibly carry before allocating for them.
    if count > (data.len() - 4) / 4 {
        return Err(err(format!(
            "bundle claims {count} records in {} bytes",
            data.len()
        )));
    }
    let mut records = Vec::with_capacity(count);
    let mut at = 4usize;
    for i in 0..count {
        if data.len() - at < 4 {
            return Err(err(format!(
                "bundle truncated at record {i} length"
            )));
        }
        let len = u32::from_le_bytes(
            data[at..at + 4].try_into().unwrap(),
        ) as usize;
        at += 4;
        if data.len() - at < len {
            return Err(err(format!(
                "bundle record {i} truncated: wants {len} bytes, {} left",
                data.len() - at
            )));
        }
        records.push(data[at..at + len].to_vec());
        at += len;
    }
    Ok((records, at))
}

/// Serialize one checkpoint into a self-verifying record.
pub fn encode(cp: &StateCheckpoint) -> Vec<u8> {
    let mut w = Writer::default();
    w.u64(cp.stream_id);
    w.u64(cp.seq);
    encode_snapshot(&mut w, &cp.snapshot);
    let payload = w.buf;

    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes()); // flags
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

fn encode_snapshot(w: &mut Writer, snap: &Snapshot) {
    match snap {
        Snapshot::Software(s) => {
            w.u8(TAG_SOFTWARE);
            w.f64s(&s.state.mean);
            w.f64(s.state.var);
            w.u64(s.state.k);
            w.u64(s.n_outliers);
            w.f64(s.m);
        }
        Snapshot::Rtl(s) => {
            w.u8(TAG_RTL);
            w.u32(s.n as u32);
            w.f32(s.m);
            w.u64(s.samples_in);
            w.f32s(s.regs.regs());
            w.u64(s.regs.counter());
            w.u64(s.regs.cycles());
        }
        Snapshot::Xla(s) => {
            w.u8(TAG_XLA);
            w.f32s(&s.mu);
            w.f32(s.var);
            w.f32(s.k);
            w.f64(s.m);
            w.u32(s.chunks.len() as u32);
            for (seq, chunk) in &s.chunks {
                w.u64(*seq);
                w.f32s(chunk);
            }
            w.f32s(&s.buf);
            w.u64(s.seq_base);
        }
        Snapshot::Ensemble(s) => {
            w.u8(TAG_ENSEMBLE);
            w.u32(s.members.len() as u32);
            for member in &s.members {
                encode_member(w, member);
            }
            w.f64s(&s.weights);
            w.u32(s.pending.len() as u32);
            for (seq, slots) in &s.pending {
                w.u64(*seq);
                w.u32(slots.len() as u32);
                for slot in slots {
                    match slot {
                        None => w.u8(0),
                        Some(vote) => {
                            w.u8(1);
                            encode_vote(w, vote);
                        }
                    }
                }
            }
        }
    }
}

fn encode_member(w: &mut Writer, member: &MemberSnapshot) {
    match member {
        MemberSnapshot::Engine(snap) => {
            w.u8(TAG_MEMBER_ENGINE);
            encode_snapshot(w, snap);
        }
        MemberSnapshot::MSigma(det) => {
            w.u8(TAG_MEMBER_MSIGMA);
            let (m, k, mean, m2) = det.parts();
            w.f64(m);
            w.u64(k);
            w.f64s(mean);
            w.f64s(m2);
        }
        MemberSnapshot::ZScore(det) => {
            w.u8(TAG_MEMBER_ZSCORE);
            let (m, window, buf, sum, sumsq) = det.parts();
            w.f64(m);
            w.u32(window as u32);
            w.f64s(sum);
            w.f64s(sumsq);
            w.u32(buf.len() as u32);
            for row in buf {
                w.f64s(row);
            }
        }
    }
}

fn encode_vote(w: &mut Writer, vote: &MemberVote) {
    w.u64(vote.stream_id);
    w.u64(vote.seq);
    w.u8(vote.outlier as u8);
    w.f64(vote.score);
    match &vote.detail {
        None => w.u8(0),
        Some(v) => {
            w.u8(1);
            encode_verdict(w, v);
        }
    }
}

fn encode_verdict(w: &mut Writer, v: &EngineVerdict) {
    w.u64(v.stream_id);
    w.u64(v.seq);
    w.u64(v.k);
    w.f64(v.eccentricity);
    w.f64(v.zeta);
    w.f64(v.threshold);
    w.u8(v.outlier as u8);
}

// --------------------------------------------------------------- decode

/// Deserialize a record produced by [`encode`].
///
/// Any deviation — short header, wrong magic/version/flags, length
/// mismatch, CRC mismatch, truncated or malformed payload, trailing
/// bytes — yields `Err(Error::Persist(..))`; this function never
/// panics on untrusted input.
pub fn decode(data: &[u8]) -> Result<StateCheckpoint> {
    if data.len() < HEADER_LEN {
        return Err(err(format!(
            "record too short: {} bytes, header needs {HEADER_LEN}",
            data.len()
        )));
    }
    if data[0..8] != MAGIC {
        return Err(err("bad magic (not a TEDA checkpoint)"));
    }
    let version = u16::from_le_bytes(data[8..10].try_into().unwrap());
    if version != VERSION {
        return Err(err(format!(
            "unsupported format version {version} (expected {VERSION})"
        )));
    }
    let flags = u16::from_le_bytes(data[10..12].try_into().unwrap());
    if flags != 0 {
        return Err(err(format!("unknown flags {flags:#06x}")));
    }
    let payload_len =
        u32::from_le_bytes(data[12..16].try_into().unwrap()) as usize;
    let payload = &data[HEADER_LEN..];
    if payload.len() != payload_len {
        return Err(err(format!(
            "payload length mismatch: header says {payload_len}, record \
             carries {}",
            payload.len()
        )));
    }
    let crc = u32::from_le_bytes(data[16..20].try_into().unwrap());
    let actual = crc32(payload);
    if crc != actual {
        return Err(err(format!(
            "CRC mismatch: header {crc:#010x}, payload {actual:#010x}"
        )));
    }

    let mut r = Reader::new(payload);
    let stream_id = r.u64()?;
    let seq = r.u64()?;
    let snapshot = decode_snapshot(&mut r)?;
    if r.remaining() != 0 {
        return Err(err(format!(
            "{} trailing bytes after the snapshot",
            r.remaining()
        )));
    }
    Ok(StateCheckpoint { stream_id, seq, snapshot })
}

fn decode_snapshot(r: &mut Reader) -> Result<Snapshot> {
    match r.u8()? {
        TAG_SOFTWARE => {
            let mean = r.f64s()?;
            let var = r.f64()?;
            let k = r.u64()?;
            let n_outliers = r.u64()?;
            let m = r.f64()?;
            if mean.is_empty() {
                return Err(err("software snapshot with zero features"));
            }
            if !(m > 0.0) {
                return Err(err(format!(
                    "software snapshot with invalid threshold m={m}"
                )));
            }
            Ok(Snapshot::Software(DetectorSnapshot {
                state: TedaState { mean, var, k },
                n_outliers,
                m,
            }))
        }
        TAG_RTL => {
            let n = r.u32()? as usize;
            let m = r.f32()?;
            let samples_in = r.u64()?;
            let regs = r.f32s()?;
            let counter = r.u64()?;
            let cycles = r.u64()?;
            if n == 0 {
                return Err(err("rtl snapshot with zero features"));
            }
            if !(m > 0.0) {
                return Err(err(format!(
                    "rtl snapshot with invalid threshold m={m}"
                )));
            }
            Ok(Snapshot::Rtl(RtlSnapshot {
                n,
                m,
                samples_in,
                regs: RegFile::from_parts(regs, counter, cycles),
            }))
        }
        TAG_XLA => {
            let mu = r.f32s()?;
            let var = r.f32()?;
            let k = r.f32()?;
            let m = r.f64()?;
            let n_chunks = r.len(12, "xla chunk list")?;
            let mut chunks = Vec::with_capacity(n_chunks);
            for _ in 0..n_chunks {
                let seq = r.u64()?;
                chunks.push((seq, r.f32s()?));
            }
            let buf = r.f32s()?;
            let seq_base = r.u64()?;
            if mu.is_empty() {
                return Err(err("xla snapshot with zero features"));
            }
            Ok(Snapshot::Xla(XlaSnapshot {
                mu,
                var,
                k,
                m,
                chunks,
                buf,
                seq_base,
            }))
        }
        TAG_ENSEMBLE => {
            let n_members = r.len(1, "ensemble member list")?;
            let mut members = Vec::with_capacity(n_members);
            for _ in 0..n_members {
                members.push(decode_member(r)?);
            }
            let weights = r.f64s()?;
            if weights.len() != members.len() {
                return Err(err(format!(
                    "ensemble snapshot with {} members but {} weights",
                    members.len(),
                    weights.len()
                )));
            }
            let n_pending = r.len(12, "ensemble pending list")?;
            let mut pending = Vec::with_capacity(n_pending);
            for _ in 0..n_pending {
                let seq = r.u64()?;
                let n_slots = r.len(1, "quorum slot list")?;
                if n_slots != members.len() {
                    return Err(err(format!(
                        "quorum with {n_slots} slots for a {}-member \
                         roster",
                        members.len()
                    )));
                }
                let mut slots = Vec::with_capacity(n_slots);
                for _ in 0..n_slots {
                    slots.push(if r.bool()? {
                        Some(decode_vote(r)?)
                    } else {
                        None
                    });
                }
                pending.push((seq, slots));
            }
            Ok(Snapshot::Ensemble(EnsembleSnapshot {
                members,
                weights,
                pending,
            }))
        }
        tag => Err(err(format!("unknown snapshot tag {tag:#04x}"))),
    }
}

fn decode_member(r: &mut Reader) -> Result<MemberSnapshot> {
    match r.u8()? {
        TAG_MEMBER_ENGINE => {
            Ok(MemberSnapshot::Engine(decode_snapshot(r)?))
        }
        TAG_MEMBER_MSIGMA => {
            let m = r.f64()?;
            let k = r.u64()?;
            let mean = r.f64s()?;
            let m2 = r.f64s()?;
            crate::baselines::MSigmaDetector::from_parts(m, k, mean, m2)
                .map(MemberSnapshot::MSigma)
                .ok_or_else(|| err("inconsistent m-sigma member state"))
        }
        TAG_MEMBER_ZSCORE => {
            let m = r.f64()?;
            let window = r.u32()? as usize;
            let sum = r.f64s()?;
            let sumsq = r.f64s()?;
            let n_rows = r.len(4, "zscore window rows")?;
            let mut buf = Vec::with_capacity(n_rows);
            for _ in 0..n_rows {
                buf.push(r.f64s()?);
            }
            crate::baselines::SlidingZScore::from_parts(
                m, window, buf, sum, sumsq,
            )
            .map(MemberSnapshot::ZScore)
            .ok_or_else(|| err("inconsistent z-score member state"))
        }
        tag => Err(err(format!("unknown member tag {tag:#04x}"))),
    }
}

fn decode_vote(r: &mut Reader) -> Result<MemberVote> {
    let stream_id = r.u64()?;
    let seq = r.u64()?;
    let outlier = r.bool()?;
    let score = r.f64()?;
    let detail =
        if r.bool()? { Some(decode_verdict(r)?) } else { None };
    Ok(MemberVote { stream_id, seq, outlier, score, detail })
}

fn decode_verdict(r: &mut Reader) -> Result<EngineVerdict> {
    Ok(EngineVerdict {
        stream_id: r.u64()?,
        seq: r.u64()?,
        k: r.u64()?,
        eccentricity: r.f64()?,
        zeta: r.f64()?,
        threshold: r.f64()?,
        outlier: r.bool()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::teda::TedaDetector;

    fn software_cp(sid: u64, seq: u64) -> StateCheckpoint {
        let mut det = TedaDetector::new(2, 3.0);
        for i in 0..=seq {
            det.step(&[i as f64 * 0.1, 1.0 - i as f64 * 0.05]);
        }
        StateCheckpoint {
            stream_id: sid,
            seq,
            snapshot: Snapshot::Software(det.snapshot()),
        }
    }

    #[test]
    fn crc32_known_vectors() {
        // The zlib/PNG CRC test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn stream_id_peek_matches_full_decode() {
        let cp = software_cp(0xDEAD_BEEF_CAFE, 12);
        let bytes = encode(&cp);
        assert_eq!(record_stream_id(&bytes).unwrap(), cp.stream_id);
        assert!(record_stream_id(&bytes[..HEADER_LEN]).is_err());
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(record_stream_id(&bad).is_err());
    }

    #[test]
    fn bundle_roundtrip_and_consumed_offset() {
        let records: Vec<Vec<u8>> =
            vec![encode(&software_cp(1, 3)), encode(&software_cp(2, 9))];
        let mut framed = encode_bundle(&records);
        let len = framed.len();
        framed.extend_from_slice(b"trailing");
        let (back, used) = decode_bundle(&framed).unwrap();
        assert_eq!(back, records);
        assert_eq!(used, len);

        let (empty, used) = decode_bundle(&encode_bundle(&[])).unwrap();
        assert!(empty.is_empty());
        assert_eq!(used, 4);
    }

    #[test]
    fn bundle_rejects_lies_about_its_size() {
        // A count the input cannot carry must fail before allocation.
        let mut huge = Vec::new();
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_bundle(&huge).is_err());

        // Truncation inside a record length, and inside record bytes.
        let framed = encode_bundle(&[vec![9u8; 32]]);
        for cut in [2, 6, framed.len() - 1] {
            assert!(decode_bundle(&framed[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn software_roundtrip_is_exact() {
        let cp = software_cp(7, 41);
        let bytes = encode(&cp);
        assert_eq!(&bytes[0..8], &MAGIC);
        let back = decode(&bytes).unwrap();
        assert_eq!(back, cp);
    }

    #[test]
    fn rtl_roundtrip_preserves_register_file() {
        // Snapshot at k = 2, while the k = 1 NaN eccentricity is still
        // inside the pipeline registers: the round trip must be
        // bit-exact, so compare re-encoded bytes (NaN != NaN would
        // fail a structural compare that is in fact exact).
        let mut rtl = crate::rtl::TedaRtl::new(2, 3.0).unwrap();
        for i in 0..2 {
            rtl.clock(&[i as f32 * 0.3, 0.5]).unwrap();
        }
        let cp = StateCheckpoint {
            stream_id: 3,
            seq: 1,
            snapshot: Snapshot::Rtl(rtl.save()),
        };
        let bytes = encode(&cp);
        let back = decode(&bytes).unwrap();
        assert_eq!(encode(&back), bytes);
        // And the decoded register file actually loads.
        let Snapshot::Rtl(snap) = back.snapshot else { unreachable!() };
        let mut fresh = crate::rtl::TedaRtl::new(2, 3.0).unwrap();
        fresh.load(&snap).unwrap();
        // Loaded state re-saves to the same bits (NaN-safe comparison
        // through the codec again).
        let resaved = StateCheckpoint {
            stream_id: 3,
            seq: 1,
            snapshot: Snapshot::Rtl(fresh.save()),
        };
        assert_eq!(encode(&resaved), bytes);
    }

    #[test]
    fn xla_roundtrip_with_chunks_and_partial_buffer() {
        // Synthetic snapshot: the codec must not depend on artifacts.
        let cp = StateCheckpoint {
            stream_id: 11,
            seq: 95,
            snapshot: Snapshot::Xla(XlaSnapshot {
                mu: vec![0.25, -1.5],
                var: 0.125,
                k: 64.0,
                m: 3.0,
                chunks: vec![
                    (64, vec![0.5; 8]),
                    (68, vec![-0.5; 8]),
                ],
                buf: vec![1.0, 2.0],
                seq_base: 72,
            }),
        };
        assert_eq!(decode(&encode(&cp)).unwrap(), cp);
    }

    #[test]
    fn nan_zeta_survives_bit_exactly() {
        let vote = MemberVote {
            stream_id: 1,
            seq: 0,
            outlier: false,
            score: 0.0,
            detail: Some(EngineVerdict {
                stream_id: 1,
                seq: 0,
                k: 1,
                eccentricity: f64::NAN,
                zeta: f64::from_bits(0x7FF8_0000_0000_0001),
                threshold: 5.0,
                outlier: false,
            }),
        };
        let cp = StateCheckpoint {
            stream_id: 1,
            seq: 0,
            snapshot: Snapshot::Ensemble(EnsembleSnapshot {
                members: vec![MemberSnapshot::MSigma(
                    crate::baselines::MSigmaDetector::new(2, 3.0),
                )],
                weights: vec![1.0],
                pending: vec![(0, vec![Some(vote)])],
            }),
        };
        let back = decode(&encode(&cp)).unwrap();
        let Snapshot::Ensemble(e) = &back.snapshot else { unreachable!() };
        let Some(v) = &e.pending[0].1[0] else { unreachable!() };
        let d = v.detail.as_ref().unwrap();
        assert!(d.eccentricity.is_nan());
        assert_eq!(d.zeta.to_bits(), 0x7FF8_0000_0000_0001);
    }

    #[test]
    fn header_violations_are_clean_errors() {
        let good = encode(&software_cp(1, 5));
        // Too short / empty.
        assert!(decode(&[]).is_err());
        assert!(decode(&good[..HEADER_LEN - 1]).is_err());
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(decode(&bad).is_err());
        // Unsupported version.
        let mut bad = good.clone();
        bad[8] = 2;
        assert!(decode(&bad).is_err());
        // Unknown flags.
        let mut bad = good.clone();
        bad[10] = 1;
        assert!(decode(&bad).is_err());
        // Trailing garbage.
        let mut bad = good.clone();
        bad.push(0);
        assert!(decode(&bad).is_err());
        // Payload bit flip → CRC mismatch.
        let mut bad = good.clone();
        *bad.last_mut().unwrap() ^= 0x01;
        assert!(decode(&bad).is_err());
        // The pristine record still decodes.
        assert!(decode(&good).is_ok());
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        // Hand-craft a payload whose vector length claims more elements
        // than bytes exist; CRC is made valid so the parser is reached.
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u64.to_le_bytes()); // stream_id
        payload.extend_from_slice(&0u64.to_le_bytes()); // seq
        payload.push(TAG_SOFTWARE);
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // mean len
        let mut rec = Vec::new();
        rec.extend_from_slice(&MAGIC);
        rec.extend_from_slice(&VERSION.to_le_bytes());
        rec.extend_from_slice(&0u16.to_le_bytes());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&crc32(&payload).to_le_bytes());
        rec.extend_from_slice(&payload);
        assert!(decode(&rec).is_err());
    }
}

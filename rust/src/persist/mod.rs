//! Durable checkpoint store — failover that survives full-process death.
//!
//! PR 3's engine-agnostic [`Snapshot`](crate::engine::Snapshot) only
//! survives *worker* death: the checkpoints live in the dying process's
//! [`StateManager`](crate::coordinator::StateManager). This module adds
//! the persistence layer underneath it:
//!
//! - [`codec`] — a dependency-free, versioned binary format (magic,
//!   format version, per-record CRC-32) covering every snapshot
//!   variant; corrupt input decodes to a clean error, never a panic or
//!   a silently wrong state.
//! - [`CheckpointStore`] — the pluggable storage surface.
//! - [`MemoryStore`] — in-process backend (tests, single-process
//!   deployments). Stores *encoded* records so it exercises exactly
//!   the same codec path as the durable backend.
//! - [`FileStore`] — atomic-rename file backend:
//!   `dir/<stream_id>/<seq>.ckpt` plus a `MANIFEST` tag, write-temp-
//!   then-rename so a crash mid-write never corrupts an existing
//!   checkpoint, keep-last-K retention per stream.
//!
//! Recovery contract: [`CheckpointStore::latest`] returns the newest
//! checkpoint that *decodes and verifies*; truncated or bit-flipped
//! tails are skipped in favour of the newest still-valid predecessor.
//! `StateManager::recover` builds on that to cold-start a whole
//! service from disk (`Service::start_from_store`).

pub mod codec;

mod file;

pub use file::FileStore;

use std::collections::HashMap;
use std::sync::Mutex;

use crate::coordinator::StateCheckpoint;
use crate::Result;

/// Pluggable durable storage for per-stream checkpoints.
///
/// Implementations must be safe to share across worker threads (the
/// coordinator publishes from every shard). `put` durability is
/// backend-defined: the file backend is crash-atomic per record.
pub trait CheckpointStore: Send + Sync {
    /// Backend label for logs/metrics.
    fn name(&self) -> &'static str;

    /// Persist one checkpoint. Retention (keep-last-K per stream) is
    /// applied by the backend; older records beyond K are dropped.
    fn put(&self, cp: &StateCheckpoint) -> Result<()>;

    /// Newest checkpoint for `stream_id` that decodes and verifies.
    /// Corrupt/truncated records are skipped (newest first), falling
    /// back to the newest still-valid earlier checkpoint; `None` when
    /// no valid record exists.
    fn latest(&self, stream_id: u64) -> Result<Option<StateCheckpoint>>;

    /// Every stream id with at least one stored record (valid or not).
    fn streams(&self) -> Result<Vec<u64>>;

    /// Drop every checkpoint of one stream (eviction).
    fn evict(&self, stream_id: u64) -> Result<()>;
}

/// In-memory [`CheckpointStore`]: encoded records in a per-stream ring.
///
/// Round-trips every checkpoint through [`codec`] on the way in *and*
/// out, so tests running against `MemoryStore` exercise the same
/// serialization path as production running against [`FileStore`].
#[derive(Debug, Default)]
pub struct MemoryStore {
    /// Per stream: (seq, encoded record), ascending by insertion.
    records: Mutex<HashMap<u64, Vec<(u64, Vec<u8>)>>>,
    /// Keep-last-K per stream (0 = unlimited).
    keep: usize,
}

impl MemoryStore {
    /// Unlimited retention.
    pub fn new() -> Self {
        Self::default()
    }

    /// Keep only the newest `keep` records per stream.
    pub fn with_keep(keep: usize) -> Self {
        MemoryStore { records: Mutex::new(HashMap::new()), keep }
    }

    /// Number of records currently held for one stream.
    pub fn records_for(&self, stream_id: u64) -> usize {
        self.records
            .lock()
            .unwrap()
            .get(&stream_id)
            .map_or(0, Vec::len)
    }
}

impl CheckpointStore for MemoryStore {
    fn name(&self) -> &'static str {
        "memory"
    }

    fn put(&self, cp: &StateCheckpoint) -> Result<()> {
        let encoded = codec::encode(cp);
        let mut records = self.records.lock().unwrap();
        let ring = records.entry(cp.stream_id).or_default();
        // Keep the ring sorted by seq so "newest" is the tail.
        let at = ring.partition_point(|(seq, _)| *seq <= cp.seq);
        ring.insert(at, (cp.seq, encoded));
        if self.keep > 0 && ring.len() > self.keep {
            let drop = ring.len() - self.keep;
            ring.drain(0..drop);
        }
        Ok(())
    }

    fn latest(&self, stream_id: u64) -> Result<Option<StateCheckpoint>> {
        let records = self.records.lock().unwrap();
        let Some(ring) = records.get(&stream_id) else {
            return Ok(None);
        };
        // Newest first; skip anything that fails to decode.
        for (_, bytes) in ring.iter().rev() {
            if let Ok(cp) = codec::decode(bytes) {
                if cp.stream_id == stream_id {
                    return Ok(Some(cp));
                }
            }
        }
        Ok(None)
    }

    fn streams(&self) -> Result<Vec<u64>> {
        let mut ids: Vec<u64> =
            self.records.lock().unwrap().keys().copied().collect();
        ids.sort_unstable();
        Ok(ids)
    }

    fn evict(&self, stream_id: u64) -> Result<()> {
        self.records.lock().unwrap().remove(&stream_id);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Snapshot;
    use crate::teda::TedaDetector;

    fn cp(sid: u64, seq: u64) -> StateCheckpoint {
        let mut det = TedaDetector::new(2, 3.0);
        for i in 0..=seq {
            det.step(&[i as f64 * 0.2, 0.5]);
        }
        StateCheckpoint {
            stream_id: sid,
            seq,
            snapshot: Snapshot::Software(det.snapshot()),
        }
    }

    #[test]
    fn memory_store_roundtrip_and_latest() {
        let store = MemoryStore::new();
        store.put(&cp(1, 9)).unwrap();
        store.put(&cp(1, 19)).unwrap();
        store.put(&cp(2, 4)).unwrap();
        assert_eq!(store.streams().unwrap(), vec![1, 2]);
        let got = store.latest(1).unwrap().unwrap();
        assert_eq!(got, cp(1, 19));
        assert!(store.latest(99).unwrap().is_none());
    }

    #[test]
    fn memory_store_keeps_last_k() {
        let store = MemoryStore::with_keep(2);
        for seq in [9, 19, 29, 39] {
            store.put(&cp(1, seq)).unwrap();
        }
        assert_eq!(store.records_for(1), 2);
        assert_eq!(store.latest(1).unwrap().unwrap().seq, 39);
    }

    #[test]
    fn memory_store_evicts() {
        let store = MemoryStore::new();
        store.put(&cp(5, 0)).unwrap();
        store.evict(5).unwrap();
        assert!(store.latest(5).unwrap().is_none());
        assert!(store.streams().unwrap().is_empty());
    }

    #[test]
    fn out_of_order_put_still_returns_newest() {
        let store = MemoryStore::new();
        store.put(&cp(1, 39)).unwrap();
        store.put(&cp(1, 19)).unwrap(); // late arrival of an older record
        assert_eq!(store.latest(1).unwrap().unwrap().seq, 39);
    }
}

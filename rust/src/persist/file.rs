//! Atomic-rename file backend for the checkpoint store.
//!
//! On-disk layout:
//!
//! ```text
//! <root>/MANIFEST                  "teda-checkpoint-store v1"
//! <root>/<stream_id>/<seq>.ckpt    one codec record per checkpoint
//! ```
//!
//! `<seq>` is zero-padded to 20 digits so lexicographic directory
//! order equals numeric seq order. Writes go to a dot-prefixed temp
//! file in the same directory and are published with `rename(2)` —
//! atomic on POSIX — so a crash mid-write leaves either the previous
//! checkpoint set intact or a stray temp file that is ignored (and
//! reclaimed on the next write), never a half-written `.ckpt`.
//! Retention keeps the newest K records per stream.

use std::fs;
use std::path::{Path, PathBuf};

use crate::coordinator::StateCheckpoint;
use crate::persist::{codec, CheckpointStore};
use crate::{Error, Result};

/// First line of the `MANIFEST` tag file.
const MANIFEST_TAG: &str = "teda-checkpoint-store v1";

/// Durable [`CheckpointStore`] over a directory tree.
#[derive(Debug)]
pub struct FileStore {
    root: PathBuf,
    /// Newest records kept per stream (≥ 1).
    keep: usize,
}

impl FileStore {
    /// Open (creating if needed) a checkpoint store rooted at `root`,
    /// retaining the newest `keep` records per stream.
    ///
    /// Refuses to open a directory whose `MANIFEST` identifies a
    /// different format — overwriting an unrelated directory's files
    /// would be worse than failing.
    pub fn open(root: impl Into<PathBuf>, keep: usize) -> Result<FileStore> {
        let root = root.into();
        fs::create_dir_all(&root)
            .map_err(|e| Error::io(format!("creating {}", root.display()), e))?;
        let manifest = root.join("MANIFEST");
        match fs::read_to_string(&manifest) {
            Ok(text) => {
                if text.lines().next() != Some(MANIFEST_TAG) {
                    return Err(Error::Persist(format!(
                        "{} is not a teda checkpoint store (MANIFEST says \
                         {:?})",
                        root.display(),
                        text.lines().next().unwrap_or("")
                    )));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                write_atomic(
                    &root,
                    &manifest,
                    format!("{MANIFEST_TAG}\n").as_bytes(),
                )?;
            }
            Err(e) => {
                return Err(Error::io(
                    format!("reading {}", manifest.display()),
                    e,
                ))
            }
        }
        Ok(FileStore { root, keep: keep.max(1) })
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn stream_dir(&self, stream_id: u64) -> PathBuf {
        self.root.join(stream_id.to_string())
    }

    /// `(seq, path)` of every `.ckpt` in a stream dir, ascending seq.
    /// Files that do not parse as `<u64>.ckpt` are ignored (temp files,
    /// foreign debris).
    fn records(&self, stream_id: u64) -> Result<Vec<(u64, PathBuf)>> {
        let dir = self.stream_dir(stream_id);
        let entries = match fs::read_dir(&dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Vec::new())
            }
            Err(e) => {
                return Err(Error::io(
                    format!("listing {}", dir.display()),
                    e,
                ))
            }
        };
        let mut out = Vec::new();
        for entry in entries {
            let entry = entry
                .map_err(|e| Error::io(format!("listing {}", dir.display()), e))?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(stem) = name.strip_suffix(".ckpt") else { continue };
            let Ok(seq) = stem.parse::<u64>() else { continue };
            out.push((seq, entry.path()));
        }
        out.sort_unstable_by_key(|(seq, _)| *seq);
        Ok(out)
    }
}

impl CheckpointStore for FileStore {
    fn name(&self) -> &'static str {
        "file"
    }

    fn put(&self, cp: &StateCheckpoint) -> Result<()> {
        let dir = self.stream_dir(cp.stream_id);
        fs::create_dir_all(&dir)
            .map_err(|e| Error::io(format!("creating {}", dir.display()), e))?;
        let path = dir.join(format!("{:020}.ckpt", cp.seq));
        write_atomic(&dir, &path, &codec::encode(cp))?;
        // Retention: drop the oldest records beyond keep-last-K.
        let records = self.records(cp.stream_id)?;
        if records.len() > self.keep {
            for (_, path) in &records[..records.len() - self.keep] {
                // Best-effort: a failed unlink costs disk, not safety.
                let _ = fs::remove_file(path);
            }
        }
        Ok(())
    }

    fn latest(&self, stream_id: u64) -> Result<Option<StateCheckpoint>> {
        for (seq, path) in self.records(stream_id)?.iter().rev() {
            let Ok(bytes) = fs::read(path) else { continue };
            match codec::decode(&bytes) {
                // A record must also agree with its own location — a
                // file copied under the wrong name is corruption too.
                Ok(cp) if cp.stream_id == stream_id && cp.seq == *seq => {
                    return Ok(Some(cp));
                }
                _ => continue, // corrupt/truncated → try the next-newest
            }
        }
        Ok(None)
    }

    fn streams(&self) -> Result<Vec<u64>> {
        let entries = fs::read_dir(&self.root).map_err(|e| {
            Error::io(format!("listing {}", self.root.display()), e)
        })?;
        let mut ids = Vec::new();
        for entry in entries {
            let entry = entry.map_err(|e| {
                Error::io(format!("listing {}", self.root.display()), e)
            })?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Ok(id) = name.parse::<u64>() {
                if entry.path().is_dir() {
                    ids.push(id);
                }
            }
        }
        ids.sort_unstable();
        Ok(ids)
    }

    fn evict(&self, stream_id: u64) -> Result<()> {
        let dir = self.stream_dir(stream_id);
        match fs::remove_dir_all(&dir) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => {
                Err(Error::io(format!("evicting {}", dir.display()), e))
            }
        }
    }
}

/// Write `bytes` to `path` via a temp file in `dir` + atomic rename.
fn write_atomic(dir: &Path, path: &Path, bytes: &[u8]) -> Result<()> {
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| Error::Persist(format!("bad path {}", path.display())))?;
    // Dot prefix keeps in-progress writes invisible to `records()`.
    let tmp = dir.join(format!(".tmp-{file_name}"));
    fs::write(&tmp, bytes)
        .map_err(|e| Error::io(format!("writing {}", tmp.display()), e))?;
    fs::rename(&tmp, path).map_err(|e| {
        let _ = fs::remove_file(&tmp);
        Error::io(
            format!("publishing {} -> {}", tmp.display(), path.display()),
            e,
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Snapshot;
    use crate::teda::TedaDetector;

    fn tmp_root(tag: &str) -> PathBuf {
        crate::util::unique_temp_dir(&format!("filestore-{tag}"))
    }

    fn cp(sid: u64, seq: u64) -> StateCheckpoint {
        let mut det = TedaDetector::new(2, 3.0);
        for i in 0..=seq {
            det.step(&[i as f64 * 0.1, 0.4]);
        }
        StateCheckpoint {
            stream_id: sid,
            seq,
            snapshot: Snapshot::Software(det.snapshot()),
        }
    }

    #[test]
    fn put_latest_roundtrip_across_reopen() {
        let root = tmp_root("roundtrip");
        {
            let store = FileStore::open(&root, 4).unwrap();
            store.put(&cp(3, 19)).unwrap();
            store.put(&cp(3, 39)).unwrap();
            store.put(&cp(8, 9)).unwrap();
        }
        // "Process death": a brand-new store handle over the same dir.
        let store = FileStore::open(&root, 4).unwrap();
        assert_eq!(store.streams().unwrap(), vec![3, 8]);
        assert_eq!(store.latest(3).unwrap().unwrap(), cp(3, 39));
        assert_eq!(store.latest(8).unwrap().unwrap().seq, 9);
        assert!(store.latest(99).unwrap().is_none());
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn retention_keeps_newest_k() {
        let root = tmp_root("retention");
        let store = FileStore::open(&root, 2).unwrap();
        for seq in [9, 19, 29, 39] {
            store.put(&cp(1, seq)).unwrap();
        }
        let files = store.records(1).unwrap();
        assert_eq!(
            files.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
            vec![29, 39]
        );
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn evict_removes_the_stream_dir() {
        let root = tmp_root("evict");
        let store = FileStore::open(&root, 4).unwrap();
        store.put(&cp(1, 5)).unwrap();
        store.evict(1).unwrap();
        assert!(store.latest(1).unwrap().is_none());
        assert!(store.streams().unwrap().is_empty());
        store.evict(1).unwrap(); // idempotent
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn foreign_manifest_is_refused() {
        let root = tmp_root("foreign");
        fs::create_dir_all(&root).unwrap();
        fs::write(root.join("MANIFEST"), "something else entirely\n")
            .unwrap();
        assert!(FileStore::open(&root, 4).is_err());
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn stray_temp_files_are_invisible() {
        let root = tmp_root("stray");
        let store = FileStore::open(&root, 4).unwrap();
        store.put(&cp(1, 9)).unwrap();
        // Simulate a crash mid-write: a temp file that never renamed.
        fs::write(
            store.stream_dir(1).join(".tmp-00000000000000000019.ckpt"),
            b"half-written",
        )
        .unwrap();
        assert_eq!(store.latest(1).unwrap().unwrap().seq, 9);
        fs::remove_dir_all(&root).unwrap();
    }
}

//! Synthesis estimator: Virtex-6 resource occupation and timing model.
//!
//! Derives the paper's Table 3 (hardware occupation) and Table 4
//! (processing time) from the *same netlist the simulator executes*
//! ([`crate::rtl`]), so cost and function cannot drift apart.
//!
//! ## Calibration (documented per DESIGN.md §2)
//!
//! The per-primitive coefficients below are calibrated so that the N=2,
//! floating-point TEDA netlist reproduces the paper's published Virtex-6
//! xc6vlx240t numbers, with every coefficient kept inside the plausible
//! range for the Xilinx Floating-Point Operator cores the paper's RTL
//! would instantiate:
//!
//! | primitive        | DSP48E1 | LUT  | FF | delay (ns) |
//! |------------------|---------|------|----|------------|
//! | FP multiplier    | 3       | 15   | 0  | 16         |
//! | FP adder/sub     | 0       | 220  | 0  | 24         |
//! | FP divider       | 0       | 2400 | 0  | 90         |
//! | FP comparator    | 0       | 40   | 0  | 6          |
//! | 2:1 mux (32-bit) | 0       | 32   | 0  | 2          |
//! | half (exp-dec)   | 0       | 8    | 0  | 1          |
//! | counter + i2f    | 0       | 28   | 32 | 6 (source) |
//! | 32-bit register  | 0       | 0    | 32 | 0          |
//!
//! - *3 DSP48E1 per FP multiplier* is the "full usage" mult configuration;
//!   9 multiplier cores (3N+3 at N=2) × 3 = the paper's **27 multipliers**.
//! - The combinational (maximum-rate, zero-latency) divider dominates
//!   both LUT count and delay, as in the paper where t_c = 138 ns at a
//!   throughput of one sample per cycle.
//! - With these coefficients the N=2 netlist yields **11 567 LUTs**
//!   (Table 3 exactly) and **416 FF bits** vs the paper's 414 (+0.5%;
//!   the paper does not itemise its register count).
//! - The critical path is the MEAN stage: counter→i2f (6) + divider D1
//!   (90) + MMULT2 (16) + MSUM (24) + MMUX (2) = **138 ns = t_c**,
//!   giving d = 3·t_c = 414 ns (Eq. 7) and 7.2 MSPS (Eq. 9).

mod resources;
mod timing;

pub use resources::{OccupationReport, ResourceModel, Virtex6};
pub use timing::{critical_path, PipelineTiming, TimingReport};

//! Resource model → Table 3 (hardware occupation).

use crate::rtl::{CompKind, Netlist};

/// Per-primitive resource usage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceCost {
    pub dsp: usize,
    pub lut: usize,
    pub ff: usize,
}

/// The calibrated Virtex-6 resource model (see `synth` module docs for
/// the calibration table and rationale).
#[derive(Debug, Clone, Copy, Default)]
pub struct ResourceModel;

impl ResourceModel {
    /// Cost of one component instance.
    pub fn cost(&self, kind: &CompKind) -> ResourceCost {
        match kind {
            CompKind::Mult => ResourceCost { dsp: 3, lut: 15, ff: 0 },
            CompKind::Add | CompKind::Sub => {
                ResourceCost { dsp: 0, lut: 220, ff: 0 }
            }
            CompKind::Div => ResourceCost { dsp: 0, lut: 2400, ff: 0 },
            CompKind::CompEqConst(_) | CompKind::CompGt => {
                ResourceCost { dsp: 0, lut: 40, ff: 0 }
            }
            CompKind::Mux => ResourceCost { dsp: 0, lut: 32, ff: 0 },
            CompKind::Half => ResourceCost { dsp: 0, lut: 8, ff: 0 },
            CompKind::Counter => ResourceCost { dsp: 0, lut: 28, ff: 32 },
            CompKind::Reg { .. } => ResourceCost { dsp: 0, lut: 0, ff: 32 },
            CompKind::Const(_) => ResourceCost::default(),
        }
    }
}

/// Target-device capacities for occupation percentages.
#[derive(Debug, Clone, Copy)]
pub struct Virtex6 {
    pub name: &'static str,
    pub dsp48e1: usize,
    pub luts: usize,
    pub ffs: usize,
}

impl Virtex6 {
    /// The paper's target: Xilinx Virtex-6 xc6vlx240t-1ff1156.
    pub fn xc6vlx240t() -> Self {
        Virtex6 {
            name: "xc6vlx240t-1ff1156",
            dsp48e1: 768,
            luts: 150_720,
            ffs: 301_440,
        }
    }
}

/// Table 3 replica: totals plus device occupation percentages.
#[derive(Debug, Clone, PartialEq)]
pub struct OccupationReport {
    /// DSP48E1 slices ("Multipliers" column of Table 3).
    pub multipliers: usize,
    /// Flip-flop bits ("Registers" column).
    pub registers: usize,
    /// LUTs.
    pub luts: usize,
    pub multipliers_pct: f64,
    pub registers_pct: f64,
    pub luts_pct: f64,
    /// FP multiplier core instances (27 DSP = 9 cores × 3).
    pub mult_cores: usize,
    /// FP divider core instances.
    pub div_cores: usize,
    /// Adder/subtractor core instances.
    pub addsub_cores: usize,
    pub device: &'static str,
}

impl OccupationReport {
    /// Analyze a netlist against a device.
    pub fn analyze(nl: &Netlist, device: Virtex6) -> Self {
        let model = ResourceModel;
        let mut total = ResourceCost::default();
        let mut mult_cores = 0;
        let mut div_cores = 0;
        let mut addsub_cores = 0;
        for c in nl.components() {
            let cost = model.cost(&c.kind);
            total.dsp += cost.dsp;
            total.lut += cost.lut;
            total.ff += cost.ff;
            match c.kind {
                CompKind::Mult => mult_cores += 1,
                CompKind::Div => div_cores += 1,
                CompKind::Add | CompKind::Sub => addsub_cores += 1,
                _ => {}
            }
        }
        OccupationReport {
            multipliers: total.dsp,
            registers: total.ff,
            luts: total.lut,
            multipliers_pct: 100.0 * total.dsp as f64 / device.dsp48e1 as f64,
            registers_pct: 100.0 * total.ff as f64 / device.ffs as f64,
            luts_pct: 100.0 * total.lut as f64 / device.luts as f64,
            mult_cores,
            div_cores,
            addsub_cores,
            device: device.name,
        }
    }

    /// Render in the paper's Table 3 shape.
    pub fn render_table3(&self) -> String {
        format!(
            "Table 3: Hardware occupation ({})\n\
             | Multipliers | Registers | n_LUT |\n\
             |-------------|-----------|-------|\n\
             | {} ({:.0}%) | {} (<{:.0}%) | {} ({:.0}%) |\n",
            self.device,
            self.multipliers,
            self.multipliers_pct.floor(), // paper prints floored percents
            self.registers,
            self.registers_pct.max(1.0).ceil(),
            self.luts,
            self.luts_pct.floor(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::TedaRtl;

    #[test]
    fn n2_reproduces_table3() {
        // The paper's Table 3: 27 multipliers (3%), 414 registers (<1%),
        // 11 567 LUT (7%). Validation bar (DESIGN.md §5): multipliers and
        // LUTs exact, registers within 1%.
        let rtl = TedaRtl::new(2, 3.0).unwrap();
        let rep =
            OccupationReport::analyze(rtl.netlist(), Virtex6::xc6vlx240t());
        assert_eq!(rep.multipliers, 27, "DSP mismatch");
        assert_eq!(rep.luts, 11_567, "LUT mismatch");
        let reg_err =
            (rep.registers as f64 - 414.0).abs() / 414.0;
        assert!(reg_err < 0.01, "registers {} vs 414", rep.registers);
        // Occupation percentages as printed in the paper.
        assert!((rep.multipliers_pct - 3.5).abs() < 1.0); // "3%"
        assert!(rep.registers_pct < 1.0); // "<1%"
        assert!((rep.luts_pct - 7.0).abs() < 1.0); // "7%"
        assert_eq!(rep.mult_cores, 9);
        assert_eq!(rep.div_cores, 4);
    }

    #[test]
    fn occupation_scales_with_n() {
        let small = OccupationReport::analyze(
            TedaRtl::new(1, 3.0).unwrap().netlist(),
            Virtex6::xc6vlx240t(),
        );
        let big = OccupationReport::analyze(
            TedaRtl::new(8, 3.0).unwrap().netlist(),
            Virtex6::xc6vlx240t(),
        );
        assert!(big.multipliers > small.multipliers);
        assert!(big.luts > small.luts);
        assert!(big.registers > small.registers);
        // Multipliers follow 3·(3N+3).
        assert_eq!(small.multipliers, 3 * (3 + 3));
        assert_eq!(big.multipliers, 3 * (27));
    }

    #[test]
    fn table3_renders() {
        let rtl = TedaRtl::new(2, 3.0).unwrap();
        let rep =
            OccupationReport::analyze(rtl.netlist(), Virtex6::xc6vlx240t());
        let s = rep.render_table3();
        assert!(s.contains("27"));
        assert!(s.contains("11567") || s.contains("11 567"));
    }
}

//! Timing model → Table 4 (processing time) via static timing analysis
//! over the netlist.
//!
//! Combinational delay coefficients are in the `synth` module docs. The
//! analysis computes, for every wire, the worst-case arrival time from
//! any register/counter/input source, and takes the maximum over all
//! register inputs and outputs — the classic register-to-register
//! critical path. The pipeline algebra then follows the paper exactly:
//! `t_TEDA = t_c` (Eq. 8), `d = 3·t_c` (Eq. 7), `th = 1/t_TEDA` (Eq. 9).

use crate::rtl::{CompKind, Netlist};

/// Combinational delay of one component traversal (ns).
pub fn comp_delay(kind: &CompKind) -> f64 {
    match kind {
        CompKind::Mult => 16.0,
        CompKind::Add | CompKind::Sub => 24.0,
        CompKind::Div => 90.0,
        CompKind::CompEqConst(_) | CompKind::CompGt => 6.0,
        CompKind::Mux => 2.0,
        CompKind::Half => 1.0,
        // Source delay: counter register → int-to-float converters.
        CompKind::Counter => 6.0,
        CompKind::Reg { .. } | CompKind::Const(_) => 0.0,
    }
}

/// Critical-path result.
#[derive(Debug, Clone, PartialEq)]
pub struct TimingReport {
    /// Critical path t_c in ns.
    pub critical_ns: f64,
    /// Instance names along the critical path (source → sink).
    pub path: Vec<String>,
}

/// Static timing analysis: longest combinational path in ns.
pub fn critical_path(nl: &Netlist) -> TimingReport {
    // Arrival time per wire + the component that set it (for the path
    // walk-back).
    let n_wires = nl
        .components()
        .iter()
        .flat_map(|c| c.outputs.iter().chain(c.inputs.iter()))
        .max()
        .map(|&w| w + 1)
        .unwrap_or(0);
    let mut arrival = vec![0.0f64; n_wires];
    let mut setter: Vec<Option<usize>> = vec![None; n_wires];

    let mut best = (0.0f64, None::<usize>);
    for (ci, c) in nl.components().iter().enumerate() {
        match c.kind {
            CompKind::Reg { .. } | CompKind::Const(_) => {
                // Outputs launch at t=0 (register clock-to-out folded
                // into the coefficients).
                for &o in &c.outputs {
                    arrival[o] = 0.0;
                    setter[o] = Some(ci);
                }
                // Register *inputs* are path endpoints.
                for &i in &c.inputs {
                    if arrival[i] > best.0 {
                        best = (arrival[i], setter[i]);
                    }
                }
            }
            CompKind::Counter => {
                for &o in &c.outputs {
                    arrival[o] = comp_delay(&c.kind);
                    setter[o] = Some(ci);
                }
            }
            _ => {
                let worst_in = c
                    .inputs
                    .iter()
                    .map(|&i| arrival[i])
                    .fold(0.0f64, f64::max);
                let t = worst_in + comp_delay(&c.kind);
                for &o in &c.outputs {
                    arrival[o] = t;
                    setter[o] = Some(ci);
                }
                if t > best.0 {
                    best = (t, Some(ci));
                }
            }
        }
    }
    // Also terminate at register inputs scanned after all components
    // (registers whose input was produced later in netlist order).
    for c in nl.components() {
        if matches!(c.kind, CompKind::Reg { .. }) {
            for &i in &c.inputs {
                if arrival[i] > best.0 {
                    best = (arrival[i], setter[i]);
                }
            }
        }
    }

    // Walk back the critical path.
    let mut path = Vec::new();
    let mut cur = best.1;
    let comps = nl.components();
    let mut guard = 0;
    while let Some(ci) = cur {
        path.push(comps[ci].name.clone());
        let c = &comps[ci];
        cur = c
            .inputs
            .iter()
            .max_by(|&&a, &&b| arrival[a].partial_cmp(&arrival[b]).unwrap())
            .and_then(|&w| setter[w])
            .filter(|_| {
                !matches!(c.kind, CompKind::Reg { .. } | CompKind::Const(_))
            });
        guard += 1;
        if guard > comps.len() {
            break;
        }
    }
    path.reverse();
    TimingReport { critical_ns: best.0, path }
}

/// Table 4 replica: the pipeline-time algebra of Eqs. 7–9.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineTiming {
    /// Critical path t_c (ns).
    pub critical_ns: f64,
    /// Initial delay d = 3·t_c (ns, Eq. 7).
    pub delay_ns: f64,
    /// Steady-state per-sample time t_TEDA = t_c (ns, Eq. 8).
    pub teda_time_ns: f64,
    /// Throughput 1/t_TEDA in samples/s (Eq. 9).
    pub throughput_sps: f64,
}

impl PipelineTiming {
    /// Derive the full Table 4 row from a critical path.
    pub fn from_critical(critical_ns: f64) -> Self {
        PipelineTiming {
            critical_ns,
            delay_ns: 3.0 * critical_ns,
            teda_time_ns: critical_ns,
            throughput_sps: 1e9 / critical_ns,
        }
    }

    /// Analyze a netlist end-to-end.
    pub fn analyze(nl: &Netlist) -> Self {
        Self::from_critical(critical_path(nl).critical_ns)
    }

    /// Render in the paper's Table 4 shape.
    pub fn render_table4(&self) -> String {
        format!(
            "Table 4: Processing time\n\
             | Critical time | Delay | TEDA time | Throughput |\n\
             |---------------|-------|-----------|------------|\n\
             | {:.0} ns | {:.0} ns | {:.0} ns | {:.1} MSPS |\n",
            self.critical_ns,
            self.delay_ns,
            self.teda_time_ns,
            self.throughput_sps / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtl::TedaRtl;

    #[test]
    fn n2_reproduces_table4() {
        // Paper: t_c = 138 ns, d = 414 ns, t_TEDA = 138 ns, 7.2 MSPS.
        let rtl = TedaRtl::new(2, 3.0).unwrap();
        let t = PipelineTiming::analyze(rtl.netlist());
        assert_eq!(t.critical_ns, 138.0);
        assert_eq!(t.delay_ns, 414.0);
        assert_eq!(t.teda_time_ns, 138.0);
        assert!((t.throughput_sps / 1e6 - 7.246).abs() < 0.05);
    }

    #[test]
    fn critical_path_is_the_mean_stage() {
        // counter → D1 (1/k) → MMULT2n → MSUMn → MMUXn (→ MREGn)
        let rtl = TedaRtl::new(2, 3.0).unwrap();
        let tr = critical_path(rtl.netlist());
        assert_eq!(tr.critical_ns, 138.0);
        let joined = tr.path.join(" ");
        assert!(joined.contains("KCNT"), "path: {joined}");
        assert!(joined.contains("D1"), "path: {joined}");
        assert!(joined.contains("MMULT2"), "path: {joined}");
        assert!(joined.contains("MSUM"), "path: {joined}");
    }

    #[test]
    fn eq7_eq8_eq9_algebra() {
        let t = PipelineTiming::from_critical(100.0);
        assert_eq!(t.delay_ns, 300.0);
        assert_eq!(t.teda_time_ns, 100.0);
        assert_eq!(t.throughput_sps, 1e7);
    }

    #[test]
    fn wide_n_moves_critical_path_to_variance() {
        // The VSUM1 adder chain grows with N; beyond N≈3 the VARIANCE
        // stage overtakes MEAN — the scaling insight the synthesizable
        // model adds beyond the paper's single N=2 data point.
        let t2 = PipelineTiming::analyze(TedaRtl::new(2, 3.0).unwrap().netlist());
        let t8 = PipelineTiming::analyze(TedaRtl::new(8, 3.0).unwrap().netlist());
        assert!(t8.critical_ns > t2.critical_ns);
        let tr8 = critical_path(TedaRtl::new(8, 3.0).unwrap().netlist());
        assert!(tr8.path.join(" ").contains("VSUM1"));
    }

    #[test]
    fn table4_renders() {
        let rtl = TedaRtl::new(2, 3.0).unwrap();
        let s = PipelineTiming::analyze(rtl.netlist()).render_table4();
        assert!(s.contains("138 ns"));
        assert!(s.contains("414 ns"));
        assert!(s.contains("7.2 MSPS"));
    }
}

//! Typed service configuration for the L3 coordinator.

use std::path::PathBuf;

use crate::config::{EnsembleConfig, Json, TomlDoc};
use crate::{Error, Result};

/// Which detector backend the coordinator drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust `teda::TedaDetector` (f64) — the software reference.
    Software,
    /// Cycle-accurate RTL pipeline simulator (f32, paper's architecture).
    Rtl,
    /// AOT-compiled JAX/Pallas artifact via PJRT.
    Xla,
    /// Multi-detector fusion over pluggable members
    /// ([`crate::ensemble::EnsembleEngine`], configured by `[ensemble]`).
    Ensemble,
}

impl std::str::FromStr for EngineKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "software" | "sw" => Ok(EngineKind::Software),
            "rtl" | "fpga" => Ok(EngineKind::Rtl),
            "xla" | "pjrt" => Ok(EngineKind::Xla),
            "ensemble" | "fusion" => Ok(EngineKind::Ensemble),
            other => Err(Error::Config(format!(
                "unknown engine kind '{other}' (software|rtl|xla|ensemble)"
            ))),
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EngineKind::Software => "software",
            EngineKind::Rtl => "rtl",
            EngineKind::Xla => "xla",
            EngineKind::Ensemble => "ensemble",
        })
    }
}

/// Elastic-sharding knobs (`[sharding]` in TOML, `"sharding"` in JSON).
///
/// `virtual_shards` is fixed for the lifetime of a service (it defines
/// the immutable stream → shard hash); the other two drive the
/// rebalancer that moves shards *between* workers at runtime.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardingConfig {
    /// Number of virtual shards stream ids hash onto. TOML/JSON:
    /// `sharding.virtual_shards`, CLI: `--virtual-shards`.
    pub virtual_shards: u32,
    /// Samples between automatic rebalance checks in `serve`
    /// (0 = automatic rebalancing off). TOML/JSON:
    /// `sharding.rebalance_interval`, CLI: `--rebalance-interval`.
    pub rebalance_interval: u64,
    /// A rebalance triggers when the most-loaded worker carries more
    /// than `imbalance_threshold ×` the mean worker load (> 1.0).
    /// TOML/JSON: `sharding.imbalance_threshold`.
    pub imbalance_threshold: f64,
}

impl Default for ShardingConfig {
    fn default() -> Self {
        ShardingConfig {
            virtual_shards: crate::coordinator::DEFAULT_VIRTUAL_SHARDS,
            rebalance_interval: 0,
            imbalance_threshold: 1.5,
        }
    }
}

/// Distributed-serve knobs (`[cluster]` in TOML, `"cluster"` in JSON).
///
/// Clustering is off unless `listen` is set. Peers are static:
/// `"ID=ADDR"` entries where ADDR is `host:port` or `unix:/path`. All
/// nodes of one logical service must share `sharding.virtual_shards`
/// and (for failover) `checkpoint.dir` on a shared filesystem.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// This node's stable identity (unique across the cluster).
    /// TOML/JSON: `cluster.node_id`, CLI: `--node-id`.
    pub node_id: u64,
    /// Transport bind address (`host:port` or `unix:/path`); `None`
    /// runs single-process. TOML/JSON: `cluster.listen`, CLI:
    /// `--cluster-listen`.
    pub listen: Option<String>,
    /// Peer roster as `"ID=ADDR"` strings. TOML/JSON: `cluster.peers`,
    /// CLI: `--peer ID=ADDR` (repeatable).
    pub peers: Vec<String>,
    /// Heartbeat interval in milliseconds. TOML/JSON:
    /// `cluster.heartbeat_ms`.
    pub heartbeat_ms: u64,
    /// Declare a silent peer dead and adopt its shards from the shared
    /// checkpoint store after this many milliseconds (0 = automatic
    /// failover off; migration and manual failover still work).
    /// TOML/JSON: `cluster.failover_ms`.
    pub failover_ms: u64,
    /// Join an existing cluster through the live member at this
    /// address instead of booting from a static roster (`peers` must
    /// be empty; the roster arrives in the JoinOk reply). TOML/JSON:
    /// `cluster.join`, CLI: `--join ADDR`.
    pub join: Option<String>,
    /// Minimum quiet window between cross-node load rebalances, in
    /// milliseconds (0 = load-driven rebalancing off). TOML/JSON:
    /// `cluster.rebalance_ms`, CLI: `--cluster-rebalance-ms`.
    pub rebalance_ms: u64,
    /// Donor gate for cross-node rebalancing: a node only sheds load
    /// while its windowed ingest rate exceeds this multiple of the
    /// cluster average (must be > 1.0 when rebalancing is on).
    /// TOML/JSON: `cluster.rebalance_threshold`.
    pub rebalance_threshold: f64,
    /// Capacity (samples) of the failover-window ingest buffer: a
    /// burst whose owner is mid-failover (or mid-join) parks locally
    /// and replays when the route heals (0 = buffering off; forward
    /// failures surface as errors). TOML/JSON: `cluster.ingest_buffer`,
    /// CLI: `--ingest-buffer`.
    pub ingest_buffer: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            node_id: 0,
            listen: None,
            peers: Vec::new(),
            heartbeat_ms: 500,
            failover_ms: 0,
            join: None,
            rebalance_ms: 0,
            rebalance_threshold: 1.5,
            ingest_buffer: 65_536,
        }
    }
}

impl ClusterConfig {
    /// Whether this config asks for a cluster transport at all.
    pub fn enabled(&self) -> bool {
        self.listen.is_some()
    }

    /// Parse the `"ID=ADDR"` roster into `(node_id, addr)` pairs.
    pub fn parse_peers(&self) -> Result<Vec<(u64, String)>> {
        let mut out = Vec::with_capacity(self.peers.len());
        for p in &self.peers {
            let (id, addr) = p.split_once('=').ok_or_else(|| {
                Error::Config(format!(
                    "cluster peer '{p}' must be ID=ADDR"
                ))
            })?;
            let id: u64 = id.trim().parse().map_err(|_| {
                Error::Config(format!(
                    "cluster peer '{p}': bad node id '{id}'"
                ))
            })?;
            if id == self.node_id {
                return Err(Error::Config(format!(
                    "cluster peer '{p}' reuses this node's id"
                )));
            }
            out.push((id, addr.trim().to_string()));
        }
        out.sort_unstable_by_key(|(id, _)| *id);
        for w in out.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(Error::Config(format!(
                    "duplicate cluster peer id {}",
                    w[0].0
                )));
            }
        }
        Ok(out)
    }
}

/// Observability knobs (`[obs]` in TOML, `"obs"` in JSON).
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Bind address for the scrape endpoint (`/metrics`, `/`, `/trace`)
    /// in `serve` (`None` = no endpoint). TOML/JSON: `obs.metrics_addr`,
    /// CLI: `--metrics-addr`.
    pub metrics_addr: Option<String>,
    /// Flight recorder master switch. TOML/JSON: `obs.recorder`.
    pub recorder: bool,
    /// Per-thread flight-recorder journal capacity in events (rounded
    /// up to a power of two). TOML/JSON: `obs.recorder_capacity`.
    pub recorder_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            metrics_addr: None,
            recorder: true,
            recorder_capacity: 4096,
        }
    }
}

/// Full coordinator/service configuration.
///
/// Built from a TOML file ([`ServiceConfig::from_toml`]) or defaults +
/// programmatic overrides; every field has a production-sane default so
/// examples can run with zero config.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceConfig {
    /// Human name used in logs/metrics.
    pub name: String,
    /// Detector backend.
    pub engine: EngineKind,
    /// Feature dimension N of every stream.
    pub n_features: usize,
    /// Chebyshev multiplier m (Eq. 6; the paper uses 3).
    pub m: f64,
    /// Worker threads executing detector engines.
    pub workers: usize,
    /// Bounded capacity of each worker's input queue (backpressure knob).
    pub queue_capacity: usize,
    /// Dynamic batcher: max streams packed per XLA chunk.
    pub batch_max_streams: usize,
    /// Dynamic batcher: samples per stream per chunk (T axis).
    pub chunk_t: usize,
    /// Dynamic batcher: max linger before a partial batch is flushed.
    pub batch_linger_us: u64,
    /// Directory with AOT artifacts (XLA engine only).
    pub artifact_dir: PathBuf,
    /// Per-stream state checkpoint interval in samples (0 = disabled).
    /// TOML/JSON: `checkpoint.interval` (legacy alias
    /// `service.checkpoint_every`), CLI: `--checkpoint-interval`.
    pub checkpoint_every: u64,
    /// Restore a stream's latest checkpoint when the stream resumes
    /// mid-sequence on a fresh worker (failover). TOML/JSON:
    /// `checkpoint.restore`, CLI: `--restore`.
    pub restore_on_resume: bool,
    /// Durable checkpoint store directory (`None` = in-memory only;
    /// checkpoints then die with the process). TOML/JSON:
    /// `checkpoint.dir`, CLI: `--checkpoint-dir`.
    pub checkpoint_dir: Option<PathBuf>,
    /// Keep-last-K retention per stream in the durable store (≥ 1).
    /// TOML/JSON: `checkpoint.keep`.
    pub checkpoint_keep: usize,
    /// Evict a stream's engine + checkpoint state after it has been
    /// idle for this many samples processed on its worker (0 = never).
    /// TOML/JSON: `checkpoint.evict_after`, CLI: `--evict-after`.
    pub evict_after: u64,
    /// RNG seed for anything stochastic in the service (workload gen).
    pub seed: u64,
    /// Elastic sharding: virtual shard count + rebalancer knobs.
    pub sharding: ShardingConfig,
    /// Observability: scrape endpoint + flight recorder knobs.
    pub obs: ObsConfig,
    /// Distributed serve: transport bind, peer roster, failover.
    pub cluster: ClusterConfig,
    /// Ensemble member roster + combiner (used when `engine = ensemble`).
    pub ensemble: EnsembleConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            name: "teda-service".into(),
            engine: EngineKind::Software,
            n_features: 2,
            m: 3.0,
            workers: 4,
            queue_capacity: 1024,
            batch_max_streams: 32,
            chunk_t: 32,
            batch_linger_us: 200,
            artifact_dir: PathBuf::from("artifacts"),
            checkpoint_every: 0,
            restore_on_resume: false,
            checkpoint_dir: None,
            checkpoint_keep: 4,
            evict_after: 0,
            seed: 0x7EDA, // "TEDA"
            sharding: ShardingConfig::default(),
            obs: ObsConfig::default(),
            cluster: ClusterConfig::default(),
            ensemble: EnsembleConfig::default(),
        }
    }
}

impl ServiceConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ServiceConfig::default();
        if let Some(v) = doc.str_("name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.str_("engine.kind") {
            cfg.engine = v.parse()?;
        }
        if let Some(v) = doc.usize_("engine.n_features") {
            cfg.n_features = v;
        }
        if let Some(v) = doc.f64_("engine.m") {
            cfg.m = v;
        }
        if let Some(v) = doc.usize_("service.workers") {
            cfg.workers = v;
        }
        if let Some(v) = doc.usize_("service.queue_capacity") {
            cfg.queue_capacity = v;
        }
        if let Some(v) = doc.usize_("batcher.max_streams") {
            cfg.batch_max_streams = v;
        }
        if let Some(v) = doc.usize_("batcher.chunk_t") {
            cfg.chunk_t = v;
        }
        if let Some(v) = doc.u64_("batcher.linger_us") {
            cfg.batch_linger_us = v;
        }
        if let Some(v) = doc.str_("artifacts.dir") {
            cfg.artifact_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.u64_("service.checkpoint_every") {
            cfg.checkpoint_every = v; // legacy spelling
        }
        if let Some(v) = doc.u64_("checkpoint.interval") {
            cfg.checkpoint_every = v;
        }
        if let Some(v) = doc.bool_("checkpoint.restore") {
            cfg.restore_on_resume = v;
        }
        if let Some(v) = doc.str_("checkpoint.dir") {
            cfg.checkpoint_dir = Some(PathBuf::from(v));
        }
        if let Some(v) = doc.usize_("checkpoint.keep") {
            cfg.checkpoint_keep = v;
        }
        if let Some(v) = doc.u64_("checkpoint.evict_after") {
            cfg.evict_after = v;
        }
        if let Some(v) = doc.u64_("service.seed") {
            cfg.seed = v;
        }
        if let Some(v) = doc.u64_("sharding.virtual_shards") {
            cfg.sharding.virtual_shards =
                u32::try_from(v).map_err(|_| {
                    Error::Config(format!(
                        "sharding.virtual_shards {v} exceeds u32"
                    ))
                })?;
        }
        if let Some(v) = doc.u64_("sharding.rebalance_interval") {
            cfg.sharding.rebalance_interval = v;
        }
        if let Some(v) = doc.f64_("sharding.imbalance_threshold") {
            cfg.sharding.imbalance_threshold = v;
        }
        if let Some(v) = doc.str_("obs.metrics_addr") {
            cfg.obs.metrics_addr = Some(v.to_string());
        }
        if let Some(v) = doc.bool_("obs.recorder") {
            cfg.obs.recorder = v;
        }
        if let Some(v) = doc.usize_("obs.recorder_capacity") {
            cfg.obs.recorder_capacity = v;
        }
        if let Some(v) = doc.u64_("cluster.node_id") {
            cfg.cluster.node_id = v;
        }
        if let Some(v) = doc.str_("cluster.listen") {
            cfg.cluster.listen = Some(v.to_string());
        }
        if let Some(arr) = doc.get("cluster.peers").and_then(Json::as_arr) {
            cfg.cluster.peers = arr
                .iter()
                .map(|p| {
                    p.as_str().map(str::to_string).ok_or_else(|| {
                        Error::Config(
                            "cluster.peers entries must be \
                             \"ID=ADDR\" strings"
                                .into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = doc.u64_("cluster.heartbeat_ms") {
            cfg.cluster.heartbeat_ms = v;
        }
        if let Some(v) = doc.u64_("cluster.failover_ms") {
            cfg.cluster.failover_ms = v;
        }
        if let Some(v) = doc.str_("cluster.join") {
            cfg.cluster.join = Some(v.to_string());
        }
        if let Some(v) = doc.u64_("cluster.rebalance_ms") {
            cfg.cluster.rebalance_ms = v;
        }
        if let Some(v) = doc.f64_("cluster.rebalance_threshold") {
            cfg.cluster.rebalance_threshold = v;
        }
        if let Some(v) = doc.u64_("cluster.ingest_buffer") {
            cfg.cluster.ingest_buffer = v;
        }
        cfg.ensemble.apply_toml(&doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from JSON text (same section/key layout as the TOML form:
    /// `{"engine": {...}, "service": {...}, "batcher": {...},
    /// "artifacts": {...}, "ensemble": {...}}`).
    pub fn from_json(text: &str) -> Result<Self> {
        let doc = Json::parse(text)
            .map_err(|e| Error::Config(format!("json: {e}")))?;
        let mut cfg = ServiceConfig::default();
        if let Some(v) = doc.get("name").and_then(Json::as_str) {
            cfg.name = v.to_string();
        }
        if let Some(engine) = doc.get("engine") {
            if let Some(v) = engine.get("kind").and_then(Json::as_str) {
                cfg.engine = v.parse()?;
            }
            if let Some(v) = engine.get("n_features").and_then(Json::as_usize)
            {
                cfg.n_features = v;
            }
            if let Some(v) = engine.get("m").and_then(Json::as_f64) {
                cfg.m = v;
            }
        }
        if let Some(service) = doc.get("service") {
            if let Some(v) = service.get("workers").and_then(Json::as_usize) {
                cfg.workers = v;
            }
            if let Some(v) =
                service.get("queue_capacity").and_then(Json::as_usize)
            {
                cfg.queue_capacity = v;
            }
            if let Some(v) =
                service.get("checkpoint_every").and_then(Json::as_u64)
            {
                cfg.checkpoint_every = v; // legacy spelling
            }
            if let Some(v) = service.get("seed").and_then(Json::as_u64) {
                cfg.seed = v;
            }
        }
        if let Some(checkpoint) = doc.get("checkpoint") {
            if let Some(v) = checkpoint.get("interval").and_then(Json::as_u64)
            {
                cfg.checkpoint_every = v;
            }
            if let Some(v) = checkpoint.get("restore").and_then(Json::as_bool)
            {
                cfg.restore_on_resume = v;
            }
            if let Some(v) = checkpoint.get("dir").and_then(Json::as_str) {
                cfg.checkpoint_dir = Some(PathBuf::from(v));
            }
            if let Some(v) = checkpoint.get("keep").and_then(Json::as_usize)
            {
                cfg.checkpoint_keep = v;
            }
            if let Some(v) =
                checkpoint.get("evict_after").and_then(Json::as_u64)
            {
                cfg.evict_after = v;
            }
        }
        if let Some(sharding) = doc.get("sharding") {
            if let Some(v) =
                sharding.get("virtual_shards").and_then(Json::as_u64)
            {
                cfg.sharding.virtual_shards =
                    u32::try_from(v).map_err(|_| {
                        Error::Config(format!(
                            "sharding.virtual_shards {v} exceeds u32"
                        ))
                    })?;
            }
            if let Some(v) =
                sharding.get("rebalance_interval").and_then(Json::as_u64)
            {
                cfg.sharding.rebalance_interval = v;
            }
            if let Some(v) =
                sharding.get("imbalance_threshold").and_then(Json::as_f64)
            {
                cfg.sharding.imbalance_threshold = v;
            }
        }
        if let Some(obs) = doc.get("obs") {
            if let Some(v) = obs.get("metrics_addr").and_then(Json::as_str) {
                cfg.obs.metrics_addr = Some(v.to_string());
            }
            if let Some(v) = obs.get("recorder").and_then(Json::as_bool) {
                cfg.obs.recorder = v;
            }
            if let Some(v) =
                obs.get("recorder_capacity").and_then(Json::as_usize)
            {
                cfg.obs.recorder_capacity = v;
            }
        }
        if let Some(cluster) = doc.get("cluster") {
            if let Some(v) = cluster.get("node_id").and_then(Json::as_u64) {
                cfg.cluster.node_id = v;
            }
            if let Some(v) = cluster.get("listen").and_then(Json::as_str) {
                cfg.cluster.listen = Some(v.to_string());
            }
            if let Some(arr) = cluster.get("peers").and_then(Json::as_arr) {
                cfg.cluster.peers = arr
                    .iter()
                    .map(|p| {
                        p.as_str().map(str::to_string).ok_or_else(|| {
                            Error::Config(
                                "cluster.peers entries must be \
                                 \"ID=ADDR\" strings"
                                    .into(),
                            )
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(v) =
                cluster.get("heartbeat_ms").and_then(Json::as_u64)
            {
                cfg.cluster.heartbeat_ms = v;
            }
            if let Some(v) =
                cluster.get("failover_ms").and_then(Json::as_u64)
            {
                cfg.cluster.failover_ms = v;
            }
            if let Some(v) = cluster.get("join").and_then(Json::as_str) {
                cfg.cluster.join = Some(v.to_string());
            }
            if let Some(v) =
                cluster.get("rebalance_ms").and_then(Json::as_u64)
            {
                cfg.cluster.rebalance_ms = v;
            }
            if let Some(v) =
                cluster.get("rebalance_threshold").and_then(Json::as_f64)
            {
                cfg.cluster.rebalance_threshold = v;
            }
            if let Some(v) =
                cluster.get("ingest_buffer").and_then(Json::as_u64)
            {
                cfg.cluster.ingest_buffer = v;
            }
        }
        if let Some(batcher) = doc.get("batcher") {
            if let Some(v) =
                batcher.get("max_streams").and_then(Json::as_usize)
            {
                cfg.batch_max_streams = v;
            }
            if let Some(v) = batcher.get("chunk_t").and_then(Json::as_usize) {
                cfg.chunk_t = v;
            }
            if let Some(v) = batcher.get("linger_us").and_then(Json::as_u64) {
                cfg.batch_linger_us = v;
            }
        }
        if let Some(v) = doc
            .get("artifacts")
            .and_then(|a| a.get("dir"))
            .and_then(Json::as_str)
        {
            cfg.artifact_dir = PathBuf::from(v);
        }
        if let Some(e) = doc.get("ensemble") {
            cfg.ensemble = EnsembleConfig::from_json(e)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path (`.json` dispatches to the JSON parser,
    /// anything else is treated as TOML).
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error::io(format!("reading {}", p.display()), e))?;
        if p.extension().and_then(|e| e.to_str()) == Some("json") {
            Self::from_json(&text)
        } else {
            Self::from_toml(&text)
        }
    }

    /// Invariant checks shared by all constructors.
    pub fn validate(&self) -> Result<()> {
        if self.n_features == 0 {
            return Err(Error::Config("n_features must be > 0".into()));
        }
        if self.m <= 0.0 {
            return Err(Error::Config("m must be > 0 (Eq. 6)".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("workers must be > 0".into()));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Config("queue_capacity must be > 0".into()));
        }
        if self.batch_max_streams == 0 || self.chunk_t == 0 {
            return Err(Error::Config(
                "batcher dimensions must be > 0".into(),
            ));
        }
        if self.checkpoint_keep == 0 {
            return Err(Error::Config(
                "checkpoint.keep must be > 0 (keep-last-K retention)"
                    .into(),
            ));
        }
        if self.sharding.virtual_shards == 0 {
            return Err(Error::Config(
                "sharding.virtual_shards must be > 0".into(),
            ));
        }
        // NaN must be rejected explicitly: it slips through any plain
        // comparison and would defeat every downstream threshold
        // check, migrating on each rebalance pass.
        let threshold = self.sharding.imbalance_threshold;
        if threshold.is_nan() || threshold <= 1.0 {
            return Err(Error::Config(
                "sharding.imbalance_threshold must be > 1.0 (1.0 would \
                 rebalance forever)"
                    .into(),
            ));
        }
        if self.obs.recorder_capacity == 0 {
            return Err(Error::Config(
                "obs.recorder_capacity must be > 0".into(),
            ));
        }
        if let Some(addr) = &self.obs.metrics_addr {
            if !addr.contains(':') {
                return Err(Error::Config(format!(
                    "obs.metrics_addr '{addr}' must be host:port"
                )));
            }
        }
        if let Some(listen) = &self.cluster.listen {
            if !listen.contains(':') {
                return Err(Error::Config(format!(
                    "cluster.listen '{listen}' must be host:port or \
                     unix:/path"
                )));
            }
            if self.cluster.heartbeat_ms == 0 {
                return Err(Error::Config(
                    "cluster.heartbeat_ms must be > 0".into(),
                ));
            }
        }
        if let Some(join) = &self.cluster.join {
            if !join.contains(':') {
                return Err(Error::Config(format!(
                    "cluster.join '{join}' must be host:port or \
                     unix:/path"
                )));
            }
            if !self.cluster.peers.is_empty() {
                return Err(Error::Config(
                    "cluster.join and cluster.peers are mutually \
                     exclusive (the roster arrives from the sponsor)"
                        .into(),
                ));
            }
            if self.cluster.listen.is_none() {
                return Err(Error::Config(
                    "cluster.join requires cluster.listen (peers must \
                     be able to dial back)"
                        .into(),
                ));
            }
        }
        if self.cluster.rebalance_ms > 0 {
            // Same NaN discipline as sharding.imbalance_threshold.
            let t = self.cluster.rebalance_threshold;
            if t.is_nan() || t <= 1.0 {
                return Err(Error::Config(
                    "cluster.rebalance_threshold must be > 1.0 (1.0 \
                     would rebalance forever)"
                        .into(),
                ));
            }
        }
        // Roster syntax fails at parse time, not at first dial.
        self.cluster.parse_peers()?;
        if self.engine == EngineKind::Ensemble {
            self.ensemble.validate()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ServiceConfig::default().validate().unwrap();
    }

    #[test]
    fn full_toml_roundtrip() {
        let text = r#"
            name = "prod-detector"
            [engine]
            kind = "xla"
            n_features = 4
            m = 2.5
            [service]
            workers = 8
            queue_capacity = 4096
            seed = 99
            [batcher]
            max_streams = 64
            chunk_t = 16
            linger_us = 50
            [artifacts]
            dir = "/opt/artifacts"
        "#;
        let cfg = ServiceConfig::from_toml(text).unwrap();
        assert_eq!(cfg.name, "prod-detector");
        assert_eq!(cfg.engine, EngineKind::Xla);
        assert_eq!(cfg.n_features, 4);
        assert_eq!(cfg.m, 2.5);
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.queue_capacity, 4096);
        assert_eq!(cfg.batch_max_streams, 64);
        assert_eq!(cfg.chunk_t, 16);
        assert_eq!(cfg.batch_linger_us, 50);
        assert_eq!(cfg.artifact_dir, PathBuf::from("/opt/artifacts"));
        assert_eq!(cfg.seed, 99);
    }

    #[test]
    fn partial_toml_keeps_defaults() {
        let cfg = ServiceConfig::from_toml("[engine]\nkind = \"rtl\"\n").unwrap();
        assert_eq!(cfg.engine, EngineKind::Rtl);
        assert_eq!(cfg.workers, ServiceConfig::default().workers);
    }

    #[test]
    fn bad_engine_kind_rejected() {
        assert!(ServiceConfig::from_toml("[engine]\nkind = \"gpu\"\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(ServiceConfig::from_toml("[engine]\nm = -1.0\n").is_err());
        assert!(
            ServiceConfig::from_toml("[service]\nworkers = 0\n").is_err()
        );
    }

    #[test]
    fn engine_kind_parse_display() {
        for (s, k) in [
            ("software", EngineKind::Software),
            ("rtl", EngineKind::Rtl),
            ("xla", EngineKind::Xla),
            ("ensemble", EngineKind::Ensemble),
        ] {
            assert_eq!(s.parse::<EngineKind>().unwrap(), k);
            assert_eq!(k.to_string(), s);
        }
    }

    #[test]
    fn ensemble_section_toml() {
        let text = r#"
            [engine]
            kind = "ensemble"
            [ensemble]
            combiner = "weighted-score"
            members = ["teda:m=3", "teda:m=2.5", "msigma:m=3,weight=0.5"]
        "#;
        let cfg = ServiceConfig::from_toml(text).unwrap();
        assert_eq!(cfg.engine, EngineKind::Ensemble);
        assert_eq!(
            cfg.ensemble.combiner,
            crate::config::CombinerKind::WeightedScore
        );
        assert_eq!(cfg.ensemble.members.len(), 3);
        assert_eq!(cfg.ensemble.members[2].weight, 0.5);
    }

    #[test]
    fn ensemble_section_toml_error_paths() {
        // Unknown combiner.
        assert!(ServiceConfig::from_toml(
            "[ensemble]\ncombiner = \"plurality\"\n"
        )
        .is_err());
        // Empty member list.
        assert!(
            ServiceConfig::from_toml("[ensemble]\nmembers = []\n").is_err()
        );
        // Unknown member kind.
        assert!(ServiceConfig::from_toml(
            "[ensemble]\nmembers = [\"gpu\"]\n"
        )
        .is_err());
    }

    #[test]
    fn ensemble_engine_without_section_gets_default_trio() {
        let cfg =
            ServiceConfig::from_toml("[engine]\nkind = \"ensemble\"\n")
                .unwrap();
        assert_eq!(cfg.engine, EngineKind::Ensemble);
        assert_eq!(cfg.ensemble, crate::config::EnsembleConfig::default());
    }

    #[test]
    fn json_config_matches_toml_config() {
        // Every key both parsers understand, with non-default values —
        // guards the two hand-written mappings against drifting apart.
        let toml = r#"
            name = "fused"
            [engine]
            kind = "ensemble"
            n_features = 4
            m = 2.5
            [service]
            workers = 2
            queue_capacity = 99
            seed = 123
            [checkpoint]
            interval = 7
            restore = true
            dir = "/var/lib/teda/ckpt"
            keep = 2
            evict_after = 5000
            [batcher]
            max_streams = 8
            chunk_t = 16
            linger_us = 42
            [artifacts]
            dir = "/opt/a"
            [obs]
            metrics_addr = "127.0.0.1:9464"
            recorder = false
            recorder_capacity = 512
            [cluster]
            node_id = 3
            listen = "127.0.0.1:7441"
            peers = ["1=127.0.0.1:7442", "2=unix:/tmp/teda-2.sock"]
            heartbeat_ms = 250
            failover_ms = 1500
            rebalance_ms = 2000
            rebalance_threshold = 1.75
            ingest_buffer = 4096
            [ensemble]
            combiner = "adaptive"
            members = ["teda", "rtl:m=2.5", "zscore:m=3,w=32"]
        "#;
        let json = r#"{
            "name": "fused",
            "engine": {"kind": "ensemble", "n_features": 4, "m": 2.5},
            "service": {"workers": 2, "queue_capacity": 99, "seed": 123},
            "checkpoint": {"interval": 7, "restore": true,
                           "dir": "/var/lib/teda/ckpt", "keep": 2,
                           "evict_after": 5000},
            "batcher": {"max_streams": 8, "chunk_t": 16, "linger_us": 42},
            "artifacts": {"dir": "/opt/a"},
            "obs": {"metrics_addr": "127.0.0.1:9464",
                    "recorder": false, "recorder_capacity": 512},
            "cluster": {"node_id": 3, "listen": "127.0.0.1:7441",
                        "peers": ["1=127.0.0.1:7442",
                                  "2=unix:/tmp/teda-2.sock"],
                        "heartbeat_ms": 250, "failover_ms": 1500,
                        "rebalance_ms": 2000,
                        "rebalance_threshold": 1.75,
                        "ingest_buffer": 4096},
            "ensemble": {"combiner": "adaptive",
                         "members": ["teda", "rtl:m=2.5", "zscore:m=3,w=32"]}
        }"#;
        let a = ServiceConfig::from_toml(toml).unwrap();
        let b = ServiceConfig::from_json(json).unwrap();
        assert_eq!(a, b);
        // And the values really landed (not both defaulted).
        assert_eq!(a.queue_capacity, 99);
        assert_eq!(a.batch_linger_us, 42);
        assert_eq!(a.checkpoint_every, 7);
        assert!(a.restore_on_resume);
        assert_eq!(
            a.checkpoint_dir,
            Some(PathBuf::from("/var/lib/teda/ckpt"))
        );
        assert_eq!(a.checkpoint_keep, 2);
        assert_eq!(a.evict_after, 5000);
        assert_eq!(a.m, 2.5);
        assert_eq!(a.obs.metrics_addr.as_deref(), Some("127.0.0.1:9464"));
        assert!(!a.obs.recorder);
        assert_eq!(a.obs.recorder_capacity, 512);
        assert_eq!(a.cluster.node_id, 3);
        assert_eq!(a.cluster.listen.as_deref(), Some("127.0.0.1:7441"));
        assert_eq!(a.cluster.peers.len(), 2);
        assert_eq!(a.cluster.heartbeat_ms, 250);
        assert_eq!(a.cluster.failover_ms, 1500);
        assert_eq!(a.cluster.rebalance_ms, 2000);
        assert_eq!(a.cluster.rebalance_threshold, 1.75);
        assert_eq!(a.cluster.ingest_buffer, 4096);
    }

    #[test]
    fn cluster_defaults_and_peer_parsing() {
        let cfg = ServiceConfig::default();
        assert!(!cfg.cluster.enabled(), "clustering off by default");
        assert_eq!(cfg.cluster.heartbeat_ms, 500);
        assert_eq!(cfg.cluster.failover_ms, 0, "auto failover off");
        assert!(cfg.cluster.join.is_none(), "static roster by default");
        assert_eq!(cfg.cluster.rebalance_ms, 0, "load rebalance off");
        assert_eq!(cfg.cluster.rebalance_threshold, 1.5);
        assert_eq!(cfg.cluster.ingest_buffer, 65_536);

        let cfg = ServiceConfig::from_toml(
            "[cluster]\nnode_id = 1\nlisten = \"127.0.0.1:0\"\n\
             peers = [\"2=127.0.0.1:7442\", \"3=unix:/tmp/n3.sock\"]\n",
        )
        .unwrap();
        assert!(cfg.cluster.enabled());
        let peers = cfg.cluster.parse_peers().unwrap();
        assert_eq!(
            peers,
            vec![
                (2, "127.0.0.1:7442".to_string()),
                (3, "unix:/tmp/n3.sock".to_string()),
            ]
        );
    }

    #[test]
    fn invalid_cluster_rejected() {
        // Listen without a port, zero heartbeat, malformed rosters,
        // self-referential and duplicate peer ids.
        assert!(ServiceConfig::from_toml(
            "[cluster]\nlisten = \"localhost\"\n"
        )
        .is_err());
        assert!(ServiceConfig::from_toml(
            "[cluster]\nlisten = \"127.0.0.1:7441\"\nheartbeat_ms = 0\n"
        )
        .is_err());
        assert!(ServiceConfig::from_toml(
            "[cluster]\npeers = [\"127.0.0.1:7442\"]\n"
        )
        .is_err());
        assert!(ServiceConfig::from_toml(
            "[cluster]\npeers = [\"x=127.0.0.1:7442\"]\n"
        )
        .is_err());
        assert!(ServiceConfig::from_toml(
            "[cluster]\nnode_id = 2\npeers = [\"2=127.0.0.1:7442\"]\n"
        )
        .is_err());
        assert!(ServiceConfig::from_json(
            r#"{"cluster": {"peers": ["1=a:1", "1=b:2"]}}"#
        )
        .is_err());
        // Join: needs a dialable form, a listen address, and no
        // static roster alongside it.
        assert!(ServiceConfig::from_toml(
            "[cluster]\nlisten = \"127.0.0.1:0\"\njoin = \"localhost\"\n"
        )
        .is_err());
        assert!(ServiceConfig::from_toml(
            "[cluster]\njoin = \"127.0.0.1:7441\"\n"
        )
        .is_err());
        assert!(ServiceConfig::from_toml(
            "[cluster]\nnode_id = 2\nlisten = \"127.0.0.1:0\"\n\
             join = \"127.0.0.1:7441\"\npeers = [\"1=127.0.0.1:7441\"]\n"
        )
        .is_err());
        // Rebalance threshold must be > 1.0 when rebalancing is on
        // (and NaN must not slip through).
        assert!(ServiceConfig::from_toml(
            "[cluster]\nrebalance_ms = 1000\nrebalance_threshold = 1.0\n"
        )
        .is_err());
        assert!(ServiceConfig::from_toml(
            "[cluster]\nrebalance_ms = 1000\nrebalance_threshold = nan\n"
        )
        .is_err());
        assert!(
            ServiceConfig::from_toml(
                "[cluster]\nrebalance_threshold = 1.0\n"
            )
            .is_ok(),
            "threshold unchecked while rebalancing is off"
        );
    }

    #[test]
    fn obs_section_defaults_and_partials() {
        let cfg = ServiceConfig::default();
        assert!(cfg.obs.metrics_addr.is_none(), "no endpoint by default");
        assert!(cfg.obs.recorder, "recorder on by default");
        assert_eq!(cfg.obs.recorder_capacity, 4096);
        // A partial section keeps the other defaults.
        let cfg = ServiceConfig::from_toml(
            "[obs]\nmetrics_addr = \"0.0.0.0:9464\"\n",
        )
        .unwrap();
        assert_eq!(cfg.obs.metrics_addr.as_deref(), Some("0.0.0.0:9464"));
        assert!(cfg.obs.recorder);
        assert_eq!(cfg.obs.recorder_capacity, 4096);
        let cfg = ServiceConfig::from_json(
            r#"{"obs": {"recorder": false}}"#,
        )
        .unwrap();
        assert!(!cfg.obs.recorder);
        assert!(cfg.obs.metrics_addr.is_none());
    }

    #[test]
    fn invalid_obs_rejected() {
        assert!(ServiceConfig::from_toml(
            "[obs]\nrecorder_capacity = 0\n"
        )
        .is_err());
        assert!(ServiceConfig::from_json(
            r#"{"obs": {"recorder_capacity": 0}}"#
        )
        .is_err());
        // An address without a port would only fail at bind time deep
        // inside serve; reject it at parse time instead.
        assert!(ServiceConfig::from_toml(
            "[obs]\nmetrics_addr = \"localhost\"\n"
        )
        .is_err());
    }

    #[test]
    fn checkpoint_dir_defaults_off_and_keep_must_be_positive() {
        let cfg = ServiceConfig::default();
        assert!(cfg.checkpoint_dir.is_none());
        assert_eq!(cfg.evict_after, 0);
        assert!(ServiceConfig::from_toml("[checkpoint]\nkeep = 0\n")
            .is_err());
    }

    #[test]
    fn checkpoint_section_and_legacy_key_coexist() {
        // New section wins; legacy spelling still parses alone.
        let cfg = ServiceConfig::from_toml(
            "[service]\ncheckpoint_every = 3\n[checkpoint]\ninterval = 11\n",
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 11);
        let cfg = ServiceConfig::from_toml(
            "[service]\ncheckpoint_every = 3\n",
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 3);
        assert!(!cfg.restore_on_resume);
        let cfg = ServiceConfig::from_json(
            r#"{"service": {"checkpoint_every": 3},
                "checkpoint": {"interval": 11, "restore": true}}"#,
        )
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 11);
        assert!(cfg.restore_on_resume);
    }

    #[test]
    fn sharding_section_roundtrips_in_toml_and_json() {
        // Mirrors the [ensemble]/[checkpoint] round-trip tests: the same
        // non-default values through both hand-written parsers must land
        // on the same typed config.
        let toml = r#"
            [sharding]
            virtual_shards = 64
            rebalance_interval = 5000
            imbalance_threshold = 2.25
        "#;
        let json = r#"{
            "sharding": {"virtual_shards": 64,
                         "rebalance_interval": 5000,
                         "imbalance_threshold": 2.25}
        }"#;
        let a = ServiceConfig::from_toml(toml).unwrap();
        let b = ServiceConfig::from_json(json).unwrap();
        assert_eq!(a, b);
        // And the values really landed (not both defaulted).
        assert_eq!(a.sharding.virtual_shards, 64);
        assert_eq!(a.sharding.rebalance_interval, 5000);
        assert_eq!(a.sharding.imbalance_threshold, 2.25);
    }

    #[test]
    fn sharding_defaults_and_partial_sections() {
        let cfg = ServiceConfig::default();
        assert_eq!(
            cfg.sharding.virtual_shards,
            crate::coordinator::DEFAULT_VIRTUAL_SHARDS
        );
        assert_eq!(cfg.sharding.rebalance_interval, 0, "auto off");
        assert_eq!(cfg.sharding.imbalance_threshold, 1.5);
        // A partial section keeps the other defaults.
        let cfg = ServiceConfig::from_toml(
            "[sharding]\nvirtual_shards = 32\n",
        )
        .unwrap();
        assert_eq!(cfg.sharding.virtual_shards, 32);
        assert_eq!(cfg.sharding.imbalance_threshold, 1.5);
        let cfg = ServiceConfig::from_json(
            r#"{"sharding": {"rebalance_interval": 9}}"#,
        )
        .unwrap();
        assert_eq!(cfg.sharding.rebalance_interval, 9);
        assert_eq!(
            cfg.sharding.virtual_shards,
            crate::coordinator::DEFAULT_VIRTUAL_SHARDS
        );
    }

    #[test]
    fn invalid_sharding_rejected() {
        assert!(ServiceConfig::from_toml(
            "[sharding]\nvirtual_shards = 0\n"
        )
        .is_err());
        // Out-of-u32-range values error instead of silently wrapping.
        assert!(ServiceConfig::from_toml(
            "[sharding]\nvirtual_shards = 4294967552\n"
        )
        .is_err());
        assert!(ServiceConfig::from_json(
            r#"{"sharding": {"virtual_shards": 4294967296}}"#
        )
        .is_err());
        assert!(ServiceConfig::from_toml(
            "[sharding]\nimbalance_threshold = 1.0\n"
        )
        .is_err());
        assert!(ServiceConfig::from_json(
            r#"{"sharding": {"virtual_shards": 0}}"#
        )
        .is_err());
        assert!(ServiceConfig::from_json(
            r#"{"sharding": {"imbalance_threshold": 0.5}}"#
        )
        .is_err());
        // NaN would defeat every threshold comparison downstream.
        assert!(ServiceConfig::from_toml(
            "[sharding]\nimbalance_threshold = nan\n"
        )
        .is_err());
    }

    #[test]
    fn json_config_error_paths() {
        assert!(ServiceConfig::from_json("{not json").is_err());
        assert!(ServiceConfig::from_json(
            r#"{"ensemble": {"combiner": "plurality"}}"#
        )
        .is_err());
        assert!(ServiceConfig::from_json(
            r#"{"ensemble": {"members": []}}"#
        )
        .is_err());
    }
}

//! Typed service configuration for the L3 coordinator.

use std::path::PathBuf;

use crate::config::TomlDoc;
use crate::{Error, Result};

/// Which detector backend the coordinator drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust `teda::TedaDetector` (f64) — the software reference.
    Software,
    /// Cycle-accurate RTL pipeline simulator (f32, paper's architecture).
    Rtl,
    /// AOT-compiled JAX/Pallas artifact via PJRT.
    Xla,
}

impl std::str::FromStr for EngineKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "software" | "sw" => Ok(EngineKind::Software),
            "rtl" | "fpga" => Ok(EngineKind::Rtl),
            "xla" | "pjrt" => Ok(EngineKind::Xla),
            other => Err(Error::Config(format!(
                "unknown engine kind '{other}' (software|rtl|xla)"
            ))),
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EngineKind::Software => "software",
            EngineKind::Rtl => "rtl",
            EngineKind::Xla => "xla",
        })
    }
}

/// Full coordinator/service configuration.
///
/// Built from a TOML file ([`ServiceConfig::from_toml`]) or defaults +
/// programmatic overrides; every field has a production-sane default so
/// examples can run with zero config.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceConfig {
    /// Human name used in logs/metrics.
    pub name: String,
    /// Detector backend.
    pub engine: EngineKind,
    /// Feature dimension N of every stream.
    pub n_features: usize,
    /// Chebyshev multiplier m (Eq. 6; the paper uses 3).
    pub m: f64,
    /// Worker threads executing detector engines.
    pub workers: usize,
    /// Bounded capacity of each worker's input queue (backpressure knob).
    pub queue_capacity: usize,
    /// Dynamic batcher: max streams packed per XLA chunk.
    pub batch_max_streams: usize,
    /// Dynamic batcher: samples per stream per chunk (T axis).
    pub chunk_t: usize,
    /// Dynamic batcher: max linger before a partial batch is flushed.
    pub batch_linger_us: u64,
    /// Directory with AOT artifacts (XLA engine only).
    pub artifact_dir: PathBuf,
    /// Per-stream state checkpoint interval in samples (0 = disabled).
    pub checkpoint_every: u64,
    /// RNG seed for anything stochastic in the service (workload gen).
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            name: "teda-service".into(),
            engine: EngineKind::Software,
            n_features: 2,
            m: 3.0,
            workers: 4,
            queue_capacity: 1024,
            batch_max_streams: 32,
            chunk_t: 32,
            batch_linger_us: 200,
            artifact_dir: PathBuf::from("artifacts"),
            checkpoint_every: 0,
            seed: 0x7EDA, // "TEDA"
        }
    }
}

impl ServiceConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ServiceConfig::default();
        if let Some(v) = doc.str_("name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.str_("engine.kind") {
            cfg.engine = v.parse()?;
        }
        if let Some(v) = doc.usize_("engine.n_features") {
            cfg.n_features = v;
        }
        if let Some(v) = doc.f64_("engine.m") {
            cfg.m = v;
        }
        if let Some(v) = doc.usize_("service.workers") {
            cfg.workers = v;
        }
        if let Some(v) = doc.usize_("service.queue_capacity") {
            cfg.queue_capacity = v;
        }
        if let Some(v) = doc.usize_("batcher.max_streams") {
            cfg.batch_max_streams = v;
        }
        if let Some(v) = doc.usize_("batcher.chunk_t") {
            cfg.chunk_t = v;
        }
        if let Some(v) = doc.u64_("batcher.linger_us") {
            cfg.batch_linger_us = v;
        }
        if let Some(v) = doc.str_("artifacts.dir") {
            cfg.artifact_dir = PathBuf::from(v);
        }
        if let Some(v) = doc.u64_("service.checkpoint_every") {
            cfg.checkpoint_every = v;
        }
        if let Some(v) = doc.u64_("service.seed") {
            cfg.seed = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error::io(format!("reading {}", p.display()), e))?;
        Self::from_toml(&text)
    }

    /// Invariant checks shared by all constructors.
    pub fn validate(&self) -> Result<()> {
        if self.n_features == 0 {
            return Err(Error::Config("n_features must be > 0".into()));
        }
        if self.m <= 0.0 {
            return Err(Error::Config("m must be > 0 (Eq. 6)".into()));
        }
        if self.workers == 0 {
            return Err(Error::Config("workers must be > 0".into()));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Config("queue_capacity must be > 0".into()));
        }
        if self.batch_max_streams == 0 || self.chunk_t == 0 {
            return Err(Error::Config(
                "batcher dimensions must be > 0".into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ServiceConfig::default().validate().unwrap();
    }

    #[test]
    fn full_toml_roundtrip() {
        let text = r#"
            name = "prod-detector"
            [engine]
            kind = "xla"
            n_features = 4
            m = 2.5
            [service]
            workers = 8
            queue_capacity = 4096
            seed = 99
            [batcher]
            max_streams = 64
            chunk_t = 16
            linger_us = 50
            [artifacts]
            dir = "/opt/artifacts"
        "#;
        let cfg = ServiceConfig::from_toml(text).unwrap();
        assert_eq!(cfg.name, "prod-detector");
        assert_eq!(cfg.engine, EngineKind::Xla);
        assert_eq!(cfg.n_features, 4);
        assert_eq!(cfg.m, 2.5);
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.queue_capacity, 4096);
        assert_eq!(cfg.batch_max_streams, 64);
        assert_eq!(cfg.chunk_t, 16);
        assert_eq!(cfg.batch_linger_us, 50);
        assert_eq!(cfg.artifact_dir, PathBuf::from("/opt/artifacts"));
        assert_eq!(cfg.seed, 99);
    }

    #[test]
    fn partial_toml_keeps_defaults() {
        let cfg = ServiceConfig::from_toml("[engine]\nkind = \"rtl\"\n").unwrap();
        assert_eq!(cfg.engine, EngineKind::Rtl);
        assert_eq!(cfg.workers, ServiceConfig::default().workers);
    }

    #[test]
    fn bad_engine_kind_rejected() {
        assert!(ServiceConfig::from_toml("[engine]\nkind = \"gpu\"\n").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(ServiceConfig::from_toml("[engine]\nm = -1.0\n").is_err());
        assert!(
            ServiceConfig::from_toml("[service]\nworkers = 0\n").is_err()
        );
    }

    #[test]
    fn engine_kind_parse_display() {
        for (s, k) in [
            ("software", EngineKind::Software),
            ("rtl", EngineKind::Rtl),
            ("xla", EngineKind::Xla),
        ] {
            assert_eq!(s.parse::<EngineKind>().unwrap(), k);
            assert_eq!(k.to_string(), s);
        }
    }
}

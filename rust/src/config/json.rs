//! Minimal JSON parser (RFC 8259 subset sufficient for the artifact
//! manifest and service configs; in-repo stand-in for `serde_json`,
//! see DESIGN.md §3).
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes incl. `\uXXXX`, numbers, booleans, null). Not supported:
//! surrogate-pair decoding beyond the BMP (unpaired surrogates error out).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As u64 if an integral number.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// As usize if an integral number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!(
                "expected '{}', found {:?}",
                b as char,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(self.err(format!("unexpected {other:?}"))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => {
                    return Err(
                        self.err(format!("expected ',' or '}}', got {other:?}"))
                    )
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                other => {
                    return Err(
                        self.err(format!("expected ',' or ']', got {other:?}"))
                    )
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    other => {
                        return Err(self.err(format!("bad escape {other:?}")))
                    }
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the sequence verbatim.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8 byte")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(format!("bad number '{text}': {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"variants":[{"name":"v1","s":8,"ok":true},{"name":"v2","shape":[8,16,2]}],"x":null}"#;
        let v = Json::parse(doc).unwrap();
        let variants = v.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants.len(), 2);
        assert_eq!(variants[0].get("name").unwrap().as_str(), Some("v1"));
        assert_eq!(variants[0].get("s").unwrap().as_usize(), Some(8));
        assert_eq!(
            variants[1].get("shape").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(v.get("x"), Some(&Json::Null));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""éA""#).unwrap(),
            Json::Str("éA".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(
            Json::parse("\"μ_k σ²\"").unwrap(),
            Json::Str("μ_k σ²".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let doc = r#"{"a":[1,2.5,"x"],"b":{"c":true}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" :  [ 1 , 2 ]\r\n} ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}

//! TOML-subset parser for service config files (in-repo stand-in for the
//! `toml` crate, DESIGN.md §3).
//!
//! Supported: `[section]` / `[section.sub]` headers, `key = value` pairs
//! with string / integer / float / boolean / flat-array values, `#`
//! comments, bare and quoted keys. Not supported (rejected, not
//! mis-parsed): array-of-tables, inline tables, multi-line strings,
//! datetimes.

use std::collections::BTreeMap;

use crate::config::Json;
use crate::{Error, Result};

/// A parsed TOML document: dotted-path → scalar/array value (stored as
/// [`Json`] values for uniform typed access).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    entries: BTreeMap<String, Json>,
}

impl TomlDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    return Err(err(lineno, "array-of-tables not supported"));
                }
                let end = rest
                    .find(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?;
                if !rest[end + 1..].trim().is_empty() {
                    return Err(err(lineno, "garbage after section header"));
                }
                section = rest[..end].trim().to_string();
                if section.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, "expected key = value"))?;
            let key = parse_key(line[..eq].trim())
                .ok_or_else(|| err(lineno, "bad key"))?;
            let value = parse_value(line[eq + 1..].trim())
                .ok_or_else(|| err(lineno, "bad value"))?;
            let path = if section.is_empty() {
                key
            } else {
                format!("{section}.{key}")
            };
            if entries.insert(path.clone(), value).is_some() {
                return Err(err(lineno, &format!("duplicate key '{path}'")));
            }
        }
        Ok(TomlDoc { entries })
    }

    /// Load + parse a file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<TomlDoc> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error::io(format!("reading {}", p.display()), e))?;
        Self::parse(&text)
    }

    /// Raw value at a dotted path.
    pub fn get(&self, path: &str) -> Option<&Json> {
        self.entries.get(path)
    }

    /// Typed accessors (None when missing or mistyped).
    pub fn str_(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Json::as_str)
    }

    pub fn u64_(&self, path: &str) -> Option<u64> {
        self.get(path).and_then(Json::as_u64)
    }

    pub fn usize_(&self, path: &str) -> Option<usize> {
        self.get(path).and_then(Json::as_usize)
    }

    pub fn f64_(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Json::as_f64)
    }

    pub fn bool_(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Json::as_bool)
    }

    /// All keys under a section prefix (e.g. `"engine"` → `engine.kind`…).
    pub fn keys_under<'a>(
        &'a self,
        prefix: &'a str,
    ) -> impl Iterator<Item = &'a str> + 'a {
        let want = format!("{prefix}.");
        self.entries
            .keys()
            .filter(move |k| k.starts_with(&want))
            .map(String::as_str)
    }
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_key(raw: &str) -> Option<String> {
    if raw.is_empty() {
        return None;
    }
    if let Some(stripped) =
        raw.strip_prefix('"').and_then(|r| r.strip_suffix('"'))
    {
        return Some(stripped.to_string());
    }
    if raw
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
    {
        Some(raw.to_string())
    } else {
        None
    }
}

fn parse_value(raw: &str) -> Option<Json> {
    if raw.is_empty() {
        return None;
    }
    if raw == "true" {
        return Some(Json::Bool(true));
    }
    if raw == "false" {
        return Some(Json::Bool(false));
    }
    if let Some(stripped) =
        raw.strip_prefix('"').and_then(|r| r.strip_suffix('"'))
    {
        // Basic strings with the common escapes.
        let mut out = String::new();
        let mut chars = stripped.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next()? {
                    'n' => out.push('\n'),
                    't' => out.push('\t'),
                    'r' => out.push('\r'),
                    '"' => out.push('"'),
                    '\\' => out.push('\\'),
                    _ => return None,
                }
            } else {
                out.push(c);
            }
        }
        return Some(Json::Str(out));
    }
    if let Some(inner) =
        raw.strip_prefix('[').and_then(|r| r.strip_suffix(']'))
    {
        let inner = inner.trim();
        if inner.is_empty() {
            return Some(Json::Arr(vec![]));
        }
        let items = split_top_level(inner)?
            .into_iter()
            .map(|s| parse_value(s.trim()))
            .collect::<Option<Vec<_>>>()?;
        return Some(Json::Arr(items));
    }
    // Numbers (allow underscores as separators, TOML-style).
    let cleaned: String = raw.chars().filter(|&c| c != '_').collect();
    cleaned.parse::<f64>().ok().map(Json::Num)
}

/// Split an array body on commas not inside nested brackets or strings.
fn split_top_level(s: &str) -> Option<Vec<&str>> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.checked_sub(1)?,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str || depth != 0 {
        return None;
    }
    parts.push(&s[start..]);
    Some(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
        # service config
        name = "teda-service"   # trailing comment
        workers = 4
        rate = 2.5
        debug = false

        [engine]
        kind = "xla"
        m = 3.0

        [engine.batcher]
        max_streams = 32
        shapes = [8, 16, 32]
        tags = ["a", "b"]
    "#;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(DOC).unwrap();
        assert_eq!(doc.str_("name"), Some("teda-service"));
        assert_eq!(doc.u64_("workers"), Some(4));
        assert_eq!(doc.f64_("rate"), Some(2.5));
        assert_eq!(doc.bool_("debug"), Some(false));
        assert_eq!(doc.str_("engine.kind"), Some("xla"));
        assert_eq!(doc.usize_("engine.batcher.max_streams"), Some(32));
        let shapes = doc.get("engine.batcher.shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes.len(), 3);
        assert_eq!(shapes[2].as_usize(), Some(32));
    }

    #[test]
    fn keys_under_lists_section() {
        let doc = TomlDoc::parse(DOC).unwrap();
        let keys: Vec<&str> = doc.keys_under("engine").collect();
        assert!(keys.contains(&"engine.kind"));
        assert!(keys.contains(&"engine.batcher.max_streams"));
        assert!(!keys.contains(&"name"));
    }

    #[test]
    fn string_escapes_and_hash_in_string() {
        let doc =
            TomlDoc::parse("s = \"a#b\\nc\"\n").unwrap();
        assert_eq!(doc.str_("s"), Some("a#b\nc"));
    }

    #[test]
    fn numbers_with_underscores() {
        let doc = TomlDoc::parse("big = 1_000_000\n").unwrap();
        assert_eq!(doc.u64_("big"), Some(1_000_000));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("k = \n").is_err());
        assert!(TomlDoc::parse("[[tables]]\n").is_err());
        assert!(TomlDoc::parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn empty_and_comment_only() {
        let doc = TomlDoc::parse("\n# nothing\n\n").unwrap();
        assert_eq!(doc, TomlDoc::default());
    }
}

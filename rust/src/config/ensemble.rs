//! `[ensemble]` configuration: member specs, combiner choice, and the
//! TOML/JSON (de)serialization both the service config and the CLI use.
//!
//! A member is written as a compact spec string:
//!
//! ```text
//! kind[:key=value[,key=value...]]
//!
//! kinds:  teda    — software TEDA (f64 reference)
//!         rtl     — cycle-accurate RTL-sim TEDA (f32, 2-cycle latency)
//!         msigma  — running m·σ baseline
//!         zscore  — sliding-window z-score baseline
//! keys:   m       — Chebyshev / sigma multiplier (default 3)
//!         w       — window length, zscore only (default 64)
//!         weight  — static fusion weight for weighted combiners (default 1)
//! ```
//!
//! e.g. `"teda:m=2.5"`, `"zscore:m=3,w=128"`, `"rtl:m=3,weight=0.5"` —
//! a TOML `members = ["teda", "teda:m=2.5", "msigma"]` array therefore
//! describes an m-threshold sweep plus a heterogeneous baseline.

use crate::config::{Json, TomlDoc};
use crate::{Error, Result};

/// Which detector family a member instantiates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemberKind {
    /// Software TEDA ([`crate::engine::SoftwareEngine`]).
    TedaSoftware,
    /// RTL-sim TEDA ([`crate::engine::RtlEngine`]).
    TedaRtl,
    /// Running m·σ baseline ([`crate::baselines::MSigmaDetector`]).
    MSigma,
    /// Sliding z-score baseline ([`crate::baselines::SlidingZScore`]).
    ZScore,
}

impl MemberKind {
    /// Canonical spec-string name.
    pub fn name(&self) -> &'static str {
        match self {
            MemberKind::TedaSoftware => "teda",
            MemberKind::TedaRtl => "rtl",
            MemberKind::MSigma => "msigma",
            MemberKind::ZScore => "zscore",
        }
    }
}

/// One ensemble member: detector family plus its parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct MemberSpec {
    pub kind: MemberKind,
    /// Chebyshev multiplier (TEDA members) / sigma multiplier (baselines).
    pub m: f64,
    /// Sliding-window length (zscore members only).
    pub window: usize,
    /// Static fusion weight (weighted combiners; 1.0 = neutral).
    pub weight: f64,
}

impl MemberSpec {
    /// A member of `kind` with default parameters (m=3, w=64, weight=1).
    pub fn new(kind: MemberKind) -> Self {
        MemberSpec { kind, m: 3.0, window: 64, weight: 1.0 }
    }

    /// Builder: override the m multiplier.
    pub fn with_m(mut self, m: f64) -> Self {
        self.m = m;
        self
    }

    /// Human label for reports/metrics (e.g. `"teda(m=2.5)"`).
    pub fn label(&self) -> String {
        match self.kind {
            MemberKind::ZScore => {
                format!("{}(m={},w={})", self.kind.name(), self.m, self.window)
            }
            _ => format!("{}(m={})", self.kind.name(), self.m),
        }
    }
}

impl std::str::FromStr for MemberSpec {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim();
        let (kind_s, params) = match s.split_once(':') {
            Some((k, p)) => (k.trim(), Some(p)),
            None => (s, None),
        };
        let kind = match kind_s {
            "teda" | "software" | "sw" => MemberKind::TedaSoftware,
            "rtl" | "fpga" => MemberKind::TedaRtl,
            "msigma" | "sigma" => MemberKind::MSigma,
            "zscore" | "window" => MemberKind::ZScore,
            other => {
                return Err(Error::Config(format!(
                    "unknown ensemble member kind '{other}' \
                     (teda|rtl|msigma|zscore)"
                )))
            }
        };
        let mut spec = MemberSpec::new(kind);
        if let Some(params) = params {
            for kv in params.split(',') {
                let (key, val) = kv.split_once('=').ok_or_else(|| {
                    Error::Config(format!(
                        "member '{s}': expected key=value, got '{kv}'"
                    ))
                })?;
                let (key, val) = (key.trim(), val.trim());
                match key {
                    "m" => {
                        spec.m = val.parse().map_err(|_| {
                            Error::Config(format!("member '{s}': bad m '{val}'"))
                        })?;
                        if spec.m <= 0.0 {
                            return Err(Error::Config(format!(
                                "member '{s}': m must be > 0"
                            )));
                        }
                    }
                    "w" | "window" => {
                        if kind != MemberKind::ZScore {
                            return Err(Error::Config(format!(
                                "member '{s}': window only applies to zscore"
                            )));
                        }
                        spec.window = val.parse().map_err(|_| {
                            Error::Config(format!(
                                "member '{s}': bad window '{val}'"
                            ))
                        })?;
                        if spec.window < 2 {
                            return Err(Error::Config(format!(
                                "member '{s}': window must be >= 2"
                            )));
                        }
                    }
                    "weight" => {
                        spec.weight = val.parse().map_err(|_| {
                            Error::Config(format!(
                                "member '{s}': bad weight '{val}'"
                            ))
                        })?;
                        if spec.weight <= 0.0 {
                            return Err(Error::Config(format!(
                                "member '{s}': weight must be > 0"
                            )));
                        }
                    }
                    other => {
                        return Err(Error::Config(format!(
                            "member '{s}': unknown parameter '{other}' \
                             (m|w|weight)"
                        )))
                    }
                }
            }
        }
        Ok(spec)
    }
}

impl std::fmt::Display for MemberSpec {
    /// Canonical spec string; `parse ∘ to_string` is the identity.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:m={}", self.kind.name(), self.m)?;
        if self.kind == MemberKind::ZScore {
            write!(f, ",w={}", self.window)?;
        }
        if self.weight != 1.0 {
            write!(f, ",weight={}", self.weight)?;
        }
        Ok(())
    }
}

/// Fusion strategy selector (the strategies live in
/// [`crate::ensemble::combiner`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombinerKind {
    /// Strict majority of member outlier flags.
    Majority,
    /// Sign of the static-weighted sum of member margin scores.
    WeightedScore,
    /// Flag when ANY member flags (max sensitivity).
    AnyOf,
    /// Flag when ALL members flag (max precision).
    AllOf,
    /// Weighted vote whose weights decay on disagreement (fSEAD-style).
    Adaptive,
}

impl std::str::FromStr for CombinerKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.trim() {
            "majority" | "majority-vote" | "vote" => Ok(CombinerKind::Majority),
            "weighted" | "weighted-score" => Ok(CombinerKind::WeightedScore),
            "any" | "any-of" | "or" => Ok(CombinerKind::AnyOf),
            "all" | "all-of" | "and" => Ok(CombinerKind::AllOf),
            "adaptive" | "adaptive-weighted" => Ok(CombinerKind::Adaptive),
            other => Err(Error::Config(format!(
                "unknown combiner '{other}' \
                 (majority|weighted-score|any-of|all-of|adaptive)"
            ))),
        }
    }
}

impl std::fmt::Display for CombinerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CombinerKind::Majority => "majority",
            CombinerKind::WeightedScore => "weighted-score",
            CombinerKind::AnyOf => "any-of",
            CombinerKind::AllOf => "all-of",
            CombinerKind::Adaptive => "adaptive",
        })
    }
}

/// The `[ensemble]` section: member roster + fusion strategy.
#[derive(Debug, Clone, PartialEq)]
pub struct EnsembleConfig {
    pub members: Vec<MemberSpec>,
    pub combiner: CombinerKind,
}

impl Default for EnsembleConfig {
    /// Default heterogeneous trio: TEDA reference, the m·σ strawman, and
    /// a sliding z-score — three detector families, majority-fused.
    fn default() -> Self {
        EnsembleConfig {
            members: vec![
                MemberSpec::new(MemberKind::TedaSoftware),
                MemberSpec::new(MemberKind::MSigma),
                MemberSpec::new(MemberKind::ZScore),
            ],
            combiner: CombinerKind::Majority,
        }
    }
}

impl EnsembleConfig {
    /// Build from a `+`-separated member list (CLI `--members`), e.g.
    /// `"teda+teda:m=2.5+zscore:m=3,w=128"`. `+`/`;` separate members
    /// because `,` already separates parameters *within* one spec.
    pub fn from_member_list(
        members: &str,
        combiner: CombinerKind,
    ) -> Result<Self> {
        let members: Vec<MemberSpec> = members
            .split(&['+', ';'][..])
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::parse)
            .collect::<Result<_>>()?;
        if members.is_empty() {
            return Err(Error::Config(
                "ensemble needs at least one member".into(),
            ));
        }
        Ok(EnsembleConfig { members, combiner })
    }

    /// Overlay the `[ensemble]` section of a parsed TOML doc, if present.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        if let Some(c) = doc.str_("ensemble.combiner") {
            self.combiner = c.parse()?;
        } else if doc.get("ensemble.combiner").is_some() {
            return Err(Error::Config(
                "ensemble.combiner must be a string".into(),
            ));
        }
        if let Some(j) = doc.get("ensemble.members") {
            self.members = parse_member_array(j)?;
        }
        Ok(())
    }

    /// Parse from the `"ensemble"` object of a JSON service config.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = EnsembleConfig::default();
        if let Some(c) = j.get("combiner") {
            let s = c.as_str().ok_or_else(|| {
                Error::Config("ensemble.combiner must be a string".into())
            })?;
            cfg.combiner = s.parse()?;
        }
        if let Some(m) = j.get("members") {
            cfg.members = parse_member_array(m)?;
        }
        Ok(cfg)
    }

    /// Serialize to the JSON object shape [`EnsembleConfig::from_json`]
    /// accepts (round-trip safe).
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert(
            "combiner".to_string(),
            Json::Str(self.combiner.to_string()),
        );
        obj.insert(
            "members".to_string(),
            Json::Arr(
                self.members
                    .iter()
                    .map(|m| Json::Str(m.to_string()))
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }

    /// Serialize to a TOML `[ensemble]` section (round-trip safe).
    pub fn to_toml_section(&self) -> String {
        let members: Vec<String> =
            self.members.iter().map(|m| format!("\"{m}\"")).collect();
        format!(
            "[ensemble]\ncombiner = \"{}\"\nmembers = [{}]\n",
            self.combiner,
            members.join(", ")
        )
    }

    /// Per-member display labels (metrics, reports).
    pub fn labels(&self) -> Vec<String> {
        self.members.iter().map(MemberSpec::label).collect()
    }

    /// Invariant checks (used by `ServiceConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        if self.members.is_empty() {
            return Err(Error::Config(
                "ensemble needs at least one member".into(),
            ));
        }
        Ok(())
    }
}

/// Parse a JSON/TOML array of member spec strings (shared error paths).
fn parse_member_array(j: &Json) -> Result<Vec<MemberSpec>> {
    let arr = j.as_arr().ok_or_else(|| {
        Error::Config("ensemble.members must be an array of strings".into())
    })?;
    if arr.is_empty() {
        return Err(Error::Config(
            "ensemble.members must list at least one member".into(),
        ));
    }
    arr.iter()
        .map(|v| {
            v.as_str()
                .ok_or_else(|| {
                    Error::Config(
                        "ensemble.members entries must be strings".into(),
                    )
                })?
                .parse()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn member_spec_parse_display_roundtrip() {
        for s in [
            "teda",
            "teda:m=2.5",
            "rtl:m=3",
            "msigma:m=4,weight=0.5",
            "zscore:m=3,w=128",
        ] {
            let spec: MemberSpec = s.parse().unwrap();
            let back: MemberSpec = spec.to_string().parse().unwrap();
            assert_eq!(spec, back, "roundtrip failed for '{s}'");
        }
    }

    #[test]
    fn member_spec_defaults() {
        let spec: MemberSpec = "teda".parse().unwrap();
        assert_eq!(spec.kind, MemberKind::TedaSoftware);
        assert_eq!(spec.m, 3.0);
        assert_eq!(spec.weight, 1.0);
        let z: MemberSpec = "zscore".parse().unwrap();
        assert_eq!(z.window, 64);
    }

    #[test]
    fn member_spec_rejects_bad_input() {
        assert!("gpu".parse::<MemberSpec>().is_err());
        assert!("teda:m=0".parse::<MemberSpec>().is_err());
        assert!("teda:m=abc".parse::<MemberSpec>().is_err());
        assert!("teda:w=8".parse::<MemberSpec>().is_err()); // window ≠ teda
        assert!("zscore:w=1".parse::<MemberSpec>().is_err());
        assert!("teda:bogus=1".parse::<MemberSpec>().is_err());
        assert!("teda:m".parse::<MemberSpec>().is_err());
        assert!("msigma:weight=-2".parse::<MemberSpec>().is_err());
    }

    #[test]
    fn combiner_kind_parse_display_roundtrip() {
        for k in [
            CombinerKind::Majority,
            CombinerKind::WeightedScore,
            CombinerKind::AnyOf,
            CombinerKind::AllOf,
            CombinerKind::Adaptive,
        ] {
            assert_eq!(k.to_string().parse::<CombinerKind>().unwrap(), k);
        }
        assert!("plurality".parse::<CombinerKind>().is_err());
    }

    #[test]
    fn member_list_uses_plus_separator() {
        let cfg = EnsembleConfig::from_member_list(
            "teda + teda:m=2.5 + zscore:m=3,w=128",
            CombinerKind::AnyOf,
        )
        .unwrap();
        assert_eq!(cfg.members.len(), 3);
        assert_eq!(cfg.members[1].m, 2.5);
        assert_eq!(cfg.members[2].window, 128);
        assert!(EnsembleConfig::from_member_list("", CombinerKind::AnyOf)
            .is_err());
    }

    #[test]
    fn toml_json_roundtrip() {
        let toml = "\
            [ensemble]\n\
            combiner = \"adaptive\"\n\
            members = [\"teda\", \"rtl:m=2.5\", \"zscore:m=3,w=32\"]\n";
        let doc = TomlDoc::parse(toml).unwrap();
        let mut cfg = EnsembleConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.combiner, CombinerKind::Adaptive);
        assert_eq!(cfg.members.len(), 3);

        // TOML → JSON → EnsembleConfig must be lossless.
        let json = cfg.to_json();
        let back = EnsembleConfig::from_json(&json).unwrap();
        assert_eq!(cfg, back);

        // And back through the TOML section renderer too.
        let doc2 = TomlDoc::parse(&cfg.to_toml_section()).unwrap();
        let mut cfg2 = EnsembleConfig::default();
        cfg2.apply_toml(&doc2).unwrap();
        assert_eq!(cfg, cfg2);
    }

    #[test]
    fn json_text_roundtrip() {
        let json = Json::parse(
            r#"{"combiner": "weighted-score",
                "members": ["teda:m=3,weight=2", "msigma"]}"#,
        )
        .unwrap();
        let cfg = EnsembleConfig::from_json(&json).unwrap();
        assert_eq!(cfg.combiner, CombinerKind::WeightedScore);
        assert_eq!(cfg.members[0].weight, 2.0);
        let reparsed =
            Json::parse(&cfg.to_json().to_string_compact()).unwrap();
        assert_eq!(EnsembleConfig::from_json(&reparsed).unwrap(), cfg);
    }

    #[test]
    fn unknown_combiner_rejected_in_both_formats() {
        let doc = TomlDoc::parse(
            "[ensemble]\ncombiner = \"plurality\"\n",
        )
        .unwrap();
        let mut cfg = EnsembleConfig::default();
        assert!(cfg.apply_toml(&doc).is_err());

        let json =
            Json::parse(r#"{"combiner": "plurality"}"#).unwrap();
        assert!(EnsembleConfig::from_json(&json).is_err());
    }

    #[test]
    fn empty_members_rejected_in_both_formats() {
        let doc =
            TomlDoc::parse("[ensemble]\nmembers = []\n").unwrap();
        let mut cfg = EnsembleConfig::default();
        assert!(cfg.apply_toml(&doc).is_err());

        let json = Json::parse(r#"{"members": []}"#).unwrap();
        assert!(EnsembleConfig::from_json(&json).is_err());

        // Mistyped entries are rejected, not skipped.
        let json = Json::parse(r#"{"members": [42]}"#).unwrap();
        assert!(EnsembleConfig::from_json(&json).is_err());
    }

    #[test]
    fn defaults_are_a_valid_heterogeneous_trio() {
        let cfg = EnsembleConfig::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.members.len(), 3);
        let kinds: Vec<MemberKind> =
            cfg.members.iter().map(|m| m.kind).collect();
        assert!(kinds.contains(&MemberKind::TedaSoftware));
        assert!(kinds.contains(&MemberKind::MSigma));
        assert!(kinds.contains(&MemberKind::ZScore));
    }
}

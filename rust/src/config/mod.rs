//! Configuration surface: JSON + TOML-subset parsers and the typed
//! service configuration.
//!
//! In-repo stand-ins for `serde_json` / `toml` (no crates.io in this
//! build environment, DESIGN.md §3).

pub mod ensemble;
pub mod json;
pub mod service;
pub mod toml;

pub use ensemble::{CombinerKind, EnsembleConfig, MemberKind, MemberSpec};
pub use json::Json;
pub use service::{
    ClusterConfig, EngineKind, ObsConfig, ServiceConfig, ShardingConfig,
};
pub use toml::TomlDoc;

//! Artifact manifest — the contract with `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::config::Json;
use crate::{Error, Result};

/// Shape + dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Artifact("tensor spec missing name".into()))?
            .to_string();
        let dtype = v
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Artifact(format!("{name}: missing dtype")))?
            .to_string();
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact(format!("{name}: missing shape")))?
            .iter()
            .map(|d| {
                d.as_usize().ok_or_else(|| {
                    Error::Artifact(format!("{name}: non-integer dim"))
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name, dtype, shape })
    }
}

/// One AOT-compiled (S, N, T, m) model variant.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantSpec {
    pub name: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Streams per batch.
    pub s: usize,
    /// Features per sample.
    pub n: usize,
    /// Time steps per chunk.
    pub t: usize,
    /// Chebyshev multiplier baked into the artifact.
    pub m: f64,
    /// Pallas stream-block size (S is a multiple of this).
    pub block_s: usize,
    /// Which kernel produced it ("pallas" or "jnp_ref").
    pub kernel: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl VariantSpec {
    fn from_json(v: &Json) -> Result<VariantSpec> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Artifact("variant missing name".into()))?
            .to_string();
        let need_usize = |key: &str| {
            v.get(key).and_then(Json::as_usize).ok_or_else(|| {
                Error::Artifact(format!("variant {name}: missing {key}"))
            })
        };
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| {
                    Error::Artifact(format!("variant {name}: missing {key}"))
                })?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(VariantSpec {
            file: v
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    Error::Artifact(format!("variant {name}: missing file"))
                })?
                .to_string(),
            s: need_usize("s")?,
            n: need_usize("n")?,
            t: need_usize("t")?,
            m: v
                .get("m")
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Artifact(format!("{name}: missing m")))?,
            block_s: need_usize("block_s")?,
            kernel: v
                .get("kernel")
                .and_then(Json::as_str)
                .unwrap_or("pallas")
                .to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            name,
        })
    }

    /// Samples classified per execution (S·T).
    pub fn samples_per_chunk(&self) -> usize {
        self.s * self.t
    }
}

/// Parsed `artifacts/manifest.json` plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub jax_version: String,
    pub variants: Vec<VariantSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(format!("reading {}", path.display()), e))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (directory recorded for artifact paths).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let v = Json::parse(text)
            .map_err(|e| Error::Artifact(format!("manifest: {e}")))?;
        match v.get("format").and_then(Json::as_u64) {
            Some(1) => {}
            other => {
                return Err(Error::Artifact(format!(
                    "unsupported manifest format {other:?}"
                )))
            }
        }
        if v.get("interchange").and_then(Json::as_str) != Some("hlo-text") {
            return Err(Error::Artifact(
                "manifest interchange is not hlo-text".into(),
            ));
        }
        let variants = v
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact("manifest missing variants".into()))?
            .iter()
            .map(VariantSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir,
            jax_version: v
                .get("jax_version")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            variants,
        })
    }

    /// Find a variant by name.
    pub fn variant(&self, name: &str) -> Option<&VariantSpec> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Smallest pallas variant matching `n` features whose S·T capacity is
    /// ≥ `min_samples` — the batcher's variant-selection policy. Falls
    /// back to the largest matching variant when none is big enough.
    pub fn select(&self, n: usize, min_samples: usize) -> Option<&VariantSpec> {
        let mut matching: Vec<&VariantSpec> = self
            .variants
            .iter()
            .filter(|v| v.n == n && v.kernel == "pallas")
            .collect();
        matching.sort_by_key(|v| v.samples_per_chunk());
        matching
            .iter()
            .find(|v| v.samples_per_chunk() >= min_samples)
            .copied()
            .or_else(|| matching.last().copied())
    }

    /// Absolute path to a variant's HLO file.
    pub fn hlo_path(&self, v: &VariantSpec) -> PathBuf {
        self.dir.join(&v.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> String {
        r#"{
          "format": 1,
          "interchange": "hlo-text",
          "jax_version": "0.8.2",
          "variants": [
            {"name": "teda_s8_n2_t16_m3p0", "file": "a.hlo.txt",
             "s": 8, "n": 2, "t": 16, "m": 3.0, "block_s": 8,
             "kernel": "pallas",
             "inputs": [{"name": "mu", "dtype": "f32", "shape": [8, 2]}],
             "outputs": [{"name": "ecc", "dtype": "f32", "shape": [8, 16]}]},
            {"name": "teda_s32_n2_t32_m3p0", "file": "b.hlo.txt",
             "s": 32, "n": 2, "t": 32, "m": 3.0, "block_s": 8,
             "kernel": "pallas",
             "inputs": [], "outputs": []}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(&sample_manifest(), PathBuf::from("/a")).unwrap();
        assert_eq!(m.variants.len(), 2);
        let v = m.variant("teda_s8_n2_t16_m3p0").unwrap();
        assert_eq!((v.s, v.n, v.t), (8, 2, 16));
        assert_eq!(v.inputs[0].elements(), 16);
        assert_eq!(m.hlo_path(v), PathBuf::from("/a/a.hlo.txt"));
    }

    #[test]
    fn select_prefers_smallest_sufficient() {
        let m = Manifest::parse(&sample_manifest(), PathBuf::from("/a")).unwrap();
        assert_eq!(m.select(2, 100).unwrap().s, 8); // 8*16=128 >= 100
        assert_eq!(m.select(2, 200).unwrap().s, 32); // needs the big one
        assert_eq!(m.select(2, 99999).unwrap().s, 32); // fallback: largest
        assert!(m.select(7, 1).is_none()); // no such N
    }

    #[test]
    fn rejects_bad_format() {
        let text = r#"{"format": 9, "interchange": "hlo-text", "variants": []}"#;
        assert!(Manifest::parse(text, PathBuf::from(".")).is_err());
    }

    #[test]
    fn rejects_wrong_interchange() {
        let text = r#"{"format": 1, "interchange": "proto", "variants": []}"#;
        assert!(Manifest::parse(text, PathBuf::from(".")).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Uses the actual artifacts/ when present (after `make artifacts`).
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(!m.variants.is_empty());
            for v in &m.variants {
                assert!(m.hlo_path(v).exists(), "{} missing", v.file);
                assert_eq!(v.inputs.len(), 4);
                assert_eq!(v.outputs.len(), 6);
            }
        }
    }
}

//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the `xla` crate is touched. The flow
//! (per /opt/xla-example and DESIGN.md §3):
//!
//! ```text
//! artifacts/manifest.json ── runtime::Manifest
//! artifacts/<variant>.hlo.txt ── HloModuleProto::from_text_file
//!                                → XlaComputation → client.compile
//!                                → PjRtLoadedExecutable (cached)
//! ```
//!
//! Python/JAX is *never* on this path — artifacts are produced once by
//! `make artifacts` and the Rust binary is self-contained afterwards.

mod client;
mod manifest;
pub mod xla_stub;

pub use client::{Executable, XlaRuntime};
pub use manifest::{Manifest, TensorSpec, VariantSpec};

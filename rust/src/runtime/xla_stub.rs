//! Compile-time stand-in for the `xla` PJRT bindings.
//!
//! This build environment has no crates.io registry, so the real
//! `xla` crate (PJRT CPU client + HLO compilation) cannot be linked.
//! This module mirrors exactly the API surface `runtime::client` uses,
//! with [`PjRtClient::cpu`] failing fast at runtime — so the crate
//! builds and every non-XLA path (software, RTL, ensemble engines) is
//! fully functional, while the XLA engine reports a clear error instead
//! of a link failure. Swapping the real bindings back in is a one-line
//! import change in `runtime::client`.

/// Error mirroring `xla::Error`: a message, `Display`-able.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "PJRT unavailable: built against runtime::xla_stub (no `xla` \
         crate in this environment); use the software/rtl/ensemble \
         engines instead"
            .to_string(),
    ))
}

/// Host literal (stub): never actually constructed with data at runtime
/// because [`PjRtClient::cpu`] fails first.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Rank-1 f32 literal.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    /// Tuple literal → element literals.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    /// Literal contents as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}

/// Device buffer handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy device buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text artifact file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled, device-loaded executable (stub).
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host inputs; `Vec<Vec<PjRtBuffer>>` mirrors the real
    /// bindings' per-device × per-output result shape.
    pub fn execute<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// PJRT client (stub): construction is the single failure point.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client — always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    /// Platform label.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn literal_paths_fail_not_panic() {
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
        assert!(Literal.to_vec::<f32>().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}

//! PJRT client wrapper: compile HLO text once, execute many times.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::{Error, Result};

// The stub mirrors the real `xla` bindings' API; swap this import for
// `use xla;` when building in an environment that has the crate.
use super::manifest::{Manifest, VariantSpec};
use super::xla_stub as xla;

/// A compiled executable plus its manifest spec.
pub struct Executable {
    spec: VariantSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// The manifest spec this executable was compiled from.
    pub fn spec(&self) -> &VariantSpec {
        &self.spec
    }

    /// Execute with f32 inputs in manifest order; returns f32 outputs in
    /// manifest order.
    ///
    /// Input lengths are validated against the manifest shapes; outputs
    /// are length-validated before returning.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, ispec) in inputs.iter().zip(&self.spec.inputs) {
            if data.len() != ispec.elements() {
                return Err(Error::Runtime(format!(
                    "{}: input '{}' expects {} elements, got {}",
                    self.spec.name,
                    ispec.name,
                    ispec.elements(),
                    data.len()
                )));
            }
            let dims: Vec<i64> = ispec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| Error::Runtime(format!("reshape: {e}")))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("to_tuple: {e}")))?;
        if tuple.len() != self.spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                tuple.len()
            )));
        }
        let mut outs = Vec::with_capacity(tuple.len());
        for (lit, ospec) in tuple.into_iter().zip(&self.spec.outputs) {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
            if v.len() != ospec.elements() {
                return Err(Error::Runtime(format!(
                    "{}: output '{}' expects {} elements, got {}",
                    self.spec.name,
                    ospec.name,
                    ospec.elements(),
                    v.len()
                )));
            }
            outs.push(v);
        }
        Ok(outs)
    }
}

/// PJRT CPU client + executable cache keyed by variant name.
///
/// `XlaRuntime` is `Send + Sync` (inner mutability behind a mutex) so
/// engines on worker threads can share one client; PJRT compilation
/// happens at most once per variant.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and load the manifest from `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(XlaRuntime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// The loaded manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string (for logs / doctor output).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) a variant by name.
    pub fn load(&self, variant: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(variant) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .variant(variant)
            .ok_or_else(|| {
                Error::Artifact(format!("variant '{variant}' not in manifest"))
            })?
            .clone();
        let path = self.manifest.hlo_path(&spec);
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::Artifact("non-utf8 path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| Error::Artifact(format!("parse {path_str}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {variant}: {e}")))?;
        let exe = Arc::new(Executable { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(variant.to_string(), exe.clone());
        Ok(exe)
    }

    /// Compile every pallas variant up front (service warm start).
    pub fn load_all(&self) -> Result<Vec<Arc<Executable>>> {
        let names: Vec<String> =
            self.manifest.variants.iter().map(|v| v.name.clone()).collect();
        names.iter().map(|n| self.load(n)).collect()
    }
}

// NOTE on threading: the `xla` crate's client wraps an `Rc` internally,
// so `XlaRuntime`/`Executable` are deliberately NOT Send/Sync. The
// coordinator gives each worker thread its own runtime instance
// (constructed inside the thread — see coordinator::service), which is
// also what PJRT recommends for CPU clients.

//! Minimal criterion-style bench harness (no crates.io in this build
//! environment, so `criterion` is replaced by this module; benches are
//! declared with `harness = false` and call [`Bench::run`]).
//!
//! Method: warmup, then fixed-count timed iterations, reporting
//! min / p50 / mean / p95 / max per-iteration wall time plus derived
//! throughput. A `black_box` re-export prevents the optimizer from
//! deleting the measured work.

use std::time::{Duration, Instant};

/// Re-export of the standard black box.
pub use std::hint::black_box;

/// One benchmark's configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Report label.
    pub name: String,
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Number of timed iterations.
    pub iters: usize,
    /// Work units per iteration (samples, cycles...) for throughput lines.
    pub units_per_iter: u64,
    /// Name of the unit for the throughput line (e.g. "samples").
    pub unit: &'static str,
}

/// Result of a bench run.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub p50: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub max: Duration,
    /// Units processed per second, from the mean iteration time.
    pub throughput: f64,
    pub unit: &'static str,
    /// Per-unit latency from the mean (ns).
    pub ns_per_unit: f64,
}

impl Bench {
    /// New bench with sane defaults: 0.3 s warmup, 50 iterations, 1 unit.
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: Duration::from_millis(300),
            iters: 50,
            units_per_iter: 1,
            unit: "iter",
        }
    }

    /// Builder: timed iteration count.
    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n.max(1);
        self
    }

    /// Builder: warmup budget.
    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    /// Builder: declare throughput units.
    pub fn units(mut self, per_iter: u64, unit: &'static str) -> Self {
        self.units_per_iter = per_iter;
        self.unit = unit;
        self
    }

    /// Run `f` (one call = one iteration) and print + return the report.
    pub fn run<F: FnMut()>(self, mut f: F) -> BenchReport {
        // Warmup until the budget is spent (at least one call).
        let wstart = Instant::now();
        loop {
            f();
            if wstart.elapsed() >= self.warmup {
                break;
            }
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort_unstable();
        let total: Duration = times.iter().sum();
        let mean = total / self.iters as u32;
        let pct = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
        let ns_per_unit =
            mean.as_nanos() as f64 / self.units_per_iter.max(1) as f64;
        let report = BenchReport {
            name: self.name,
            iters: self.iters,
            min: times[0],
            p50: pct(0.50),
            mean,
            p95: pct(0.95),
            max: *times.last().unwrap(),
            throughput: 1e9 / ns_per_unit * 1.0,
            unit: self.unit,
            ns_per_unit,
        };
        println!("{report}");
        report
    }
}

impl std::fmt::Display for BenchReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} iters={:<4} min={:>10.3?} p50={:>10.3?} mean={:>10.3?} p95={:>10.3?} | {:>12.1} {}/s ({:.1} ns/{})",
            self.name,
            self.iters,
            self.min,
            self.p50,
            self.mean,
            self.p95,
            self.throughput,
            self.unit,
            self.ns_per_unit,
            self.unit,
        )
    }
}

/// Format a nanosecond quantity with an adaptive unit (for tables).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_percentiles() {
        let r = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .iters(20)
            .units(100, "ops")
            .run(|| {
                black_box((0..100).sum::<u64>());
            });
        assert!(r.min <= r.p50 && r.p50 <= r.p95 && r.p95 <= r.max);
        assert!(r.throughput > 0.0);
        assert_eq!(r.unit, "ops");
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(12e9).ends_with(" s"));
    }
}

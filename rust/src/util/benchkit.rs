//! Minimal criterion-style bench harness (no crates.io in this build
//! environment, so `criterion` is replaced by this module; benches are
//! declared with `harness = false` and call [`Bench::run`]).
//!
//! Method: warmup, then fixed-count timed iterations, reporting
//! min / p50 / mean / p95 / max per-iteration wall time plus derived
//! throughput. A `black_box` re-export prevents the optimizer from
//! deleting the measured work.

use std::io;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::config::Json;

/// Re-export of the standard black box.
pub use std::hint::black_box;

/// One benchmark's configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Report label.
    pub name: String,
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Number of timed iterations.
    pub iters: usize,
    /// Work units per iteration (samples, cycles...) for throughput lines.
    pub units_per_iter: u64,
    /// Name of the unit for the throughput line (e.g. "samples").
    pub unit: &'static str,
}

/// Result of a bench run.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub p50: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub max: Duration,
    /// Units processed per second, from the mean iteration time.
    pub throughput: f64,
    pub unit: &'static str,
    /// Per-unit latency from the mean (ns).
    pub ns_per_unit: f64,
}

impl Bench {
    /// New bench with sane defaults: 0.3 s warmup, 50 iterations, 1 unit.
    pub fn new(name: impl Into<String>) -> Self {
        Bench {
            name: name.into(),
            warmup: Duration::from_millis(300),
            iters: 50,
            units_per_iter: 1,
            unit: "iter",
        }
    }

    /// Builder: timed iteration count.
    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n.max(1);
        self
    }

    /// Builder: warmup budget.
    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    /// Builder: declare throughput units.
    pub fn units(mut self, per_iter: u64, unit: &'static str) -> Self {
        self.units_per_iter = per_iter;
        self.unit = unit;
        self
    }

    /// Run `f` (one call = one iteration) and print + return the report.
    pub fn run<F: FnMut()>(self, mut f: F) -> BenchReport {
        // Warmup until the budget is spent (at least one call).
        let wstart = Instant::now();
        loop {
            f();
            if wstart.elapsed() >= self.warmup {
                break;
            }
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
        }
        times.sort_unstable();
        let total: Duration = times.iter().sum();
        let mean = total / self.iters as u32;
        let pct = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
        let ns_per_unit =
            mean.as_nanos() as f64 / self.units_per_iter.max(1) as f64;
        let report = BenchReport {
            name: self.name,
            iters: self.iters,
            min: times[0],
            p50: pct(0.50),
            mean,
            p95: pct(0.95),
            max: *times.last().unwrap(),
            throughput: 1e9 / ns_per_unit * 1.0,
            unit: self.unit,
            ns_per_unit,
        };
        println!("{report}");
        report
    }
}

impl std::fmt::Display for BenchReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} iters={:<4} min={:>10.3?} p50={:>10.3?} mean={:>10.3?} p95={:>10.3?} | {:>12.1} {}/s ({:.1} ns/{})",
            self.name,
            self.iters,
            self.min,
            self.p50,
            self.mean,
            self.p95,
            self.throughput,
            self.unit,
            self.ns_per_unit,
            self.unit,
        )
    }
}

/// Append one bench run's result document to the cumulative
/// `BENCH_trend.json` at the repository root, so per-PR performance
/// trajectory stays visible (ROADMAP follow-up).
///
/// The trend file is an object keyed by bench name, each holding an
/// append-only array of `{"run": N, "results": <doc>}` entries.
/// Appending the exact same document twice in a row is a no-op, which
/// makes `sync_trend` idempotent when a bench already self-appended.
/// Returns whether a new entry was written.
pub fn append_trend(
    repo_root: &Path,
    bench: &str,
    results: &Json,
) -> io::Result<bool> {
    let path = repo_root.join("BENCH_trend.json");
    let mut root = match std::fs::read_to_string(&path) {
        Ok(text) => Json::parse(&text).map_err(|e| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{}: {e}", path.display()),
            )
        })?,
        Err(e) if e.kind() == io::ErrorKind::NotFound => {
            Json::Obj(Default::default())
        }
        Err(e) => return Err(e),
    };
    let Json::Obj(map) = &mut root else {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("{} is not a JSON object", path.display()),
        ));
    };
    let runs = map
        .entry(bench.to_string())
        .or_insert_with(|| Json::Arr(Vec::new()));
    let Json::Arr(runs) = runs else {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("trend entry '{bench}' is not an array"),
        ));
    };
    if runs.last().and_then(|e| e.get("results")) == Some(results) {
        return Ok(false); // identical re-run: keep the file stable
    }
    let mut entry = std::collections::BTreeMap::new();
    entry.insert("run".to_string(), Json::Num((runs.len() + 1) as f64));
    entry.insert("results".to_string(), results.clone());
    runs.push(Json::Obj(entry));
    write_atomic(&path, &(root.to_string_compact() + "\n"))?;
    Ok(true)
}

/// Fold every `BENCH_*.json` at the repository root (except the trend
/// file itself) into `BENCH_trend.json`. Returns the bench names that
/// gained a new entry — the `teda-fpga bench-trend` subcommand CI runs
/// after its bench step.
pub fn sync_trend(repo_root: &Path) -> io::Result<Vec<String>> {
    let mut names: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(repo_root)? {
        let path = entry?.path();
        let Some(fname) = path.file_name().and_then(|f| f.to_str()) else {
            continue;
        };
        let Some(bench) = fname
            .strip_prefix("BENCH_")
            .and_then(|r| r.strip_suffix(".json"))
        else {
            continue;
        };
        if bench == "trend" {
            continue;
        }
        names.push(bench.to_string());
    }
    names.sort_unstable(); // deterministic append order
    let mut updated = Vec::new();
    for bench in names {
        let path = repo_root.join(format!("BENCH_{bench}.json"));
        let text = std::fs::read_to_string(&path)?;
        let doc = Json::parse(&text).map_err(|e| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("BENCH_{bench}.json: {e}"),
            )
        })?;
        if append_trend(repo_root, &bench, &doc)? {
            updated.push(bench);
        }
    }
    Ok(updated)
}

/// Write-temp-then-rename so a crash mid-write never truncates the
/// cumulative history. (A sibling of `persist::file`'s checkpoint
/// writer; kept separate because that one lives in the crate-`Error`
/// domain with store-specific temp naming, while this is plain
/// `io::Result` for a dev-tooling file.)
fn write_atomic(path: &Path, text: &str) -> io::Result<()> {
    let tmp: PathBuf = path.with_extension("json.tmp");
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)
}

/// Format a nanosecond quantity with an adaptive unit (for tables).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_percentiles() {
        let r = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .iters(20)
            .units(100, "ops")
            .run(|| {
                black_box((0..100).sum::<u64>());
            });
        assert!(r.min <= r.p50 && r.p50 <= r.p95 && r.p95 <= r.max);
        assert!(r.throughput > 0.0);
        assert_eq!(r.unit, "ops");
    }

    #[test]
    fn trend_appends_and_dedupes() {
        let root = crate::util::unique_temp_dir("benchkit-trend");
        std::fs::create_dir_all(&root).unwrap();
        let doc = Json::parse(r#"{"bench":"x","results":[{"ns":1}]}"#)
            .unwrap();
        assert!(append_trend(&root, "x", &doc).unwrap());
        // Identical re-append is a no-op...
        assert!(!append_trend(&root, "x", &doc).unwrap());
        // ...a changed run appends with the next run index.
        let doc2 = Json::parse(r#"{"bench":"x","results":[{"ns":2}]}"#)
            .unwrap();
        assert!(append_trend(&root, "x", &doc2).unwrap());
        let trend = Json::parse(
            &std::fs::read_to_string(root.join("BENCH_trend.json")).unwrap(),
        )
        .unwrap();
        let runs = trend.get("x").and_then(Json::as_arr).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].get("run").and_then(Json::as_u64), Some(1));
        assert_eq!(runs[1].get("run").and_then(Json::as_u64), Some(2));
        assert_eq!(runs[1].get("results"), Some(&doc2));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn sync_trend_folds_bench_files() {
        let root = crate::util::unique_temp_dir("benchkit-sync");
        std::fs::create_dir_all(&root).unwrap();
        std::fs::write(root.join("BENCH_alpha.json"), r#"{"a":1}"#).unwrap();
        std::fs::write(root.join("BENCH_beta.json"), r#"{"b":2}"#).unwrap();
        std::fs::write(root.join("unrelated.txt"), "x").unwrap();
        let updated = sync_trend(&root).unwrap();
        assert_eq!(updated, vec!["alpha".to_string(), "beta".to_string()]);
        // Re-sync without new results: nothing appended, trend file
        // itself is skipped as an input.
        assert!(sync_trend(&root).unwrap().is_empty());
        let trend = Json::parse(
            &std::fs::read_to_string(root.join("BENCH_trend.json")).unwrap(),
        )
        .unwrap();
        assert!(trend.get("alpha").is_some());
        assert!(trend.get("beta").is_some());
        assert!(trend.get("trend").is_none());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(12e9).ends_with(" s"));
    }
}

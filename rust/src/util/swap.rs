//! Hand-rolled arc-swap: a lock-free read-mostly cell for immutable
//! snapshot values (the crate is dependency-free, so the usual
//! `arc_swap` crate is replaced by this module — DESIGN.md §3).
//!
//! Readers take [`Swap::load`] — **one atomic pointer load**, no
//! reference counting, no lock — and get a `&T` valid for the lifetime
//! of their borrow of the `Swap`. That is sound because every value
//! ever installed is retained (an `Arc<T>` kept in a writer-side vec)
//! until the `Swap` itself drops; a pointer read from `current` can
//! therefore never dangle, even if a writer installs a successor one
//! nanosecond later.
//!
//! The deliberate trade-off: memory for retired values is not reclaimed
//! until the owner drops. The coordinator installs a new routing table
//! per migration epoch — tens of entries over a service lifetime, each
//! a few hundred bytes — so bounded retention is far cheaper than the
//! hazard-pointer or epoch-GC machinery real reclamation would need.
//!
//! Writers serialize on the retention mutex ([`Swap::rcu`]), which also
//! gives read-modify-write installs (epoch checks) atomicity. The hot
//! path never touches that mutex.

use std::sync::atomic::{AtomicPtr, Ordering};
use std::sync::{Arc, Mutex};

/// Lock-free snapshot cell. See the module docs for the retention
/// contract that makes [`Swap::load`] safe.
#[derive(Debug)]
pub struct Swap<T> {
    /// Always points at the payload of the last `Arc<T>` in `retained`.
    current: AtomicPtr<T>,
    /// Every value ever installed, oldest first. Never popped until
    /// drop — this is what keeps `current` dereferenceable.
    retained: Mutex<Vec<Arc<T>>>,
}

impl<T> Swap<T> {
    pub fn new(initial: Arc<T>) -> Self {
        let ptr = Arc::as_ptr(&initial) as *mut T;
        Swap {
            current: AtomicPtr::new(ptr),
            retained: Mutex::new(vec![initial]),
        }
    }

    /// The current value: a single `Acquire` pointer load. The borrow
    /// stays valid (and readable) across concurrent installs — it is
    /// merely *detectably stale* once a successor lands.
    #[inline]
    pub fn load(&self) -> &T {
        // SAFETY: the pointer was produced by `Arc::as_ptr` on a value
        // held in `retained`, which is append-only until `self` drops,
        // and the returned borrow cannot outlive `self`.
        unsafe { &*self.current.load(Ordering::Acquire) }
    }

    /// An owned handle to the current value — still lock-free (one
    /// pointer load plus a refcount bump), for callers that must hold
    /// the snapshot beyond a borrow of the `Swap`.
    pub fn snapshot(&self) -> Arc<T> {
        let ptr = self.current.load(Ordering::Acquire);
        // SAFETY: `ptr` designates a live Arc payload (retention
        // contract above), so bumping its strong count and rebuilding
        // an Arc is the documented `increment_strong_count`/`from_raw`
        // round trip.
        unsafe {
            Arc::increment_strong_count(ptr);
            Arc::from_raw(ptr)
        }
    }

    /// Read-modify-write install under the writer lock: `f` sees the
    /// current value and returns its successor (or an error to abort
    /// with nothing changed). Readers switch atomically; the previous
    /// value stays retained.
    pub fn rcu<E, F>(&self, f: F) -> Result<Arc<T>, E>
    where
        F: FnOnce(&T) -> Result<Arc<T>, E>,
    {
        let mut retained = self.retained.lock().unwrap();
        let cur = retained.last().expect("swap retention never empty");
        let next = f(cur)?;
        retained.push(next.clone());
        self.current
            .store(Arc::as_ptr(&next) as *mut T, Ordering::Release);
        Ok(next)
    }

    /// Unconditional install (an `rcu` that cannot fail).
    pub fn store(&self, next: Arc<T>) {
        let _ = self.rcu::<std::convert::Infallible, _>(|_| Ok(next));
    }

    /// How many values are currently retained (diagnostics/tests).
    pub fn retained_len(&self) -> usize {
        self.retained.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::thread;

    #[test]
    fn load_and_snapshot_follow_installs() {
        let s = Swap::new(Arc::new(1u64));
        assert_eq!(*s.load(), 1);
        s.store(Arc::new(2));
        assert_eq!(*s.load(), 2);
        assert_eq!(*s.snapshot(), 2);
        assert_eq!(s.retained_len(), 2);
    }

    #[test]
    fn stale_borrow_stays_readable_and_detectable() {
        let s = Swap::new(Arc::new(10u64));
        let before = s.load();
        s.store(Arc::new(20));
        // The old borrow is still valid (retention) but lags.
        assert_eq!(*before, 10);
        assert_eq!(*s.load(), 20);
    }

    #[test]
    fn rcu_error_installs_nothing() {
        let s = Swap::new(Arc::new(5u64));
        let r: Result<_, &str> = s.rcu(|_| Err("nope"));
        assert!(r.is_err());
        assert_eq!(*s.load(), 5);
        assert_eq!(s.retained_len(), 1);
    }

    #[test]
    fn concurrent_readers_see_monotone_values() {
        // Writer installs 0..N ascending; every reader must observe a
        // non-decreasing sequence (a torn or dangling read would show
        // up as garbage or regression).
        let s = Arc::new(Swap::new(Arc::new(0u64)));
        let done = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                let done = done.clone();
                thread::spawn(move || {
                    let mut last = 0u64;
                    while !done.load(Ordering::Relaxed) {
                        let v = *s.load();
                        assert!(v >= last, "regressed {last} -> {v}");
                        last = v;
                    }
                })
            })
            .collect();
        for i in 1..=1000u64 {
            s.store(Arc::new(i));
        }
        done.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(*s.load(), 1000);
    }
}

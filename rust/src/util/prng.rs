//! Deterministic PRNGs: SplitMix64 and PCG32.
//!
//! Every stochastic component in the repo (DAMADICS noise, workload
//! generators, property sweeps) takes an explicit seed so that tests,
//! figures and benches are reproducible run-to-run.

/// SplitMix64 — tiny, fast, excellent for seeding and test sweeps.
/// Reference: Steele, Lea & Flood, "Fast Splittable Pseudorandom Number
/// Generators" (the `splitmix64` finalizer).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a seed. All 2^64 seeds are valid.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (n > 0), via Lemire-style rejection-free
    /// multiply-shift (bias < 2^-32 for the sizes used here).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — the hot paths never draw normals).
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0) by nudging u1 away from zero.
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with explicit mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Split off an independently-seeded child generator.
    pub fn split(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

/// PCG32 (XSH-RR 64/32) — the repo's general-purpose generator when a
/// stream of 32-bit values is preferred (e.g. f32 sample synthesis).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6_364_136_223_846_793_005;

    /// Create from `(seed, stream)`; distinct streams are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut pcg = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        pcg.next_u32();
        pcg.state = pcg.state.wrapping_add(seed);
        pcg.next_u32();
        pcg
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(123);
        let mut b = SplitMix64::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // First outputs for seed 0 (cross-checked against the reference C
        // implementation of splitmix64).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = SplitMix64::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = SplitMix64::new(13);
        let n = 20_000;
        let draws: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean: f64 = draws.iter().sum::<f64>() / n as f64;
        let var: f64 =
            draws.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(1, 0);
        let mut b = Pcg32::new(1, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_children_independent() {
        let mut parent = SplitMix64::new(77);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}

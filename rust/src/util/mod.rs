//! Support kit: deterministic PRNGs, the bench harness, and the
//! property-test sweep helper.
//!
//! The build environment has no crates.io access, so the usual suspects
//! (`rand`, `criterion`, `proptest`) are replaced by small, auditable
//! in-repo equivalents (see DESIGN.md §3 "No-network substitutions").

pub mod benchkit;
pub mod prng;
pub mod propkit;

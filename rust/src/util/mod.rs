//! Support kit: deterministic PRNGs, the bench harness, and the
//! property-test sweep helper.
//!
//! The build environment has no crates.io access, so the usual suspects
//! (`rand`, `criterion`, `proptest`) are replaced by small, auditable
//! in-repo equivalents (see DESIGN.md §3 "No-network substitutions").

pub mod benchkit;
pub mod prng;
pub mod propkit;
pub mod swap;

/// A unique, not-yet-created directory under the system temp dir —
/// shared by the persistence tests and benches so the uniqueness
/// scheme (tag + pid + wall-clock nanos) lives in exactly one place.
/// The caller owns the directory's lifecycle (creation and cleanup).
pub fn unique_temp_dir(tag: &str) -> std::path::PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .expect("system clock before 1970")
        .as_nanos();
    std::env::temp_dir().join(format!(
        "teda-{tag}-{}-{nanos}",
        std::process::id()
    ))
}

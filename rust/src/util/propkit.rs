//! Property-test sweep helper (in-repo stand-in for `proptest`; see
//! DESIGN.md §3).
//!
//! A property is a closure over a [`Gen`] (a seeded value source). The
//! runner executes it for `cases` distinct seeds; a failing case panics
//! with its seed so the exact input is reproducible with
//! `Gen::from_seed(seed)`. No shrinking — generated inputs are kept small
//! and the seed is enough to debug.

use super::prng::SplitMix64;

/// Seeded value source handed to each property case.
pub struct Gen {
    rng: SplitMix64,
    seed: u64,
}

impl Gen {
    /// Rebuild the generator a failing case printed.
    pub fn from_seed(seed: u64) -> Self {
        Gen { rng: SplitMix64::new(seed), seed }
    }

    /// The case's seed (printed on failure).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform u64 below `n`.
    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    /// Bernoulli(p).
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Vector of uniform f64 samples.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// A batch of `count` N-dimensional samples (uniform in `[lo, hi)`).
    pub fn samples(
        &mut self,
        count: usize,
        n: usize,
        lo: f64,
        hi: f64,
    ) -> Vec<Vec<f64>> {
        (0..count).map(|_| self.vec_f64(n, lo, hi)).collect()
    }

    /// Standard normal draw.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Access to the raw RNG for anything not covered above.
    pub fn rng(&mut self) -> &mut SplitMix64 {
        &mut self.rng
    }
}

/// Run `property` for `cases` seeded cases. Panics (with the seed) on the
/// first failing case.
///
/// ```
/// use teda_fpga::util::propkit::forall;
/// forall("abs is non-negative", 64, |g| {
///     let x = g.f64_in(-10.0, 10.0);
///     assert!(x.abs() >= 0.0);
/// });
/// ```
pub fn forall<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut property: F) {
    // Derive case seeds from the property name so distinct properties
    // explore distinct inputs, deterministically across runs.
    let mut root = SplitMix64::new(fnv1a(name.as_bytes()));
    for case in 0..cases {
        let seed = root.next_u64();
        let mut gen = Gen::from_seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || property(&mut gen),
        ));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (reproduce with Gen::from_seed({seed})): {msg}"
            );
        }
    }
}

/// FNV-1a 64-bit hash (stable across runs/platforms, unlike `DefaultHasher`).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially_true_property() {
        forall("sum of squares non-negative", 32, |g| {
            let v = g.vec_f64(8, -3.0, 3.0);
            assert!(v.iter().map(|x| x * x).sum::<f64>() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with Gen::from_seed")]
    fn forall_reports_seed_on_failure() {
        forall("always fails", 4, |_| panic!("boom"));
    }

    #[test]
    fn usize_in_is_inclusive() {
        let mut g = Gen::from_seed(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = g.usize_in(2, 5);
            assert!((2..=5).contains(&v));
            seen_lo |= v == 2;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn fnv1a_distinct_names() {
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }
}

//! Classical running m·σ detector (the paper's "traditional" baseline).

use super::AnomalyDetector;

/// Running per-feature mean/variance with the m·σ flag rule.
///
/// This is the textbook method the paper contrasts TEDA against (§3):
/// it assumes the data distribution (Gaussian for the usual m=3
/// coverage guarantee) and compares each point to the *global* mean —
/// precisely the punctual/local information loss §1 criticises.
#[derive(Debug, Clone, PartialEq)]
pub struct MSigmaDetector {
    m: f64,
    k: u64,
    mean: Vec<f64>,
    m2: Vec<f64>, // Welford sum of squared deviations per feature
}

impl MSigmaDetector {
    /// New detector over `n_features` dims flagging at `m` sigmas.
    pub fn new(n_features: usize, m: f64) -> Self {
        assert!(n_features > 0 && m > 0.0);
        MSigmaDetector {
            m,
            k: 0,
            mean: vec![0.0; n_features],
            m2: vec![0.0; n_features],
        }
    }

    /// Samples absorbed.
    pub fn k(&self) -> u64 {
        self.k
    }

    /// Raw state `(m, k, mean, m2)` for the persistence codec.
    pub fn parts(&self) -> (f64, u64, &[f64], &[f64]) {
        (self.m, self.k, &self.mean, &self.m2)
    }

    /// Rebuild from raw parts (the codec's decode path). Returns
    /// `None` when the parts are inconsistent — corrupt input must
    /// become an error, not a detector with impossible state.
    pub fn from_parts(
        m: f64,
        k: u64,
        mean: Vec<f64>,
        m2: Vec<f64>,
    ) -> Option<Self> {
        if !(m > 0.0) || mean.is_empty() || mean.len() != m2.len() {
            return None;
        }
        Some(MSigmaDetector { m, k, mean, m2 })
    }

    /// Per-feature standard deviation estimate.
    pub fn sigma(&self) -> Vec<f64> {
        if self.k < 2 {
            return vec![0.0; self.mean.len()];
        }
        self.m2.iter().map(|&s| (s / self.k as f64).sqrt()).collect()
    }
}

impl AnomalyDetector for MSigmaDetector {
    fn step(&mut self, x: &[f64]) -> bool {
        assert_eq!(x.len(), self.mean.len());
        self.k += 1;
        let kf = self.k as f64;
        let mut flagged = false;
        for i in 0..x.len() {
            // Flag BEFORE absorbing (otherwise a gross outlier drags the
            // stats toward itself first).
            if self.k > 2 {
                let sigma = (self.m2[i] / (kf - 1.0)).sqrt();
                if sigma > 0.0 && (x[i] - self.mean[i]).abs() > self.m * sigma {
                    flagged = true;
                }
            }
            // Welford update.
            let delta = x[i] - self.mean[i];
            self.mean[i] += delta / kf;
            self.m2[i] += delta * (x[i] - self.mean[i]);
        }
        flagged
    }

    fn name(&self) -> &'static str {
        "m-sigma"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::SplitMix64;

    #[test]
    fn flags_gross_outlier() {
        let mut det = MSigmaDetector::new(1, 3.0);
        let mut rng = SplitMix64::new(1);
        for _ in 0..500 {
            assert!(!det.step(&[rng.normal()]) || true);
        }
        assert!(det.step(&[100.0]));
        assert_eq!(det.k(), 501);
    }

    #[test]
    fn gaussian_false_positive_rate_near_3sigma_expectation() {
        // ~0.27% of N(0,1) exceeds 3σ; allow generous slack.
        let mut det = MSigmaDetector::new(1, 3.0);
        let mut rng = SplitMix64::new(2);
        let n = 50_000;
        let mut flags = 0;
        for _ in 0..n {
            if det.step(&[rng.normal()]) {
                flags += 1;
            }
        }
        let rate = flags as f64 / n as f64;
        assert!(rate > 0.0005 && rate < 0.01, "rate={rate}");
    }

    #[test]
    fn sigma_estimate_converges() {
        let mut det = MSigmaDetector::new(2, 3.0);
        let mut rng = SplitMix64::new(3);
        for _ in 0..20_000 {
            det.step(&[rng.normal() * 2.0, rng.normal() * 0.5]);
        }
        let s = det.sigma();
        assert!((s[0] - 2.0).abs() < 0.1, "s0={}", s[0]);
        assert!((s[1] - 0.5).abs() < 0.05, "s1={}", s[1]);
    }

    #[test]
    fn early_samples_never_flag() {
        let mut det = MSigmaDetector::new(1, 3.0);
        assert!(!det.step(&[5.0]));
        assert!(!det.step(&[-5.0]));
    }
}

//! Comparison baselines for the TEDA detector.
//!
//! The paper motivates TEDA against "traditional statistical methods"
//! (§1, §3): the m·σ rule, which presumes a Gaussian distribution and a
//! global mean, and windowed variants that regain locality at the price
//! of memory. Both are implemented here so the examples/benches can
//! reproduce the paper's framing (same Chebyshev-style `m`, same
//! streams):
//!
//! - [`MSigmaDetector`] — classical running m·σ rule (the paper's
//!   "traditional" strawman; recursive global mean/variance, flag when
//!   `|x − μ| > m·σ` on any feature).
//! - [`SlidingZScore`] — windowed z-score with an O(W) ring buffer, the
//!   common practical compromise TEDA's recursion avoids.

mod msigma;
mod zscore;

pub use msigma::MSigmaDetector;
pub use zscore::SlidingZScore;

/// Minimal trait shared by baselines so harnesses can sweep them.
pub trait AnomalyDetector {
    /// Absorb one sample, return `true` when flagged anomalous.
    fn step(&mut self, x: &[f64]) -> bool;

    /// Detector label for reports.
    fn name(&self) -> &'static str;
}

impl AnomalyDetector for crate::teda::TedaDetector {
    fn step(&mut self, x: &[f64]) -> bool {
        crate::teda::TedaDetector::step(self, x).outlier
    }

    fn name(&self) -> &'static str {
        "teda"
    }
}

//! Sliding-window z-score baseline.

use std::collections::VecDeque;

use super::AnomalyDetector;

/// Windowed z-score detector: flag when `|x − μ_W| > m·σ_W` over the
/// last `W` samples (per feature, any-feature-flags semantics).
///
/// Regains the locality the global m·σ rule lacks, but needs O(W·N)
/// memory and assumes a window length — the two costs TEDA's recursion
/// avoids (paper §1/§3).
#[derive(Debug, Clone, PartialEq)]
pub struct SlidingZScore {
    m: f64,
    window: usize,
    buf: VecDeque<Vec<f64>>,
    sum: Vec<f64>,
    sumsq: Vec<f64>,
}

impl SlidingZScore {
    /// New detector with window length `window` (≥ 2).
    pub fn new(n_features: usize, m: f64, window: usize) -> Self {
        assert!(n_features > 0 && m > 0.0 && window >= 2);
        SlidingZScore {
            m,
            window,
            buf: VecDeque::with_capacity(window + 1),
            sum: vec![0.0; n_features],
            sumsq: vec![0.0; n_features],
        }
    }

    /// Current fill level (≤ window).
    pub fn fill(&self) -> usize {
        self.buf.len()
    }

    /// Raw state `(m, window, buf, sum, sumsq)` for the persistence
    /// codec (buffer rows oldest-first).
    pub fn parts(&self) -> (f64, usize, &VecDeque<Vec<f64>>, &[f64], &[f64])
    {
        (self.m, self.window, &self.buf, &self.sum, &self.sumsq)
    }

    /// Rebuild from raw parts (the codec's decode path). Returns
    /// `None` when the parts are inconsistent — corrupt input must
    /// become an error, not a detector with impossible state.
    pub fn from_parts(
        m: f64,
        window: usize,
        buf: Vec<Vec<f64>>,
        sum: Vec<f64>,
        sumsq: Vec<f64>,
    ) -> Option<Self> {
        if !(m > 0.0)
            || window < 2
            || sum.is_empty()
            || sum.len() != sumsq.len()
            || buf.len() > window
            || buf.iter().any(|row| row.len() != sum.len())
        {
            return None;
        }
        Some(SlidingZScore {
            m,
            window,
            buf: buf.into(),
            sum,
            sumsq,
        })
    }
}

impl AnomalyDetector for SlidingZScore {
    fn step(&mut self, x: &[f64]) -> bool {
        assert_eq!(x.len(), self.sum.len());
        let mut flagged = false;
        let n = self.buf.len() as f64;
        if self.buf.len() >= 8 {
            for i in 0..x.len() {
                let mean = self.sum[i] / n;
                let var = (self.sumsq[i] / n - mean * mean).max(0.0);
                let sigma = var.sqrt();
                if sigma > 0.0 && (x[i] - mean).abs() > self.m * sigma {
                    flagged = true;
                }
            }
        }
        // Absorb.
        for i in 0..x.len() {
            self.sum[i] += x[i];
            self.sumsq[i] += x[i] * x[i];
        }
        self.buf.push_back(x.to_vec());
        if self.buf.len() > self.window {
            let old = self.buf.pop_front().unwrap();
            for i in 0..old.len() {
                self.sum[i] -= old[i];
                self.sumsq[i] -= old[i] * old[i];
            }
        }
        flagged
    }

    fn name(&self) -> &'static str {
        "sliding-zscore"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::SplitMix64;

    #[test]
    fn window_never_exceeds_capacity() {
        let mut det = SlidingZScore::new(1, 3.0, 16);
        let mut rng = SplitMix64::new(4);
        for _ in 0..100 {
            det.step(&[rng.normal()]);
            assert!(det.fill() <= 16);
        }
        assert_eq!(det.fill(), 16);
    }

    #[test]
    fn flags_spike_against_local_context() {
        let mut det = SlidingZScore::new(1, 3.0, 64);
        let mut rng = SplitMix64::new(5);
        for _ in 0..64 {
            det.step(&[rng.normal_with(0.0, 0.1)]);
        }
        assert!(det.step(&[5.0]));
    }

    #[test]
    fn adapts_to_level_shift_where_global_rule_would_not() {
        // After a regime change, the sliding window re-centers; samples
        // at the new level stop being flagged once the window refills.
        let mut det = SlidingZScore::new(1, 3.0, 32);
        let mut rng = SplitMix64::new(6);
        for _ in 0..64 {
            det.step(&[rng.normal_with(0.0, 0.1)]);
        }
        for _ in 0..64 {
            det.step(&[rng.normal_with(10.0, 0.1)]);
        }
        // Now firmly in the new regime: no flags.
        let mut flags = 0;
        for _ in 0..32 {
            if det.step(&[rng.normal_with(10.0, 0.1)]) {
                flags += 1;
            }
        }
        assert_eq!(flags, 0);
    }

    #[test]
    fn rolling_sums_match_recompute() {
        let mut det = SlidingZScore::new(2, 3.0, 8);
        let mut rng = SplitMix64::new(7);
        for _ in 0..50 {
            det.step(&[rng.normal(), rng.uniform(-1.0, 1.0)]);
            // recompute from buffer
            for i in 0..2 {
                let s: f64 = det.buf.iter().map(|v| v[i]).sum();
                assert!((s - det.sum[i]).abs() < 1e-9);
            }
        }
    }
}

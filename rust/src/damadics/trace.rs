//! Trace container + CSV I/O.

use std::io::Write as _;
use std::path::Path;

use crate::{Error, Result};

use super::faults::FaultEvent;

/// One generated run: samples, ground-truth labels, and the injected
/// fault (if any).
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// `samples[k]` is the observed feature vector at sample k.
    pub samples: Vec<Vec<f64>>,
    /// `labels[k]` is true when sample k lies in the fault window.
    pub labels: Vec<bool>,
    /// The injected fault event, if any.
    pub fault: Option<FaultEvent>,
}

impl Trace {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when the trace holds no samples.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Feature dimension (0 for an empty trace).
    pub fn n_features(&self) -> usize {
        self.samples.first().map(Vec::len).unwrap_or(0)
    }

    /// A sub-trace view `[start, end)` copied out (for windowed plots).
    pub fn slice(&self, start: usize, end: usize) -> Trace {
        let end = end.min(self.len());
        let start = start.min(end);
        Trace {
            samples: self.samples[start..end].to_vec(),
            labels: self.labels[start..end].to_vec(),
            fault: self.fault.clone(),
        }
    }

    /// Write as CSV: `k,x1..xN,label`.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let p = path.as_ref();
        if let Some(parent) = p.parent() {
            std::fs::create_dir_all(parent)
                .map_err(|e| Error::io(format!("mkdir {}", parent.display()), e))?;
        }
        let file = std::fs::File::create(p)
            .map_err(|e| Error::io(format!("create {}", p.display()), e))?;
        let mut w = std::io::BufWriter::new(file);
        let n = self.n_features();
        let header: Vec<String> =
            (1..=n).map(|i| format!("x{i}")).collect();
        writeln!(w, "k,{},label", header.join(","))
            .map_err(|e| Error::io("csv header", e))?;
        for (k, (s, &l)) in self.samples.iter().zip(&self.labels).enumerate() {
            let row: Vec<String> = s.iter().map(|v| format!("{v:.6}")).collect();
            writeln!(w, "{k},{},{}", row.join(","), l as u8)
                .map_err(|e| Error::io("csv row", e))?;
        }
        Ok(())
    }

    /// Read back a CSV written by [`Trace::write_csv`].
    pub fn read_csv(path: impl AsRef<Path>) -> Result<Trace> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error::io(format!("read {}", p.display()), e))?;
        let mut samples = Vec::new();
        let mut labels = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.is_empty() {
                continue; // header
            }
            let fields: Vec<&str> = line.split(',').collect();
            if fields.len() < 3 {
                return Err(Error::Stream(format!(
                    "csv line {i}: expected >=3 fields"
                )));
            }
            let feat = fields[1..fields.len() - 1]
                .iter()
                .map(|f| {
                    f.parse::<f64>().map_err(|e| {
                        Error::Stream(format!("csv line {i}: {e}"))
                    })
                })
                .collect::<Result<Vec<f64>>>()?;
            samples.push(feat);
            labels.push(fields[fields.len() - 1].trim() == "1");
        }
        Ok(Trace { samples, labels, fault: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Trace {
        Trace {
            samples: vec![vec![0.1, 0.2], vec![0.3, 0.4], vec![0.5, 0.6]],
            labels: vec![false, true, false],
            fault: None,
        }
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("teda_fpga_trace_test");
        let path = dir.join("t.csv");
        let t = tiny();
        t.write_csv(&path).unwrap();
        let back = Trace::read_csv(&path).unwrap();
        assert_eq!(back.labels, t.labels);
        assert_eq!(back.n_features(), 2);
        for (a, b) in back.samples.iter().flatten().zip(t.samples.iter().flatten())
        {
            assert!((a - b).abs() < 1e-6);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn slice_bounds_are_safe() {
        let t = tiny();
        assert_eq!(t.slice(1, 2).len(), 1);
        assert_eq!(t.slice(0, 99).len(), 3);
        assert_eq!(t.slice(5, 9).len(), 0);
        assert!(t.slice(2, 1).is_empty());
    }

    #[test]
    fn n_features_handles_empty() {
        let e = Trace { samples: vec![], labels: vec![], fault: None };
        assert_eq!(e.n_features(), 0);
        assert!(e.is_empty());
    }
}

//! Physics-flavoured actuator simulator (the DAMADICS substitution).
//!
//! Models the benchmark's actuator 1 — pneumatic servo-motor driving a
//! control valve with a positioner — at 1 Hz, one day = 86 400 samples:
//!
//! - a plant *setpoint* trajectory (slow daily drift + operator steps),
//! - first-order servo dynamics tracking the setpoint,
//! - flow through the valve `F = Cv(X)·√Δp` with slowly-varying line
//!   pressure,
//! - measurement noise on both reported channels.
//!
//! The observed vector matches the paper's Figs. 6–7: `x_k = [F, X]`
//! (flow measurement and valve position). Fault injection (Table 1
//! semantics) perturbs the *physics*, not the labels:
//!
//! - **f16** positioner supply pressure drop → servo gain collapses and
//!   the stem droops, so X sags and F follows;
//! - **f17** unexpected pressure change across the valve → Δp steps
//!   down, F drops with X unchanged;
//! - **f18** partly opened bypass valve → extra flow bypasses the valve,
//!   F steps up with X unchanged;
//! - **f19** flow sensor fault → reported F is rescaled + noisy while
//!   the true process is healthy.

use crate::util::prng::SplitMix64;

use super::faults::{FaultEvent, FaultType};
use super::trace::Trace;

/// Simulator tuning. Defaults reproduce Fig. 6/7-scale signatures.
#[derive(Debug, Clone)]
pub struct ActuatorConfig {
    /// Samples per generated trace (a DAMADICS day = 86 400 @ 1 Hz).
    pub samples: usize,
    /// Operator setpoint steps per day. The paper's evaporator runs near
    /// steady state, so the default is 0; raise it to stress TEDA with
    /// regime changes (the `regime_changes` ablation bench does).
    pub setpoint_steps: usize,
    /// Half-range of operator setpoint moves around the base level.
    pub step_range: f64,
    /// Amplitude of the slow daily sinusoidal drift.
    pub drift_amplitude: f64,
    /// Servo time constant (samples).
    pub servo_tau: f64,
    /// Std-dev of process noise on the servo position.
    pub process_noise: f64,
    /// Std-dev of measurement noise on both channels.
    pub measurement_noise: f64,
    /// Nominal pressure drop across the valve.
    pub nominal_dp: f64,
    /// Valve flow coefficient scale.
    pub cv_scale: f64,
    /// f16: multiplier on servo gain during the fault.
    pub f16_gain: f64,
    /// f16: per-sample stem droop during the fault.
    pub f16_droop: f64,
    /// f17: fractional Δp drop during the fault.
    pub f17_dp_drop: f64,
    /// f18: bypass flow fraction (of full-open valve flow).
    pub f18_bypass: f64,
    /// f19: sensor scale factor during the fault.
    pub f19_scale: f64,
    /// f19: extra sensor noise during the fault.
    pub f19_noise: f64,
}

impl Default for ActuatorConfig {
    fn default() -> Self {
        ActuatorConfig {
            samples: 86_400,
            setpoint_steps: 0,
            step_range: 0.06,
            drift_amplitude: 0.02,
            servo_tau: 40.0,
            process_noise: 0.002,
            measurement_noise: 0.004,
            nominal_dp: 1.0,
            cv_scale: 1.0,
            f16_gain: 0.25,
            f16_droop: 0.0015,
            f17_dp_drop: 0.35,
            f18_bypass: 0.18,
            f19_scale: 0.55,
            f19_noise: 0.02,
        }
    }
}

/// Deterministic (seeded) actuator simulator.
#[derive(Debug, Clone)]
pub struct ActuatorSim {
    cfg: ActuatorConfig,
    seed: u64,
}

impl ActuatorSim {
    /// New simulator; identical `(seed, cfg)` ⇒ identical traces.
    pub fn new(seed: u64, cfg: ActuatorConfig) -> Self {
        ActuatorSim { cfg, seed }
    }

    /// Convenience: default config.
    pub fn with_seed(seed: u64) -> Self {
        Self::new(seed, ActuatorConfig::default())
    }

    /// The config in use.
    pub fn config(&self) -> &ActuatorConfig {
        &self.cfg
    }

    /// Generate one day of operation, optionally with a fault injected
    /// over `fault`'s window. Observed features per sample: `[F, X]`.
    pub fn generate_day(&self, fault: Option<&FaultEvent>) -> Trace {
        let cfg = &self.cfg;
        // Derive independent noise streams so the *same* seed produces
        // the same in-control trajectory regardless of the fault window.
        let mut seed_src = SplitMix64::new(self.seed);
        let mut sp_rng = seed_src.split();
        let mut servo_rng = seed_src.split();
        let mut dp_rng = seed_src.split();
        let mut meas_rng = seed_src.split();

        // Operator step schedule (default: none — steady-state plant).
        let mut steps: Vec<(usize, f64)> = (0..cfg.setpoint_steps)
            .map(|_| {
                (
                    sp_rng.below(cfg.samples as u64) as usize,
                    sp_rng.uniform(0.6 - cfg.step_range, 0.6 + cfg.step_range),
                )
            })
            .collect();
        steps.sort_by_key(|s| s.0);

        let mut samples = Vec::with_capacity(cfg.samples);
        let mut labels = Vec::with_capacity(cfg.samples);

        let mut x = 0.6f64; // valve position (0..1)
        let mut sp_level = 0.6f64;
        let mut step_idx = 0usize;

        for k in 0..cfg.samples {
            // Setpoint: held level + slow sinusoidal drift.
            while step_idx < steps.len() && steps[step_idx].0 <= k {
                sp_level = steps[step_idx].1;
                step_idx += 1;
            }
            let drift = cfg.drift_amplitude
                * (k as f64 * std::f64::consts::TAU / 43_200.0).sin();
            let sp = (sp_level + drift).clamp(0.05, 0.95);

            let in_fault = fault.map(|f| f.contains(k)).unwrap_or(false);
            let ftype = fault.map(|f| f.fault);

            // Servo dynamics (+ f16 degradation).
            let mut gain = 1.0;
            if in_fault && ftype == Some(FaultType::F16) {
                gain = cfg.f16_gain;
                x -= cfg.f16_droop;
            }
            x += gain * (sp - x) / cfg.servo_tau
                + servo_rng.normal_with(0.0, cfg.process_noise);
            x = x.clamp(0.0, 1.0);

            // Pressure drop across the valve (+ f17 step).
            let mut dp = cfg.nominal_dp
                + 0.03 * (k as f64 * std::f64::consts::TAU / 21_600.0).cos()
                + dp_rng.normal_with(0.0, 0.003);
            if in_fault && ftype == Some(FaultType::F17) {
                dp *= 1.0 - cfg.f17_dp_drop;
            }
            dp = dp.max(0.0);

            // Flow through the valve (equal-percentage-ish Cv) + f18
            // bypass contribution.
            let cv = cfg.cv_scale * x;
            let mut flow = cv * dp.sqrt();
            if in_fault && ftype == Some(FaultType::F18) {
                flow += cfg.f18_bypass * cfg.cv_scale * dp.sqrt();
            }

            // Measurement channel (+ f19 sensor fault).
            let mut f_meas =
                flow + meas_rng.normal_with(0.0, cfg.measurement_noise);
            if in_fault && ftype == Some(FaultType::F19) {
                f_meas = f_meas * cfg.f19_scale
                    + meas_rng.normal_with(0.0, cfg.f19_noise);
            }
            let x_meas =
                x + meas_rng.normal_with(0.0, cfg.measurement_noise);

            samples.push(vec![f_meas, x_meas]);
            labels.push(in_fault);
        }

        Trace { samples, labels, fault: fault.cloned() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::damadics::faults::schedule_item;

    #[test]
    fn deterministic_per_seed() {
        let a = ActuatorSim::with_seed(1).generate_day(None);
        let b = ActuatorSim::with_seed(1).generate_day(None);
        assert_eq!(a.samples, b.samples);
        let c = ActuatorSim::with_seed(2).generate_day(None);
        assert_ne!(a.samples, c.samples);
    }

    #[test]
    fn healthy_trace_has_no_labels() {
        let t = ActuatorSim::with_seed(3).generate_day(None);
        assert_eq!(t.samples.len(), 86_400);
        assert!(t.labels.iter().all(|&l| !l));
        assert!(t.fault.is_none());
    }

    #[test]
    fn fault_window_is_labelled_exactly() {
        let ev = schedule_item(5).unwrap();
        let t = ActuatorSim::with_seed(3).generate_day(Some(&ev));
        for (k, &l) in t.labels.iter().enumerate() {
            assert_eq!(l, ev.contains(k), "k={k}");
        }
    }

    #[test]
    fn signals_bounded_and_finite() {
        let ev = schedule_item(1).unwrap();
        let t = ActuatorSim::with_seed(4).generate_day(Some(&ev));
        for s in &t.samples {
            assert_eq!(s.len(), 2);
            assert!(s.iter().all(|v| v.is_finite()));
            assert!(s[0] > -0.5 && s[0] < 2.5, "flow {}", s[0]);
            assert!(s[1] > -0.5 && s[1] < 1.5, "pos {}", s[1]);
        }
    }

    #[test]
    fn f18_raises_flow_in_window() {
        // Same seed with/without fault: flow must be visibly higher
        // inside the window, identical outside.
        let ev = schedule_item(1).unwrap(); // f18
        let sim = ActuatorSim::with_seed(7);
        let healthy = sim.generate_day(None);
        let faulty = sim.generate_day(Some(&ev));
        let mid = (ev.start + ev.end) / 2;
        let delta = faulty.samples[mid][0] - healthy.samples[mid][0];
        assert!(delta > 0.05, "bypass flow delta {delta}");
        // Identical before the fault (same noise streams).
        assert_eq!(faulty.samples[ev.start - 10], healthy.samples[ev.start - 10]);
    }

    #[test]
    fn f16_sags_position() {
        let ev = schedule_item(2).unwrap(); // f16
        let sim = ActuatorSim::with_seed(8);
        let healthy = sim.generate_day(None);
        let faulty = sim.generate_day(Some(&ev));
        let end = ev.end;
        assert!(
            faulty.samples[end][1] < healthy.samples[end][1] - 0.02,
            "position should droop: {} vs {}",
            faulty.samples[end][1],
            healthy.samples[end][1]
        );
    }

    #[test]
    fn f17_drops_flow_not_position() {
        let ev = schedule_item(7).unwrap(); // f17
        let sim = ActuatorSim::with_seed(9);
        let healthy = sim.generate_day(None);
        let faulty = sim.generate_day(Some(&ev));
        let mid = (ev.start + ev.end) / 2;
        assert!(
            faulty.samples[mid][0] < healthy.samples[mid][0] - 0.05,
            "flow should drop"
        );
        assert!(
            (faulty.samples[mid][1] - healthy.samples[mid][1]).abs() < 0.02,
            "position roughly unchanged"
        );
    }

    #[test]
    fn f19_rescales_measured_flow_only() {
        let mut ev = schedule_item(1).unwrap();
        ev.fault = FaultType::F19; // synthesize an f19 window
        let sim = ActuatorSim::with_seed(10);
        let healthy = sim.generate_day(None);
        let faulty = sim.generate_day(Some(&ev));
        let mid = (ev.start + ev.end) / 2;
        let ratio = faulty.samples[mid][0] / healthy.samples[mid][0];
        assert!(ratio < 0.85, "sensor reads low: ratio {ratio}");
    }
}

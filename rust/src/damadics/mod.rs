//! DAMADICS-like actuator benchmark substrate.
//!
//! The paper validates on the DAMADICS benchmark (actuator 1 of a Polish
//! sugar-factory evaporator; Tables 1–2, Figs. 6–7). The original dataset
//! is no longer distributable, so this module implements the substitution
//! documented in DESIGN.md §2: a physics-flavoured simulator of the
//! benchmark's control-valve + pneumatic-servo + positioner actuator,
//! with the paper's exact fault catalogue (Table 1) and actuator-1 fault
//! schedule (Table 2) injected at the published sample windows.
//!
//! What TEDA sees is the *statistical signature* of the signals — smooth
//! in-control behaviour with abrupt (f16–f18) or sensor-level (f19)
//! excursions at fault onset — which is exactly what this simulator
//! reproduces, at the same sample indices as the paper.

mod actuator;
mod faults;
mod metrics;
mod trace;

pub use actuator::{ActuatorConfig, ActuatorSim};
pub use faults::{
    actuator1_schedule, fault_catalog, schedule_item, FaultEvent, FaultType,
};
pub use metrics::{evaluate_detection, DetectionReport};
pub use trace::Trace;

//! Detection-quality metrics for fault experiments (Figs. 6–7 framing).

use super::faults::FaultEvent;

/// Outcome of running a detector over a labelled trace.
#[derive(Debug, Clone, PartialEq)]
pub struct DetectionReport {
    /// Fault item evaluated.
    pub item: u32,
    /// First flagged sample index inside the window, if any.
    pub first_detection: Option<usize>,
    /// Detection latency in samples from fault onset.
    pub latency: Option<usize>,
    /// Flagged samples inside the fault window.
    pub hits_in_window: usize,
    /// Window length.
    pub window_len: usize,
    /// Flags raised outside the window after the warmup prefix.
    pub false_alarms: usize,
    /// Samples considered for false alarms.
    pub normal_samples: usize,
}

impl DetectionReport {
    /// Whether the fault was caught at all.
    pub fn detected(&self) -> bool {
        self.first_detection.is_some()
    }

    /// Fraction of window samples flagged.
    pub fn window_hit_rate(&self) -> f64 {
        if self.window_len == 0 {
            0.0
        } else {
            self.hits_in_window as f64 / self.window_len as f64
        }
    }

    /// False alarms per normal sample.
    pub fn false_alarm_rate(&self) -> f64 {
        if self.normal_samples == 0 {
            0.0
        } else {
            self.false_alarms as f64 / self.normal_samples as f64
        }
    }
}

/// Score a verdict sequence against a fault window.
///
/// `outlier_flags[k]` is the detector's verdict for sample k; samples
/// before `warmup` are excluded from false-alarm accounting (every
/// streaming detector needs a run-in; the paper's plots likewise start
/// deep into the day).
pub fn evaluate_detection(
    outlier_flags: &[bool],
    event: &FaultEvent,
    warmup: usize,
) -> DetectionReport {
    let mut first_detection = None;
    let mut hits = 0usize;
    let mut false_alarms = 0usize;
    let mut normal = 0usize;
    for (k, &flag) in outlier_flags.iter().enumerate() {
        if event.contains(k) {
            if flag {
                hits += 1;
                if first_detection.is_none() {
                    first_detection = Some(k);
                }
            }
        } else if k >= warmup {
            normal += 1;
            if flag {
                false_alarms += 1;
            }
        }
    }
    DetectionReport {
        item: event.item,
        first_detection,
        latency: first_detection.map(|k| k - event.start),
        hits_in_window: hits,
        window_len: event.len().min(outlier_flags.len()),
        false_alarms,
        normal_samples: normal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::damadics::faults::{FaultEvent, FaultType};

    fn event() -> FaultEvent {
        FaultEvent {
            item: 42,
            fault: FaultType::F18,
            start: 10,
            end: 19,
            date: "",
            description: "",
        }
    }

    #[test]
    fn detects_and_measures_latency() {
        let mut flags = vec![false; 30];
        flags[13] = true;
        flags[14] = true;
        let r = evaluate_detection(&flags, &event(), 5);
        assert!(r.detected());
        assert_eq!(r.first_detection, Some(13));
        assert_eq!(r.latency, Some(3));
        assert_eq!(r.hits_in_window, 2);
        assert_eq!(r.window_len, 10);
        assert_eq!(r.false_alarms, 0);
        assert!((r.window_hit_rate() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn counts_false_alarms_after_warmup_only() {
        let mut flags = vec![false; 30];
        flags[2] = true; // inside warmup — ignored
        flags[25] = true; // false alarm
        let r = evaluate_detection(&flags, &event(), 5);
        assert!(!r.detected());
        assert_eq!(r.false_alarms, 1);
        // normal samples: k in [5,30) minus window [10,19] = 25-10=15
        assert_eq!(r.normal_samples, 15);
        assert!(r.false_alarm_rate() > 0.0);
    }

    #[test]
    fn empty_flags_safe() {
        let r = evaluate_detection(&[], &event(), 0);
        assert!(!r.detected());
        assert_eq!(r.window_hit_rate(), 0.0);
        assert_eq!(r.false_alarm_rate(), 0.0);
    }
}

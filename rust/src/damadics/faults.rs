//! Fault catalogue (Table 1) and the actuator-1 artificial fault
//! schedule (Table 2) exactly as published.

use std::fmt;

/// DAMADICS fault types used by the paper (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultType {
    /// f16 — positioner supply pressure drop.
    F16,
    /// f17 — unexpected pressure change across the valve.
    F17,
    /// f18 — fully or partly opened bypass valves.
    F18,
    /// f19 — flow rate sensor fault.
    F19,
}

impl FaultType {
    /// Table 1 description string.
    pub fn description(self) -> &'static str {
        match self {
            FaultType::F16 => "Positioner supply pressure drop",
            FaultType::F17 => "Unexpected pressure change across the valve",
            FaultType::F18 => "Fully or partly opened bypass valves",
            FaultType::F19 => "Flow rate sensor fault",
        }
    }

    /// All Table 1 rows in order.
    pub fn all() -> [FaultType; 4] {
        [FaultType::F16, FaultType::F17, FaultType::F18, FaultType::F19]
    }
}

impl fmt::Display for FaultType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FaultType::F16 => "f16",
            FaultType::F17 => "f17",
            FaultType::F18 => "f18",
            FaultType::F19 => "f19",
        };
        f.write_str(s)
    }
}

/// One Table 2 row: an artificial fault injected into actuator 1.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultEvent {
    /// Table 2 "Item" column (1-based).
    pub item: u32,
    /// Fault type.
    pub fault: FaultType,
    /// First faulty sample index within the day trace (inclusive).
    pub start: usize,
    /// Last faulty sample index (inclusive).
    pub end: usize,
    /// Table 2 "Date" column (documentation only; the sim keys off
    /// sample indices).
    pub date: &'static str,
    /// Table 2 "Description" column.
    pub description: &'static str,
}

impl FaultEvent {
    /// Number of faulty samples.
    pub fn len(&self) -> usize {
        self.end - self.start + 1
    }

    /// True when the window is empty (never, for Table 2 rows).
    pub fn is_empty(&self) -> bool {
        self.end < self.start
    }

    /// Whether sample index `k` (0-based within the day) is in the window.
    pub fn contains(&self, k: usize) -> bool {
        (self.start..=self.end).contains(&k)
    }
}

/// Table 1 — the fault catalogue.
pub fn fault_catalog() -> Vec<(FaultType, &'static str)> {
    FaultType::all().iter().map(|&f| (f, f.description())).collect()
}

/// Table 2 — the list of artificial failures introduced to actuator 1.
///
/// Sample windows are verbatim from the paper. (Item 1's figure caption
/// places the visible excursion at 58900–59800; the table row says
/// 58800–59800 — we keep the table row.)
pub fn actuator1_schedule() -> Vec<FaultEvent> {
    vec![
        FaultEvent {
            item: 1,
            fault: FaultType::F18,
            start: 58_800,
            end: 59_800,
            date: "Oct 30, 2001",
            description: "Partly opened bypass valve",
        },
        FaultEvent {
            item: 2,
            fault: FaultType::F16,
            start: 57_275,
            end: 57_550,
            date: "Nov 9, 2001",
            description: "Positioner supply pressure drop",
        },
        FaultEvent {
            item: 3,
            fault: FaultType::F18,
            start: 58_830,
            end: 58_930,
            date: "Nov 9, 2001",
            description: "Partly opened bypass valve",
        },
        FaultEvent {
            item: 4,
            fault: FaultType::F18,
            start: 58_520,
            end: 58_625,
            date: "Nov 9, 2001",
            description: "Partly opened bypass valve",
        },
        FaultEvent {
            item: 5,
            fault: FaultType::F18,
            start: 54_600,
            end: 54_700,
            date: "Nov 17, 2001",
            description: "Partly opened bypass valve",
        },
        FaultEvent {
            item: 6,
            fault: FaultType::F16,
            start: 56_670,
            end: 56_770,
            date: "Nov 17, 2001",
            description: "Positioner supply pressure drop",
        },
        FaultEvent {
            item: 7,
            fault: FaultType::F17,
            start: 37_780,
            end: 38_400,
            date: "Nov 20, 2001",
            description: "Unexpected pressure drop across the valve",
        },
    ]
}

/// Look up a Table 2 row by its Item number.
pub fn schedule_item(item: u32) -> Option<FaultEvent> {
    actuator1_schedule().into_iter().find(|e| e.item == item)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_seven_items_in_order() {
        let sched = actuator1_schedule();
        assert_eq!(sched.len(), 7);
        for (i, e) in sched.iter().enumerate() {
            assert_eq!(e.item as usize, i + 1);
            assert!(e.start < e.end, "item {}", e.item);
            assert!(!e.is_empty());
        }
    }

    #[test]
    fn table2_windows_match_paper() {
        let sched = actuator1_schedule();
        assert_eq!((sched[0].start, sched[0].end), (58_800, 59_800));
        assert_eq!(sched[0].fault, FaultType::F18);
        assert_eq!((sched[6].start, sched[6].end), (37_780, 38_400));
        assert_eq!(sched[6].fault, FaultType::F17);
        assert_eq!(sched[1].fault, FaultType::F16);
    }

    #[test]
    fn windows_fit_in_a_day_trace() {
        for e in actuator1_schedule() {
            assert!(e.end < 86_400, "item {} exceeds one day", e.item);
        }
    }

    #[test]
    fn contains_is_inclusive() {
        let e = schedule_item(3).unwrap();
        assert!(e.contains(58_830));
        assert!(e.contains(58_930));
        assert!(!e.contains(58_829));
        assert!(!e.contains(58_931));
        assert_eq!(e.len(), 101);
    }

    #[test]
    fn catalog_matches_table1() {
        let cat = fault_catalog();
        assert_eq!(cat.len(), 4);
        assert_eq!(cat[0].0.to_string(), "f16");
        assert!(cat[3].1.contains("Flow rate sensor"));
    }
}
